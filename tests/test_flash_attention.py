"""Flash-attention Pallas kernel vs oracles (interpret mode), incl. GQA/MQA,
padding paths and a dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.models.lm.attention import blockwise_attention, full_attention


@pytest.mark.parametrize("b,s,h,kv,d,bq,bk", [
    (2, 256, 8, 4, 32, 64, 64),
    (1, 512, 4, 1, 64, 128, 128),   # MQA
    (2, 300, 6, 6, 16, 128, 64),    # non-aligned seq -> padding
    (1, 128, 20, 20, 128, 128, 128),
    (2, 192, 8, 2, 32, 64, 96),
])
def test_flash_matches_full_attention(b, s, h, kv, d, bq, bk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)).astype(np.float32))
    ref = full_attention(q, k, v, causal=True)
    pal = flash_attention(q, k, v, causal=True, use_pallas=True,
                          block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=5e-5)


def test_flash_ref_matches_blockwise():
    """Three independent implementations agree (kernel oracle, pure-jnp
    blockwise, dense full attention)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 32)).astype(np.float32))
    a = flash_attention(q, k, v, causal=True)  # oracle path
    c = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=5e-5)


def test_flash_bf16_inputs():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 4, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 4, 32))).astype(jnp.bfloat16)
    ref = full_attention(q, k, v, causal=True)
    pal = flash_attention(q, k, v, causal=True, use_pallas=True,
                          block_q=64, block_k=64)
    assert pal.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(pal, np.float32), atol=3e-2)


@given(s=st.integers(16, 200), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), d=st.sampled_from([8, 16]))
@settings(max_examples=15, deadline=None)
def test_flash_property(s, h, kv, d):
    rng = np.random.default_rng(s * 3 + h)
    q = jnp.asarray(rng.standard_normal((1, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, s, kv, d)).astype(np.float32))
    ref = full_attention(q, k, v, causal=True)
    pal = flash_attention(q, k, v, causal=True, use_pallas=True,
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=5e-5)
