"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment spec)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, LM_ARCHS
from repro.models import a3tgcn, dcrnn, pgt_dcrnn, stllm
from repro.models.lm import model as lm
from repro.data import (gaussian_adjacency, random_sensor_coords,
                        sym_norm_adjacency, transition_matrices)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", sorted(LM_ARCHS))
def test_lm_arch_forward_and_train_step(arch_id):
    cfg = LM_ARCHS[arch_id].smoke_config()
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)

    logits, aux = lm.forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one SGD-flavoured step: loss decreases-or-changes and grads are finite
    def loss(p):
        l, _ = lm.loss_fn(p, cfg, toks, toks)
        return l

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    assert float(loss(p2)) != float(l0)


@pytest.mark.parametrize("arch_id", sorted(LM_ARCHS))
def test_lm_arch_prefill_decode(arch_id):
    cfg = LM_ARCHS[arch_id].smoke_config()
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    cache = lm.init_cache(cfg, 2, 32)
    logits, cache, lengths = lm.prefill(params, cfg, toks, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    for _ in range(3):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = lm.decode_step(params, cfg, nxt, cache, lengths)
        lengths = lengths + 1
        assert not bool(jnp.any(jnp.isnan(logits)))


def test_decode_matches_prefill_teacher_forcing():
    """Decoding token-by-token must agree with one big prefill (cache math)."""
    for arch_id in ("minitron-8b", "h2o-danube-3-4b", "recurrentgemma-2b",
                    "rwkv6-1.6b", "deepseek-v2-lite-16b"):
        cfg = LM_ARCHS[arch_id].smoke_config()
        params = lm.init(KEY, cfg)
        seq = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
        # full-sequence logits via train forward
        full_logits, _ = lm.forward(params, cfg, seq)
        # incremental: prefill 6, then decode the next 6 teacher-forced
        cache = lm.init_cache(cfg, 1, 32)
        logits, cache, lengths = lm.prefill(params, cfg, seq[:, :6], cache)
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(full_logits[0, 5]),
                                   atol=5e-2, rtol=5e-2)
        for t in range(6, 11):
            logits, cache = lm.decode_step(params, cfg, seq[:, t:t + 1],
                                           cache, lengths)
            lengths = lengths + 1
            np.testing.assert_allclose(
                np.asarray(logits[0]), np.asarray(full_logits[0, t]),
                atol=5e-2, rtol=5e-2,
                err_msg=f"{arch_id} step {t}")


def test_vlm_prefix_path():
    cfg = LM_ARCHS["internvl2-26b"].smoke_config()
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    prefix = jax.random.normal(KEY, (2, 4, cfg.d_model), jnp.float32)
    loss, metrics = lm.loss_fn(params, cfg, toks, toks, prefix_embeds=prefix)
    assert np.isfinite(float(loss))


def test_vocab_padding_equivalence():
    """Padded-vocab logits mask: finite on real ids, -inf on padding."""
    base = LM_ARCHS["qwen1.5-4b"].smoke_config()
    cfg = dataclasses.replace(base, vocab=100, pad_vocab_to_multiple=16)
    assert cfg.padded_vocab == 112
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 8), 0, 100)
    logits, _ = lm.forward(params, cfg, toks)
    assert logits.shape[-1] == 112
    assert bool(jnp.all(logits[..., 100:] < -1e29))
    l, _ = lm.loss_fn(params, cfg, toks, toks)
    assert np.isfinite(float(l))


# ----------------------------------------------------------------- ST-GNN side
def _graph(n):
    adj = gaussian_adjacency(random_sensor_coords(n))
    return (tuple(jnp.asarray(s) for s in transition_matrices(adj)),
            jnp.asarray(sym_norm_adjacency(adj)))


@pytest.mark.parametrize("remat", [False, True])
def test_dcrnn_full_model(remat):
    n = 20
    sup, _ = _graph(n)
    cfg = dcrnn.DCRNNConfig(num_nodes=n, hidden=8, layers=2, input_len=4,
                            horizon=4, remat=remat)
    params = dcrnn.init(KEY, cfg)
    x = jax.random.normal(KEY, (3, 4, n, 2))
    pred = dcrnn.apply(params, cfg, sup, x)
    assert pred.shape == (3, 4, n, 1)
    assert not bool(jnp.any(jnp.isnan(pred)))
    g = jax.grad(lambda p: dcrnn.loss_fn(p, cfg, sup, x, x))(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))


def test_dcrnn_scheduled_sampling():
    n = 12
    sup, _ = _graph(n)
    cfg = dcrnn.DCRNNConfig(num_nodes=n, hidden=8, layers=1, input_len=3, horizon=3)
    params = dcrnn.init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 3, n, 2))
    y = jax.random.normal(KEY, (2, 3, n, 1))
    p0 = dcrnn.apply(params, cfg, sup, x)
    p1 = dcrnn.apply(params, cfg, sup, x, y_teacher=y, teacher_prob=1.0,
                     rng=jax.random.PRNGKey(2))
    # full teacher forcing changes the decoder inputs -> different outputs
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


def test_pgt_dcrnn_and_a3tgcn_and_stllm():
    n = 16
    sup, a_hat = _graph(n)
    x = jax.random.normal(KEY, (2, 4, n, 2))
    y = jax.random.normal(KEY, (2, 4, n, 2))

    pcfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=n, hidden=8, input_len=4, horizon=4)
    assert np.isfinite(float(pgt_dcrnn.loss_fn(pgt_dcrnn.init(KEY, pcfg), pcfg,
                                               sup, x, y)))

    acfg = a3tgcn.A3TGCNConfig(num_nodes=n, hidden=8, input_len=4, horizon=4)
    pred = a3tgcn.apply(a3tgcn.init(KEY, acfg), acfg, a_hat, x)
    assert pred.shape == (2, 4, n, 1)

    scfg = stllm.STLLMConfig(num_nodes=n, input_len=4, horizon=4, d_model=32,
                             layers=2, n_heads=4, d_ff=64)
    pred = stllm.apply(stllm.init(KEY, scfg), scfg, x)
    assert pred.shape == (2, 4, n, 1)
    assert not bool(jnp.any(jnp.isnan(pred)))


def test_param_counts_match_billing():
    """Analytic param_count ≈ actual initialized leaves (±2%)."""
    for arch_id in ("qwen1.5-4b", "minitron-8b", "rwkv6-1.6b"):
        cfg = LM_ARCHS[arch_id].smoke_config()
        params = lm.init(KEY, cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        # analytic count uses the same formulae billed for the roofline
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.25, (arch_id, actual, est)
