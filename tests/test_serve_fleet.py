"""Elastic serving fleet (PR 9 tentpole): workers, coordinator, kill/rejoin.

In-process pins of the fleet contracts (the multihost harness proves the
same drill across real processes — ``tests/multihost.py``):

- mailbox spools deliver in order exactly once (both flavours);
- a 2-worker fleet's greedy output is bit-identical to the reference
  ``Server`` — distribution changes WHERE a request runs, never what it
  generates;
- kill drill: a dead worker's in-flight requests are re-prefilled on the
  survivor from prompt + generated prefix, outputs still bit-identical
  (greedy argmax continuation is exact);
- rejoin: a returned incarnation (bumped ``attempt``) is assigned new work;
  messages from the dead incarnation are dropped (no double-finish);
- coordinator mirrors block accounting: a never-fitting request is rejected
  at fleet submit; deadlines cancel in-flight work on the worker.
"""
import numpy as np
import pytest

from repro.configs import LM_ARCHS
from repro.models.lm import model as lm
from repro.serve import (FileMailbox, FleetEngine, LocalMailbox, ServeConfig,
                         ServeWorker, Server)

import jax


@pytest.fixture(scope="module")
def lm_setup():
    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _prompts(n, rng, lo=2, hi=10):
    return [rng.integers(0, 120, size=int(rng.integers(lo, hi))) for _ in range(n)]


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _build_fleet(params, cfg, sc, *, world=2, clock=None):
    fleet = FleetEngine(sc, world=world, hb_timeout=1.5,
                        clock=clock or _Clock())
    workers = {}
    for wid in range(world):
        inbox, outbox = LocalMailbox(), LocalMailbox()
        workers[wid] = ServeWorker(params, cfg, sc, worker_id=wid,
                                   inbox=inbox, outbox=outbox)
        fleet.attach(wid, send=inbox, recv=outbox)
    return fleet, workers


def _drive(fleet, workers, clock, *, skip=(), limit=600):
    """Tick coordinator + workers with fresh beats until the fleet drains."""
    n = 0
    while fleet.pending() or n == 0:
        fleet.tracker.observe({w.worker_id: n for w in workers.values()
                               if w.worker_id not in skip})
        fleet.tick()
        for w in workers.values():
            if w.worker_id not in skip:
                w.tick()
        clock.t += 0.01
        n += 1
        assert n < limit, "fleet made no progress"
    return fleet.results()


# ------------------------------------------------------------------ mailboxes
def test_local_mailbox_fifo_exactly_once():
    mb = LocalMailbox()
    for i in range(3):
        mb.send({"i": i})
    assert [m["i"] for m in mb.recv()] == [0, 1, 2]
    assert mb.recv() == []  # drained


def test_file_mailbox_ordered_and_gap_proof(tmp_path):
    mb = FileMailbox(str(tmp_path / "spool"))
    for i in range(5):
        mb.send({"i": i})
    reader = FileMailbox(str(tmp_path / "spool"))
    assert [m["i"] for m in reader.recv()] == [0, 1, 2, 3, 4]
    assert reader.recv() == []
    # a fresh writer over an existing spool continues the sequence
    mb2 = FileMailbox(str(tmp_path / "spool"))
    mb2.send({"i": 5})
    assert [m["i"] for m in reader.recv()] == [5]


def test_file_mailbox_reader_stops_at_gap(tmp_path):
    """A missing sequence number (message mid-write) delays delivery, never
    reorders: the reader stops at the gap and resumes once it fills."""
    import os
    d = str(tmp_path / "spool")
    mb = FileMailbox(d)
    mb.send({"i": 0})
    mb.send({"i": 1})
    os.rename(os.path.join(d, "m_00000001.json"),
              os.path.join(d, "hidden"))
    reader = FileMailbox(d)
    assert reader.recv() == []  # message 1 missing: nothing delivered yet
    os.rename(os.path.join(d, "hidden"),
              os.path.join(d, "m_00000001.json"))
    assert [m["i"] for m in reader.recv()] == [0, 1]


# ------------------------------------------------------------ fleet identity
def test_fleet_bit_identical_to_server(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=6, eos_id=7)
    rng = np.random.default_rng(0)
    prompts = _prompts(6, rng)
    srv = Server(params, cfg, sc)
    for p in prompts:
        srv.submit(p)
    ref = srv.run()

    clock = _Clock()
    fleet, workers = _build_fleet(params, cfg, sc, clock=clock)
    rids = [fleet.submit(p) for p in prompts]
    res = _drive(fleet, workers, clock)
    for i, rid in enumerate(rids):
        assert res[rid] == ref[i], f"request {i} diverged"
    # both workers actually served (the point of a fleet)
    assert all(w.served > 0 for w in fleet.workers.values())


def test_fleet_kill_restores_on_survivor_bit_identical(lm_setup):
    """THE elasticity contract: kill a worker mid-decode; its in-flight
    requests re-prefill on the survivor from prompt + generated prefix and
    every output stays bit-identical to the reference server."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=8, block_size=4)
    rng = np.random.default_rng(0)
    prompts = _prompts(6, rng)
    srv = Server(params, cfg, ServeConfig(slots=2, max_len=48,
                                          max_new_tokens=8))
    for p in prompts:
        srv.submit(p)
    ref = srv.run()

    clock = _Clock()
    fleet, workers = _build_fleet(params, cfg, sc, clock=clock)
    rids = [fleet.submit(p) for p in prompts]

    n, killed, saw_partial = 0, False, False
    while fleet.pending() or n == 0:
        beats = {0: n} if killed else {0: n, 1: n}
        fleet.tracker.observe(beats)
        fleet.tick()
        for wid, w in workers.items():
            if not (killed and wid == 1):
                w.tick()
        if not killed and n == 3:
            # kill mid-decode: worker 1 holds in-flight work with a partial
            # generated prefix (the restore path must CONTINUE, not restart)
            infl = fleet.workers[1].inflight
            saw_partial = any(0 < len(r.out) < r.budget
                              for r, _ in infl.values())
            assert infl, "worker 1 had nothing in flight at the kill point"
            killed = True
            clock.t += 2.0  # silence > hb_timeout: tracker flips it dead
        clock.t += 0.01
        n += 1
        assert n < 800, "fleet made no progress after the kill"

    assert saw_partial, "kill point missed the mid-decode window"
    res = fleet.results()
    for i, rid in enumerate(rids):
        assert res[rid] == ref[i], f"request {i} diverged after the kill"
    assert fleet.workers[1].served == 0  # everything landed on the survivor
    assert fleet.workers[0].served == len(prompts)


def test_fleet_kill_restores_sampled_bit_identical(lm_setup):
    """The PR-10 payoff: the same kill→re-prefill drill at temperature > 0.
    Keyed draws depend only on (seed, rid, position), so the survivor's
    re-prefill of prompt + g generated tokens samples at position plen + g —
    re-deriving exactly the draw the dead worker would have made next."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=8)
    rng = np.random.default_rng(0)
    prompts = _prompts(6, rng)
    srv = Server(params, cfg, sc)
    for i, p in enumerate(prompts):
        srv.submit(p, temperature=0.8, seed=40 + i)
    ref = srv.run()

    clock = _Clock()
    fleet, workers = _build_fleet(params, cfg, sc, clock=clock)
    rids = [fleet.submit(p, temperature=0.8, seed=40 + i)
            for i, p in enumerate(prompts)]

    n, killed, saw_partial = 0, False, False
    while fleet.pending() or n == 0:
        beats = {0: n} if killed else {0: n, 1: n}
        fleet.tracker.observe(beats)
        fleet.tick()
        for wid, w in workers.items():
            if not (killed and wid == 1):
                w.tick()
        if not killed and n == 3:
            infl = fleet.workers[1].inflight
            saw_partial = any(0 < len(r.out) < r.budget
                              for r, _ in infl.values())
            assert infl, "worker 1 had nothing in flight at the kill point"
            killed = True
            clock.t += 2.0
        clock.t += 0.01
        n += 1
        assert n < 800, "fleet made no progress after the kill"

    assert saw_partial, "kill point missed the mid-decode window"
    res = fleet.results()
    for i, rid in enumerate(rids):
        assert res[rid] == ref[i], \
            f"sampled request {i} diverged after the kill"


def test_fleet_rejoin_and_stale_incarnation_dropped(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4)
    rng = np.random.default_rng(2)
    prompts = _prompts(4, rng)
    srv = Server(params, cfg, sc)
    for p in prompts:
        srv.submit(p)
    ref = srv.run()

    clock = _Clock()
    fleet, workers = _build_fleet(params, cfg, sc, clock=clock)
    # kill worker 1 before it ever beats, drain the first wave on worker 0
    clock.t += 2.0
    fleet.tracker.observe({0: 0})
    rids = [fleet.submit(p) for p in prompts[:2]]
    res = _drive(fleet, workers, clock, skip=(1,))
    assert [res[r] for r in rids] == [ref[0], ref[1]]

    # the dead incarnation's ghost: a stale-attempt report must be dropped
    ghost_out = fleet.workers[1].recv
    ghost_out.send({"kind": "report", "attempt": 0, "step": 99,
                    "toks": {str(rids[0]): [123]}, "done": {}})

    # rejoin: fresh incarnation, bumped attempt, fresh beats -> live again
    inbox, outbox = LocalMailbox(), LocalMailbox()
    fleet.attach(1, send=inbox, recv=outbox)
    assert fleet.workers[1].attempt == 1
    workers[1] = ServeWorker(params, cfg, sc, worker_id=1, inbox=inbox,
                             outbox=outbox, attempt=1)
    before = dict(fleet.results())
    rids2 = [fleet.submit(p) for p in prompts[2:]]
    res2 = _drive(fleet, workers, clock)
    assert [res2[r] for r in rids2] == [ref[2], ref[3]]
    assert fleet.workers[1].served > 0, "returned worker got no work"
    # the ghost report changed nothing
    assert {r: res2[r] for r in rids} == {r: before[r] for r in rids}


# ----------------------------------------------------------------- admission
def test_fleet_paged_never_fits_rejected(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=20,
                     block_size=4, pool_blocks=3)
    fleet = FleetEngine(sc, world=1, clock=_Clock())
    with pytest.raises(ValueError, match="blocks"):
        fleet.submit(np.arange(1, 9, dtype=np.int32))


def test_fleet_deadline_cancels_inflight(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=1, max_len=48, max_new_tokens=30)
    clock = _Clock()
    fleet, workers = _build_fleet(params, cfg, sc, world=1, clock=clock)
    rid = fleet.submit(np.array([3, 1, 4], np.int32), deadline_s=0.5)
    for n in range(4):  # assign + a few decode steps
        fleet.tracker.observe({0: n})
        fleet.tick()
        workers[0].tick()
        clock.t += 0.01
    clock.t = 1.0  # past the deadline while ACTIVE on the worker
    fleet.tracker.observe({0: 9})
    fleet.tick()  # coordinator times it out + sends cancel
    req = fleet.router.done[rid]
    assert req.status == "timeout" and 0 < len(req.out) < 30
    for _ in range(3):  # worker processes the cancel and frees the lane
        workers[0].tick()
    assert len(workers[0].engine.planes[0].free_slots()) == 1
    assert fleet.pending() == 0
