"""Integration: the full paper workflow end-to-end (train converges, resumes
bit-identically after a simulated failure) and the serving loop."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_ARCHS
from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import Checkpointer, restore
from repro.models import pgt_dcrnn
from repro.models.lm import model as lm
from repro.optim import AdamConfig
from repro.train import ServeConfig, Server, TrainLoopConfig, run_training
from repro.train.loop import init_train_state, make_train_step

N = 12


@pytest.fixture(scope="module")
def workflow():
    series = make_traffic_series(240, N, seed=1)
    ds = IndexDataset.from_raw(series, WindowSpec(horizon=4, input_len=4)).to_device()
    adj = gaussian_adjacency(random_sensor_coords(N, seed=1))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=8, input_len=4, horizon=4)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, starts):
        x, y = gather_batch(ds.series, starts, input_len=4, horizon=4)
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    return ds, cfg, params, loss_fn


def test_training_converges(workflow):
    ds, cfg, params, loss_fn = workflow
    adam = AdamConfig(lr=1e-2)
    step = make_train_step(loss_fn, adam, lambda s: 1e-2, donate=False)
    sampler = GlobalShuffleSampler(ds.train_windows, 8, ShardInfo(0, 1), seed=0)
    state, hist = run_training(
        state=init_train_state(params, adam), train_step=step, sampler=sampler,
        batch_of_starts=lambda s: jnp.asarray(s),
        loop=TrainLoopConfig(epochs=3, log_every=5))
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses[-1] < 0.6 * losses[0]


def test_restart_resumes_bit_identical(tmp_path, workflow):
    """Fault tolerance: kill after step K, restore, finish — final params must
    equal the uninterrupted run exactly (deterministic samplers + ckpt)."""
    ds, cfg, params, loss_fn = workflow
    adam = AdamConfig(lr=1e-2)
    sampler = GlobalShuffleSampler(ds.train_windows, 8, ShardInfo(0, 1), seed=0)
    mk = lambda: make_train_step(loss_fn, adam, lambda s: 1e-2, donate=False)
    batch_of = lambda s: jnp.asarray(s)

    # uninterrupted run: 2 epochs
    s_full, _ = run_training(
        state=init_train_state(params, adam), train_step=mk(), sampler=sampler,
        batch_of_starts=batch_of, loop=TrainLoopConfig(epochs=2, log_every=0))

    # interrupted run: save at some mid step, "crash", restore, continue
    ck = Checkpointer(str(tmp_path), async_write=False)
    spe = sampler.steps_per_epoch
    mid = spe + spe // 2  # mid-second-epoch
    s_a, _ = run_training(
        state=init_train_state(params, adam), train_step=mk(), sampler=sampler,
        batch_of_starts=batch_of,
        loop=TrainLoopConfig(epochs=2, log_every=0, ckpt_every=mid),
        checkpointer=ck)
    # restore from the mid-epoch checkpoint and REPLAY the remainder
    template = init_train_state(params, adam)
    restored, step0 = restore(str(tmp_path), template, step=mid)
    s_b, _ = run_training(
        state=restored, train_step=mk(), sampler=sampler,
        batch_of_starts=batch_of, loop=TrainLoopConfig(epochs=2, log_every=0),
        start_epoch=step0 // spe, start_step=step0)

    for a, b in zip(jax.tree.leaves(s_full["params"]), jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_grad_compression_bf16_close(workflow):
    ds, cfg, params, loss_fn = workflow
    adam = AdamConfig(lr=1e-2)
    s_f32 = make_train_step(loss_fn, adam, lambda s: 1e-2, donate=False)
    s_bf16 = make_train_step(loss_fn, adam, lambda s: 1e-2, donate=False,
                             grad_dtype="bfloat16")
    batch = jnp.asarray(
        GlobalShuffleSampler(ds.train_windows, 8, ShardInfo(0, 1)).epoch_global(0)[0])
    a, _ = s_f32(init_train_state(params, adam), batch)
    b, _ = s_bf16(init_train_state(params, adam), batch)
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=5e-3)


# ----------------------------------------------------------------------- serve
def test_server_continuous_batching():
    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)
    srv = Server(params, cfg, ServeConfig(slots=2, max_len=48, max_new_tokens=4))
    rng = np.random.default_rng(0)
    rids = [srv.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(2, 8))))
            for _ in range(5)]
    out = srv.run()
    assert set(out) == set(rids)
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < cfg.padded_vocab for v in out.values() for t in v)


def test_server_greedy_matches_manual_decode():
    """One slot, one request: the server must equal hand-rolled greedy decode."""
    cfg = LM_ARCHS["minitron-8b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(2), cfg)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)

    srv = Server(params, cfg, ServeConfig(slots=1, max_len=32, max_new_tokens=5))
    rid = srv.submit(prompt)
    out = srv.run()[rid]

    cache = lm.init_cache(cfg, 1, 32)
    logits, cache, lengths = lm.prefill(params, cfg, jnp.asarray(prompt[None]), cache)
    manual = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(4):
        tok = jnp.asarray([[manual[-1]]], jnp.int32)
        logits, cache = lm.decode_step(params, cfg, tok, cache, lengths)
        lengths = lengths + 1
        manual.append(int(jnp.argmax(logits, -1)[0]))
    assert out == manual
