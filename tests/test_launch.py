"""Launch layer on the single real device: sharding rules, cost analyzer,
cell builders (shapes only), and a tiny-mesh end-to-end sharded train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import LM_ARCHS, get_arch
from repro.launch import costs as C
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import model_flops, roofline_terms


def _mesh1():
    return make_host_mesh(model=1)


# ------------------------------------------------------------- sharding rules
def test_lm_param_specs_tp_divisibility():
    """Rules must only shard dims that divide the axis; fall back otherwise."""
    cfg = get_arch("minitron-8b").lm  # heads 32, kv 8, d_ff 16384
    # tile to exactly 16 mesh slots regardless of the host's device count
    mesh16 = Mesh(np.array((jax.devices() * 16)[:16]).reshape(1, 16),
                  ("data", "model"))
    spec = shd.lm_param_spec("stages/0/sub0/attn/wq/w", (32, 4096, 4096),
                             cfg, mesh16)
    assert spec[-1] == "model"  # heads 32 % 16 == 0 -> column parallel
    spec_kv = shd.lm_param_spec("stages/0/sub0/attn/wk/w", (32, 4096, 1024),
                                cfg, mesh16)
    assert spec_kv[-1] is None  # kv heads 8 % 16 != 0 -> replicated on model

    qwen = get_arch("qwen1.5-4b").lm  # heads 20 -> not divisible
    spec_q = shd.lm_param_spec("stages/0/sub0/attn/wq/w", (40, 2560, 2560),
                               qwen, mesh16)
    assert "model" not in tuple(spec_q)


def test_lm_head_vocab_sharded():
    cfg = get_arch("qwen1.5-4b").lm
    mesh16 = Mesh(np.array(jax.devices() * 16)[:16].reshape(1, 16), ("data", "model"))
    spec = shd.lm_param_spec("lm_head/w", (2560, 151936), cfg, mesh16)
    assert spec[-1] == "model"


def test_fsdp_respects_divisibility_and_size():
    cfg = get_arch("qwen1.5-4b").lm
    mesh = Mesh(np.array(jax.devices() * 16)[:16].reshape(4, 4), ("data", "model"))
    # tiny leaf (< min_size elements): no FSDP
    spec = shd.lm_param_spec("stages/0/sub0/norm1", (40, 64), cfg, mesh)
    assert tuple(spec) == (None, None)
    # large leaf: largest divisible dim gets "data"
    spec2 = shd.lm_param_spec("stages/0/sub0/mlp/wi/w", (40, 2560, 6912), cfg, mesh)
    assert "data" in tuple(spec2)


# -------------------------------------------------------------- cost analyzer
def test_costs_scan_trip_rollup():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = C.analyze_hlo(jax.jit(f).lower(x).compile().as_text())
    assert r.flops == pytest.approx(7 * 2 * 128**3, rel=0.01)


def test_costs_nested_loops():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def g(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = C.analyze_hlo(jax.jit(g).lower(x).compile().as_text())
    assert r.flops == pytest.approx(15 * 2 * 64**3, rel=0.01)


def test_costs_bytes_scan_over_stack_slice_aware():
    """Per-iteration traffic must be slice-sized, not whole-stack-sized."""
    def body(c, x):
        return c + x, None

    def f(stack):
        y, _ = jax.lax.scan(body, jnp.zeros((256, 256)), stack)
        return y

    stack = jax.ShapeDtypeStruct((100, 256, 256), jnp.float32)
    r = C.analyze_hlo(jax.jit(f).lower(stack).compile().as_text())
    slice_bytes = 256 * 256 * 4
    assert r.bytes < 100 * 10 * slice_bytes  # far below whole-stack charging


def test_costs_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    r = C.analyze_hlo(jax.jit(f).lower(a, b).compile().as_text())
    assert r.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


# ------------------------------------------------------------------- roofline
def test_roofline_terms_shape():
    rec = {"cost": {"flops": 1e12, "bytes_accessed": 1e12},
           "collectives": {"total": 1e9}, "chips": 256, "kind": "train",
           "meta": {"active_params": 1e9, "tokens_per_step": 1e6}}
    t = roofline_terms(rec)
    assert t["dominant"] == "memory"
    assert t["model_flops"] == 6e15
    assert 0 < t["roofline_fraction"] < 1


def test_model_flops_moe_uses_active():
    arch = get_arch("deepseek-v2-lite-16b")
    assert arch.lm.active_param_count() < 0.25 * arch.lm.param_count()


# --------------------------------------------- tiny-mesh sharded training step
def test_sharded_stgnn_step_matches_unsharded():
    """The production step program on a 1-device mesh == plain step."""
    from repro.launch.specs import build_stgnn_train
    from repro.configs import get_arch
    import dataclasses as dc

    arch = get_arch("pgt-dcrnn-pems-all-la")
    small_model = dc.replace(arch.model, num_nodes=12)
    arch = dc.replace(arch, model=small_model)
    mesh = _mesh1()
    prog = build_stgnn_train(arch, arch.shapes[0], mesh, series_len=200)
    # replace the ShapeDtypeStructs with real arrays
    rng = np.random.default_rng(0)

    def realize(x):
        if x.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, 150, size=x.shape).astype(np.int32))
        return jnp.asarray(rng.standard_normal(x.shape).astype(np.float32) * 0.1)

    args = jax.tree.map(realize, prog.args,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    with mesh:
        step = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                       out_shardings=prog.out_shardings)
        state, loss = step(*args)
    assert np.isfinite(float(loss))


def test_all_cells_enumerates_40():
    from repro.launch.specs import all_cells

    cells = list(all_cells())
    lm_cells = [c for c in cells if get_arch(c[0]).family != "stgnn"]
    assert len(lm_cells) == 40
    skips = [c for c in lm_cells if c[2]]
    assert len(skips) == 7  # pure full-attention archs skip long_500k
    assert all(s[1] == "long_500k" for s in skips)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="collectives need a >1-device mesh "
                           "(run with XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_halo_evidence_communication_free():
    """Dry-run evidence for the PipelineConfig.halo knob: the halo=False
    (shard-local) lowering of the PARTITIONED step moves ZERO data-collective
    bytes — only the gradient all-reduce — while halo=True's global-index
    lowering all-gathers the resident series."""
    from repro.launch.dryrun import partitioned_halo_evidence

    rec = partitioned_halo_evidence(make_host_mesh())
    assert rec["halo_false"]["data_bytes"] == 0
    assert rec["halo_false"]["all-reduce"] > 0  # grads still reduce
    assert rec["halo_true"]["data_bytes"] > 0
    assert rec["halo_true"]["counts"]["all-gather"] >= 1
