"""The perf-trend comparator (``benchmarks/trend.py``) on hand-built pairs:
direction-aware regressions, percentage-POINT semantics for the table3
overhead (whose baseline can be negative — a relative ratio would be
garbage), missing-field tolerance, and the CLI's exit-code contract."""
import json

import pytest

from benchmarks.trend import HEADLINE_FIELDS, compare_headlines


BASE = {
    "tokens_per_s": 1_000_000.0,
    "gather_dense_us": 3000.0,
    "gather_pallas_interpret_us": 4500.0,
    "gather_auto_us": 2900.0,
    "step_overhead_vs_base_pct": -4.0,
    "step_overlap_pct": 20.0,
    "prefetch_step_us": 550.0,
    "peak_rss_bytes": 450_000_000,
}

# The bench-kernels (BENCH_kernels.json) headline: same gate table, other
# artifact kind.
KBASE = {
    "gather_auto_us": 12.0,
    "gather_slice_us": 15.0,
    "window_gather_auto_us": 10.0,
    "linear_scan_auto_us": 130.0,
    "flash_attention_auto_us": 2900.0,
    "diffusion_conv_auto_us": 155.0,
}

# The bench-serve (BENCH_serve.json) headline: Poisson latencies + bursty
# throughput.  Occupancy rides the record but is NOT gated (utilization
# diagnostic), so it stays out of the gate table on purpose.
SBASE = {
    "serve_p50_ms": 6.7,
    "serve_p99_ms": 9.5,
    "serve_tokens_s": 350.0,
    # PR 9 paged-KV arm: resident pool bytes (lower) and requests admitted
    # inside the contiguous byte budget (higher), both ratio-gated.
    "serve_cache_bytes": 73728.0,
    "serve_admitted_at_saturation": 16.0,
    # PR 10 sampled-decode arm (determinism is asserted in the bench
    # itself; the gate only watches throughput).
    "serve_sampled_tokens_s": 2767.0,
}


def _verdicts(prev, cur, **kw):
    return {r["field"]: r["verdict"] for r in compare_headlines(prev, cur, **kw)}


def test_identical_points_all_ok():
    assert set(_verdicts(BASE, BASE).values()) == {"ok"}


def test_improvements_never_flag():
    cur = dict(BASE, tokens_per_s=2_000_000.0, gather_dense_us=1500.0,
               step_overhead_vs_base_pct=-8.0, peak_rss_bytes=300_000_000)
    v = _verdicts(BASE, cur)
    assert set(v.values()) == {"ok"}
    regs = {r["field"]: r["regression"] for r in compare_headlines(BASE, cur)}
    assert regs["tokens_per_s"] < 0  # improvements are NEGATIVE regressions


def test_direction_awareness():
    """tokens/s is higher-better, the rest lower-better: the same 30% move
    flags on the correct side of each."""
    v = _verdicts(BASE, dict(BASE, tokens_per_s=700_000.0))
    assert v["tokens_per_s"] == "fail"
    v = _verdicts(BASE, dict(BASE, gather_dense_us=3000.0 * 1.3))
    assert v["gather_dense_us"] == "fail"
    # the same magnitude in the GOOD direction is ok
    v = _verdicts(BASE, dict(BASE, gather_dense_us=3000.0 * 0.7))
    assert v["gather_dense_us"] == "ok"


def test_warn_band_between_10_and_25_pct():
    v = _verdicts(BASE, dict(BASE, tokens_per_s=1_000_000.0 * 0.85))  # -15%
    assert v["tokens_per_s"] == "warn"
    v = _verdicts(BASE, dict(BASE, peak_rss_bytes=450_000_000 * 1.12))
    assert v["peak_rss_bytes"] == "warn"
    # thresholds are configurable
    v = _verdicts(BASE, dict(BASE, tokens_per_s=1_000_000.0 * 0.85),
                  warn=0.20, fail=0.5)
    assert v["tokens_per_s"] == "ok"


def test_overhead_pct_compares_in_points_not_ratio():
    """-4% -> +8% overhead is a 12-POINT slide (warn), not a -300% ratio;
    -4% -> +30% is 34 points (fail).  A ratio against the negative baseline
    would invert the sign and read the regression as an improvement."""
    v = _verdicts(BASE, dict(BASE, step_overhead_vs_base_pct=8.0))
    assert v["step_overhead_vs_base_pct"] == "warn"
    v = _verdicts(BASE, dict(BASE, step_overhead_vs_base_pct=30.0))
    assert v["step_overhead_vs_base_pct"] == "fail"
    v = _verdicts(BASE, dict(BASE, step_overhead_vs_base_pct=-2.0))
    assert v["step_overhead_vs_base_pct"] == "ok"


def test_prefetch_fields_direction_and_kind():
    """The pipeline's overlap is higher-better in percentage POINTS (it can
    legitimately sit near zero — or negative — on a loaded runner, where a
    ratio would explode); the pipelined step time is an ordinary
    lower-better latency ratio."""
    v = _verdicts(BASE, dict(BASE, step_overlap_pct=8.0))   # -12 points
    assert v["step_overlap_pct"] == "warn"
    v = _verdicts(BASE, dict(BASE, step_overlap_pct=-10.0))  # -30 points
    assert v["step_overlap_pct"] == "fail"
    v = _verdicts(BASE, dict(BASE, step_overlap_pct=35.0))   # improvement
    assert v["step_overlap_pct"] == "ok"
    v = _verdicts(BASE, dict(BASE, prefetch_step_us=550.0 * 1.3))
    assert v["prefetch_step_us"] == "fail"
    v = _verdicts(BASE, dict(BASE, prefetch_step_us=550.0 * 0.7))
    assert v["prefetch_step_us"] == "ok"


def test_missing_and_nonpositive_fields_never_fail():
    prev = dict(BASE)
    del prev["gather_pallas_interpret_us"]          # schema drift: old point
    prev["tokens_per_s"] = 0.0                      # broken old record
    v = _verdicts(prev, BASE)
    assert v["gather_pallas_interpret_us"] == "missing"
    assert v["tokens_per_s"] == "missing"
    assert all(verdict != "fail" for verdict in v.values())


def test_every_headline_field_is_covered():
    """One gate table spans BOTH artifact kinds; a field present in neither
    record (it belongs to the other kind) emits no row at all, so a
    bench-smoke pair is never polluted by bench-kernels 'missing' rows."""
    assert set(HEADLINE_FIELDS) == set(BASE) | set(KBASE) | set(SBASE)
    assert len(compare_headlines(BASE, BASE)) == len(BASE)
    assert len(compare_headlines(KBASE, KBASE)) == len(KBASE)
    assert len(compare_headlines(SBASE, SBASE)) == len(SBASE)
    assert set(_verdicts(KBASE, KBASE).values()) == {"ok"}
    v = _verdicts(KBASE, dict(KBASE, gather_auto_us=12.0 * 1.3))
    assert v["gather_auto_us"] == "fail"


def test_serve_fields_direction_aware():
    """Latency DOWN is good — only the rise flags; throughput the reverse."""
    v = _verdicts(SBASE, dict(SBASE, serve_p50_ms=6.7 * 0.7,
                              serve_p99_ms=9.5 * 0.7))
    assert v["serve_p50_ms"] == "ok" and v["serve_p99_ms"] == "ok"
    v = _verdicts(SBASE, dict(SBASE, serve_p99_ms=9.5 * 1.3))
    assert v["serve_p99_ms"] == "fail"
    v = _verdicts(SBASE, dict(SBASE, serve_tokens_s=350.0 * 0.7))
    assert v["serve_tokens_s"] == "fail"
    v = _verdicts(SBASE, dict(SBASE, serve_tokens_s=350.0 * 1.3))
    assert v["serve_tokens_s"] == "ok"
    v = _verdicts(SBASE, dict(SBASE, serve_sampled_tokens_s=2767.0 * 0.7))
    assert v["serve_sampled_tokens_s"] == "fail"
    v = _verdicts(SBASE, dict(SBASE, serve_sampled_tokens_s=2767.0 * 1.3))
    assert v["serve_sampled_tokens_s"] == "ok"


# --------------------------------------------------------------- CLI contract
def _write(path, headline):
    with open(path, "w") as f:
        json.dump({"schema": 1, "headline": headline}, f)
    return str(path)


def test_cli_exit_codes(tmp_path, capsys):
    from benchmarks.trend import main

    prev = _write(tmp_path / "prev.json", BASE)
    ok = _write(tmp_path / "ok.json", dict(BASE, tokens_per_s=990_000.0))
    bad = _write(tmp_path / "bad.json", dict(BASE, tokens_per_s=500_000.0))

    main(["--prev", prev, "--cur", ok])             # no regression: returns
    assert "ok" in capsys.readouterr().out
    with pytest.raises(SystemExit) as e:
        main(["--prev", prev, "--cur", bad])        # -50% tokens/s: fails
    assert e.value.code == 1
    assert "::error::" in capsys.readouterr().out

    with pytest.raises(SystemExit):                 # not a bench record
        main(["--prev", _write(tmp_path / "junk.json", None), "--cur", ok])
