"""Regression coverage for the §Perf opt-in knobs: they must keep producing
valid programs/shardings and numerically-identical math where claimed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS, get_arch
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.lm import model as lm
from repro.models.lm.config import MoEConfig
from repro.models.lm.moe import init_moe, moe_ffn

KEY = jax.random.PRNGKey(0)


def test_tp_rules_disabled_no_model_axis():
    """mode2d: with tp_rules off no param spec may reference 'model'."""
    cfg = get_arch("qwen1.5-4b").lm
    import numpy as _np
    from jax.sharding import Mesh

    mesh = Mesh(_np.array(jax.devices() * 16)[:16].reshape(4, 4),
                ("data", "model"))
    for path, shape in [("lm_head/w", (2560, 151936)),
                        ("stages/0/sub0/attn/wq/w", (40, 2560, 2560)),
                        ("stages/0/sub0/mlp/wi/w", (40, 2560, 6912)),
                        ("embed", (151936, 2560))]:
        spec = shd.lm_param_spec(path, shape, cfg, mesh, tp_rules=False,
                                 fsdp=("data", "model"))
        flat = [a for e in spec if e for a in (e if isinstance(e, tuple) else (e,))]
        # FSDP may use model as a *data-like* axis, but never two dims
        assert len(flat) == len(set(flat))


def test_grouped_moe_matches_global_exact():
    moe = MoEConfig(n_experts=8, top_k=2, capacity_factor=8.0, n_shared=2)
    p = init_moe(KEY, 64, moe, 128, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 64))
    y1, a1 = moe_ffn(p, x, moe, "swiglu")
    for g in (2, 4, 8):
        y2, a2 = moe_ffn(p, x, moe, "swiglu", groups=g)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5,
                                   err_msg=f"groups={g}")
        assert abs(float(a1) - float(a2)) < 1e-6


def test_grouped_moe_grad_flows():
    moe = MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)
    p = init_moe(KEY, 32, moe, 64, "swiglu")
    x = jax.random.normal(KEY, (2, 16, 32))

    def loss(p):
        y, aux = moe_ffn(p, x, moe, "swiglu", groups=4)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))


@pytest.mark.parametrize("q_chunk,kv_chunk", [(512, 512), (1024, 4096)])
def test_attention_chunk_config_equivalence(q_chunk, kv_chunk):
    """The §Perf chunk knobs change tiling, never values."""
    cfg = dataclasses.replace(LM_ARCHS["minitron-8b"].smoke_config(),
                              max_seq_len=4096)
    cfg_t = dataclasses.replace(cfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
    params = lm.init(KEY, cfg)
    # force the blockwise path: seq > BLOCKWISE_THRESHOLD
    toks = jax.random.randint(KEY, (1, 4096), 0, cfg.vocab)
    l1, _ = lm.loss_fn(params, cfg, toks, toks)
    l2, _ = lm.loss_fn(params, cfg_t, toks, toks)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_mode2d_program_runs_on_host_mesh():
    """mode2d cell program lowers + executes on a small host mesh."""
    import dataclasses as dc

    from repro.launch.specs import build_lm_train
    from repro.configs.base import ShapeCell
    from repro.launch.mesh import shrink_mesh

    arch = get_arch("minitron-8b")
    arch = dc.replace(arch, lm=arch.smoke_config())
    cell = ShapeCell("tiny", "train", 64, 2)
    # cap the data axis at the global batch (2) so the batch stays divisible
    # on forced multi-device hosts (the CI mesh-8 leg)
    mesh = shrink_mesh(make_host_mesh(model=1), cell.global_batch)
    prog = build_lm_train(arch, cell, mesh, mode2d=True, microbatches=1)
    rng = np.random.default_rng(0)

    def realize(x):
        if np.issubdtype(x.dtype, np.integer):
            hi = 100 if x.shape == () or x.ndim <= 1 else 100
            return jnp.asarray(rng.integers(0, hi, size=x.shape).astype(x.dtype))
        return jnp.asarray((rng.standard_normal(x.shape) * 0.02).astype(x.dtype))

    args = jax.tree.map(realize, prog.args,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    with mesh:
        step = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                       out_shardings=prog.out_shardings)
        state, loss = step(*args)
    assert np.isfinite(float(loss))
