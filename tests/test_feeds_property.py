"""Property-based pin of the per-rank feed contract (ISSUE 3 satellite):

for EVERY sampler × placement × world size,
``concat([feed(r, epoch) for r in range(world)], axis=1)`` reassembles
exactly to ``epoch_global(epoch)`` — the invariant the whole multi-process
data plane stands on (a real fleet iterates feed columns, the single-host
simulation iterates ``epoch_global``; tests/multihost.py proves the two
trajectories bit-identical END to end, this file proves the index grids
identical at the SOURCE for the whole parameter space, not just the
hand-picked cases in test_pipeline.py).

Plus the EVAL mirror of the contract (ISSUE 4 satellite): for every sampler
× placement × world × pool size, the ``eval_feed(rank)`` column blocks plus
the ragged ``eval_tail`` reproduce the global eval pool EXACTLY ONCE — no
window dropped, none double-counted, pool order preserved.

Runs under real hypothesis when installed, else under the seeded-example
fallback from conftest.py.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.sampler import (GlobalShuffleSampler, LocalBatchShuffleSampler,
                                ShardInfo, local_shuffle_sampler)
from repro.core.windows import WindowSpec
from repro.pipeline.samplers import ShardAlignedBatchSampler

SAMPLERS = ["global", "local-batch", "local-shuffle", "shard-aligned"]


def _build(kind: str, world: int, batch: int, seed: int, halo: bool):
    """A valid sampler of ``kind``: sized so every rank owns ≥ 1 batch."""
    if kind == "shard-aligned":
        spec = WindowSpec(horizon=1, input_len=2)  # span 3
        entries = world * (batch + spec.span + 2)
        train = np.arange(entries - spec.span + 1, dtype=np.int32)
        return ShardAlignedBatchSampler(entries, spec, train, batch, world,
                                        seed=seed, halo=halo)
    ids = np.arange(world * batch * 3 + 5, dtype=np.int32)
    shard = ShardInfo(0, world)
    if kind == "global":
        return GlobalShuffleSampler(ids, batch, shard, seed=seed)
    if kind == "local-batch":
        return LocalBatchShuffleSampler(ids, batch, shard, seed=seed)
    return local_shuffle_sampler(ids, batch, shard, seed=seed)


@settings(max_examples=60, deadline=None)
@given(kind=st.sampled_from(SAMPLERS),
       world=st.integers(1, 6),
       batch=st.integers(1, 4),
       seed=st.integers(0, 2**16),
       epoch=st.integers(0, 7),
       halo=st.sampled_from([True, False]))
def test_feed_columns_reassemble_epoch_global(kind, world, batch, seed,
                                              epoch, halo):
    s = _build(kind, world, batch, seed, halo)
    cols = np.concatenate([s.feed(r, epoch) for r in range(world)], axis=1)
    grid = s.epoch_global(epoch)
    assert grid.shape == (s.steps_per_epoch, world * batch)
    assert np.array_equal(cols, grid)
    # the feed is a pure function of (seed, epoch, rank): re-derive and match
    assert all(np.array_equal(s.feed(r, epoch), _build(
        kind, world, batch, seed, halo).feed(r, epoch)) for r in range(world))
    # rank r's feed is column block r of the global grid (rank-major)
    blocks = grid.reshape(s.steps_per_epoch, world, batch)
    for r in range(world):
        assert np.array_equal(blocks[:, r, :], s.feed(r, epoch))


@settings(max_examples=60, deadline=None)
@given(kind=st.sampled_from(SAMPLERS),
       world=st.integers(1, 6),
       batch=st.integers(1, 4),
       seed=st.integers(0, 2**16),
       epoch=st.integers(0, 7),
       start=st.integers(0, 5),
       chunk=st.integers(1, 9),
       halo=st.sampled_from([True, False]))
def test_feed_stream_chunks_reassemble_feed(kind, world, batch, seed, epoch,
                                            start, chunk, halo):
    """The prefetch pipeline's source contract (ISSUE 6): for every sampler
    × world × (start, chunk), ``feed_stream(rank, epoch)`` yields row blocks
    that concatenate EXACTLY to ``feed(rank, epoch)[start:]`` — so early
    materialization on the prefetch thread can never feed different window
    ids than the lockstep path, for any chunking or mid-epoch resume."""
    s = _build(kind, world, batch, seed, halo)
    for r in range(world):
        feed = s.feed(r, epoch)
        blocks = list(s.feed_stream(r, epoch, start=start, chunk=chunk))
        assert all(b.shape[1] == batch and b.shape[0] <= chunk
                   for b in blocks)
        rows = (np.concatenate(blocks) if blocks
                else np.empty((0, batch), feed.dtype))
        assert np.array_equal(rows, feed[start:])


@settings(max_examples=60, deadline=None)
@given(kind=st.sampled_from(SAMPLERS),
       world=st.integers(1, 6),
       batch=st.integers(1, 4),
       seed=st.integers(0, 2**16),
       pool_n=st.integers(0, 40),
       halo=st.sampled_from([True, False]))
def test_eval_feed_columns_reproduce_pool_exactly_once(kind, world, batch,
                                                       seed, pool_n, halo):
    """concat([eval_feed(r, pool) for r]).ravel() ++ eval_tail(pool) == pool:
    the eval pool is covered exactly once, rank-major, in pool order — the
    invariant distributed evaluate() stands on."""
    s = _build(kind, world, batch, seed, halo)
    # distinct, non-contiguous ids so reassembly errors cannot alias
    pool = (7 * np.arange(pool_n, dtype=np.int32) + 3)
    steps = pool_n // (world * batch)
    cols = np.concatenate([s.eval_feed(r, pool) for r in range(world)], axis=1)
    tail = s.eval_tail(pool)
    assert cols.shape == (steps, world * batch)
    assert len(tail) == pool_n - steps * world * batch
    assert np.array_equal(np.concatenate([cols.ravel(), tail]), pool)
    # eval_global is exactly the full-chunk view of the same columns
    assert np.array_equal(s.eval_global(pool), cols)
    # rank r's eval feed is column block r of each full chunk (rank-major),
    # and it is a pure function of the pool — no epoch, no shuffle
    grid = pool[:steps * world * batch].reshape(steps, world, batch)
    for r in range(world):
        assert np.array_equal(s.eval_feed(r, pool), grid[:, r, :])
        assert np.array_equal(s.eval_feed(r, pool),
                              _build(kind, world, batch, seed,
                                     halo).eval_feed(r, pool))


@settings(max_examples=25, deadline=None)
@given(placement_i=st.integers(0, 2),
       world=st.integers(1, 5),
       batch=st.integers(1, 3),
       split=st.sampled_from(["val", "test"]),
       seed=st.integers(0, 999))
def test_dataplane_eval_feeds_cover_split_for_every_placement(placement_i,
                                                              world, batch,
                                                              split, seed):
    """One layer up: whatever sampler ``build_dataplane`` instantiates for a
    placement, its eval feeds + tail must cover the split pool exactly once,
    and the single-process ``eval_grid`` must be their assembly."""
    from repro.core import Placement
    from repro.data import make_traffic_series
    from repro.launch.mesh import make_host_mesh
    from repro.pipeline import PipelineConfig, build_dataplane

    placement = list(Placement)[placement_i]
    dp = build_dataplane(
        make_traffic_series(120, 2), WindowSpec(horizon=2, input_len=2),
        make_host_mesh(),
        PipelineConfig(batch_per_rank=batch, placement=placement,
                       world=world, seed=seed))
    pool = dp.eval_pool(split)
    cols = np.concatenate([dp.eval_feed(r, split) for r in range(world)],
                          axis=1)
    tail = dp.eval_tail(split)
    assert np.array_equal(np.concatenate([cols.ravel(), tail]), pool)
    rows, grid_tail = dp.eval_grid(split)
    assert np.array_equal(rows, cols)
    assert np.array_equal(grid_tail, tail)


@settings(max_examples=25, deadline=None)
@given(placement_i=st.integers(0, 2),
       world=st.integers(1, 5),
       batch=st.integers(1, 3),
       epoch=st.integers(0, 3),
       seed=st.integers(0, 999))
def test_dataplane_feeds_reassemble_for_every_placement(placement_i, world,
                                                        batch, epoch, seed):
    """Same invariant one layer up: whatever sampler ``build_dataplane``
    instantiates for a placement (including the aligned→count-split
    fallback), the per-rank feeds must still reassemble its epoch grid."""
    from repro.core import Placement
    from repro.data import make_traffic_series
    from repro.launch.mesh import make_host_mesh
    from repro.pipeline import PipelineConfig, build_dataplane

    placement = list(Placement)[placement_i]
    dp = build_dataplane(
        make_traffic_series(120, 2), WindowSpec(horizon=2, input_len=2),
        make_host_mesh(),
        PipelineConfig(batch_per_rank=batch, placement=placement,
                       world=world, seed=seed))
    cols = np.concatenate([dp.feed(r, epoch) for r in range(world)], axis=1)
    assert np.array_equal(cols, dp.epoch_global(epoch))
    # single-process epoch_grid IS the global grid
    assert np.array_equal(dp.epoch_grid(epoch), dp.epoch_global(epoch))


@settings(max_examples=25, deadline=None)
@given(placement_i=st.integers(0, 2),
       world=st.integers(1, 5),
       batch=st.integers(1, 3),
       epoch=st.integers(0, 3),
       start=st.integers(0, 4),
       chunk=st.integers(1, 9),
       seed=st.integers(0, 999))
def test_dataplane_grid_stream_reassembles_for_every_placement(placement_i,
                                                               world, batch,
                                                               epoch, start,
                                                               chunk, seed):
    """The stream the prefetcher actually drains: for every placement the
    data plane supports, ``grid_stream(epoch, start=, chunk=)`` blocks must
    reassemble to ``epoch_grid(epoch)[start:]`` — the same rows the
    synchronous step loop would index, from any mid-epoch resume point."""
    from repro.core import Placement
    from repro.data import make_traffic_series
    from repro.launch.mesh import make_host_mesh
    from repro.pipeline import PipelineConfig, build_dataplane

    placement = list(Placement)[placement_i]
    dp = build_dataplane(
        make_traffic_series(120, 2), WindowSpec(horizon=2, input_len=2),
        make_host_mesh(),
        PipelineConfig(batch_per_rank=batch, placement=placement,
                       world=world, seed=seed))
    grid = dp.epoch_grid(epoch)
    blocks = list(dp.grid_stream(epoch, start=start, chunk=chunk))
    rows = (np.concatenate(blocks) if blocks
            else np.empty((0, grid.shape[1]), grid.dtype))
    assert np.array_equal(rows, grid[start:])
