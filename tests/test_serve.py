"""Serving engine: retirement regressions, fleet equivalence, router policy.

Pins the PR-8 contracts:
- prefill-time retirement (budget-1 / EOS-at-prefill) on BOTH the reference
  ``Server`` and the ``ServeEngine``,
- submit validation (explicit ``max_new_tokens=0`` rejected, over-long
  prompts rejected — never silently corrupting a lane's cache slice),
- the one-device-pull-per-decode-step contract via the transfer-counting
  shim (``repro.serve.common.count_transfers``),
- greedy fleet output bit-identical to the single-host Server (which
  ``test_train_serve.py`` pins to manual decode),
- router backpressure + deadlines, batched-prefill grouping,
- the sharded slot pool on a forced multi-device host mesh (the CI mesh-8
  leg runs this file with ``--xla_force_host_platform_device_count=8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS
from repro.models.lm import model as lm
from repro.serve import (Backpressure, Router, ServeConfig, ServeEngine,
                         Server, count_transfers)


@pytest.fixture(scope="module")
def lm_setup():
    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _prompts(n, rng, lo=2, hi=10):
    return [rng.integers(0, 120, size=int(rng.integers(lo, hi))) for _ in range(n)]


def _first_greedy_token(params, cfg, prompt, max_len=48) -> int:
    cache = lm.init_cache(cfg, 1, max_len)
    logits, _, _ = lm.prefill(params, cfg, jnp.asarray(np.asarray(prompt)[None]),
                              cache)
    return int(jnp.argmax(logits, -1)[0])


# ------------------------------------------------- prefill-time retirement
@pytest.mark.parametrize("impl", ["server", "engine"])
def test_budget_one_returns_exactly_one_token(lm_setup, impl):
    """Regression: max_new_tokens=1 used to return TWO tokens (the prefill
    token never counted against the budget before the first decode)."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4)
    srv = (Server(params, cfg, sc) if impl == "server"
           else ServeEngine(params, cfg, sc))
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    rid = srv.submit(prompt, max_new_tokens=1)
    out = srv.run()
    assert out[rid] == [_first_greedy_token(params, cfg, prompt)]


@pytest.mark.parametrize("impl", ["server", "engine"])
def test_eos_at_prefill_stops_immediately(lm_setup, impl):
    """Regression: a prompt whose FIRST sampled token is eos_id used to keep
    decoding past EOS (the prefill token was never EOS-checked)."""
    cfg, params = lm_setup
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    eos = _first_greedy_token(params, cfg, prompt)
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=6, eos_id=eos)
    srv = (Server(params, cfg, sc) if impl == "server"
           else ServeEngine(params, cfg, sc))
    rid = srv.submit(prompt)
    out = srv.run()
    assert out[rid] == [eos]


def test_prefill_retired_slot_refills_same_step(lm_setup):
    """A request retired at prefill must not waste its slot: queued work
    behind it is admitted into the SAME lane within the same step."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=1, max_len=48, max_new_tokens=3)
    srv = Server(params, cfg, sc)
    p1, p2 = np.array([3, 1, 4], np.int32), np.array([1, 5, 9, 2], np.int32)
    r1 = srv.submit(p1, max_new_tokens=1)  # retires at prefill
    r2 = srv.submit(p2)
    srv.step()
    assert r1 in srv.done  # never occupied the lane
    assert srv.active[0] is not None and srv.active[0].rid == r2
    out = srv.run()
    assert len(out[r1]) == 1 and len(out[r2]) == 3


# ----------------------------------------------------------- submit contract
@pytest.mark.parametrize("impl", ["server", "engine"])
def test_submit_validation(lm_setup, impl):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=32, max_new_tokens=5)
    srv = (Server(params, cfg, sc) if impl == "server"
           else ServeEngine(params, cfg, sc))
    prompt = np.array([3, 1, 4], np.int32)
    # explicit 0 is NOT "use the default" — there is nothing to generate
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit(prompt, max_new_tokens=0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit(prompt, max_new_tokens=-2)
    # an over-long prompt must be rejected, not corrupt the lane's cache
    with pytest.raises(ValueError, match="max_len"):
        srv.submit(np.arange(31, dtype=np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="prompt"):
        srv.submit(np.zeros((0,), np.int32))
    # None still means the config default
    rid = srv.submit(prompt)
    assert len(srv.run()[rid]) == 5


def test_full_cache_boundary_exact_fit(lm_setup):
    """plen + budget == max_len is the tightest legal request: it must
    complete with exactly ``budget`` tokens (no early 'cache full' retire,
    no overrun)."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=1, max_len=16, max_new_tokens=4)
    for impl in ("server", "engine"):
        srv = (Server(params, cfg, sc) if impl == "server"
               else ServeEngine(params, cfg, sc))
        rid = srv.submit(np.arange(1, 13, dtype=np.int32), max_new_tokens=4)
        assert len(srv.run()[rid]) == 4, impl


# ----------------------------------------------------------- sync discipline
def test_server_one_pull_per_decode_step(lm_setup):
    """O(slots) blocking syncs per step was the decode hot path's bug: with
    every lane live, a step must cost exactly ONE device→host pull."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=4, max_len=48, max_new_tokens=8)
    srv = Server(params, cfg, sc)
    rng = np.random.default_rng(0)
    for p in _prompts(4, rng):
        srv.submit(p)
    with count_transfers() as c:
        srv.step()  # 4 single-lane prefills + 1 decode
    assert c["pulls"] == 5
    with count_transfers() as c:
        srv.step()  # steady state: all lanes live
    assert c["pulls"] == 1


def test_engine_batched_prefill_single_pull(lm_setup):
    """The engine's batched prefill collapses k same-length fills into ONE
    forward + ONE pull (vs the server's k)."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=4, max_len=48, max_new_tokens=8)
    eng = ServeEngine(params, cfg, sc)
    for _ in range(4):
        eng.submit(np.array([3, 1, 4, 1, 5], np.int32))
    with count_transfers() as c:
        eng.step()  # 1 batched prefill + 1 decode
    assert c["pulls"] == 2
    with count_transfers() as c:
        eng.step()
    assert c["pulls"] == 1


# -------------------------------------------------------- fleet equivalence
def test_fleet_greedy_bit_identical_to_server(lm_setup):
    """2-plane engine (batched prefill, sharded pool, different admission
    order) must generate EXACTLY what the reference server generates for
    every request — grouping/placement can change when, never what."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=5, eos_id=7)
    rng = np.random.default_rng(3)
    prompts = _prompts(9, rng)

    srv = Server(params, cfg, sc)
    for p in prompts:
        srv.submit(p)
    ref = srv.run()

    eng = ServeEngine(params, cfg, sc, planes=2)
    rids = [eng.submit(p) for p in prompts]
    got = eng.run()
    for i, rid in enumerate(rids):
        assert got[rid] == ref[i], f"request {i} diverged"


def test_engine_temperature_sampling_runs(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4, temperature=0.8)
    eng = ServeEngine(params, cfg, sc, seed=7)
    rng = np.random.default_rng(1)
    rids = [eng.submit(p) for p in _prompts(3, rng)]
    out = eng.run()
    assert all(len(out[r]) == 4 for r in rids)
    assert all(0 <= t < cfg.padded_vocab for r in rids for t in out[r])


def test_fleet_sampled_bit_identical_to_server(lm_setup):
    """The tentpole contract: at temperature > 0 the keyed draws depend only
    on (seed, rid, position), so 1-plane, 2-plane and PAGED engines all
    generate exactly what the reference server generates for the same
    per-request seeds — plane count and cache layout change nothing."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=5)
    rng = np.random.default_rng(3)
    prompts = _prompts(9, rng)

    srv = Server(params, cfg, sc)
    for i, p in enumerate(prompts):
        srv.submit(p, temperature=0.9, seed=100 + i)
    ref = srv.run()
    greedy = _reference(params, cfg, sc, prompts)
    assert any(ref[i] != greedy[i] for i in range(len(prompts))), \
        "sampled run reproduced greedy everywhere — sampling inert?"

    paged = ServeConfig(slots=2, max_len=48, max_new_tokens=5, block_size=8)
    for planes, cfg_e in ((1, sc), (2, sc), (1, paged)):
        eng = ServeEngine(params, cfg, cfg_e, planes=planes, queue_limit=64)
        rids = [eng.submit(p, temperature=0.9, seed=100 + i)
                for i, p in enumerate(prompts)]
        got = eng.run()
        for i, rid in enumerate(rids):
            assert got[rid] == ref[i], \
                f"request {i} diverged (planes={planes}, paged={cfg_e.block_size})"


def test_sampled_one_pull_per_decode_step(lm_setup):
    """Moving sampling inside the jit must not add device→host syncs: a
    sampled steady-state step still costs exactly ONE pull."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=4, max_len=48, max_new_tokens=8, temperature=0.7)
    eng = ServeEngine(params, cfg, sc)
    for _ in range(4):
        eng.submit(np.array([3, 1, 4, 1, 5], np.int32))
    with count_transfers() as c:
        eng.step()  # 1 batched prefill + 1 decode
    assert c["pulls"] == 2
    with count_transfers() as c:
        eng.step()
    assert c["pulls"] == 1


def test_top_k_top_p_filters_run_and_stay_keyed(lm_setup):
    """Filtered sampling produces full-length outputs and stays
    deterministic across engines (same seeds → same tokens)."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4)
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8, 2], np.int32)]
    outs = []
    for _ in range(2):
        eng = ServeEngine(params, cfg, sc)
        rids = [eng.submit(p, temperature=1.2, seed=5 + i, top_k=20,
                           top_p=0.95) for i, p in enumerate(prompts)]
        got = eng.run()
        outs.append([got[r] for r in rids])
        assert all(len(o) == 4 for o in outs[-1])
    assert outs[0] == outs[1]


def test_negative_temperature_rejected(lm_setup):
    """Regression: temperature < 0 silently decoded greedy.  It is now
    rejected at config construction AND at submit-time override."""
    cfg, params = lm_setup
    with pytest.raises(ValueError, match="temperature"):
        ServeConfig(slots=2, max_len=48, temperature=-0.5)
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4)
    eng = ServeEngine(params, cfg, sc)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.array([3, 1, 4], np.int32), temperature=-1.0)
    with pytest.raises(ValueError, match="top_p"):
        eng.submit(np.array([3, 1, 4], np.int32), top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(np.array([3, 1, 4], np.int32), top_k=-3)


def test_latency_none_until_terminal_and_truncated_status(lm_setup):
    """Regression pair: ``latency_s`` used to go NEGATIVE on unfinished
    requests (0.0 - submitted_at); a lane retired because its cache filled
    before the budget was spent used to report ``"ok"``."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=1, max_len=16, max_new_tokens=4)
    eng = ServeEngine(params, cfg, sc)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    req = eng.router.queue[0]
    assert req.latency_s is None  # queued
    eng.step()
    assert req.status == "active" and req.latency_s is None
    # simulate a budget the lane's cache cannot hold (submit validation
    # rejects such requests, so the engine branch is defensive — but it must
    # label the cut-off honestly, not "ok")
    req.budget = 100
    eng.run()
    done = eng.router.done[rid]
    assert done.status == "truncated"
    assert done.latency_s is not None and done.latency_s >= 0.0
    assert 0 < len(done.out) < 100


# ------------------------------------------------------------- router policy
def test_router_backpressure_when_queue_outruns_slots(lm_setup):
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=32, max_new_tokens=3)
    eng = ServeEngine(params, cfg, sc, queue_limit=3)
    prompt = np.array([3, 1, 4], np.int32)
    for _ in range(3):
        eng.submit(prompt)
    with pytest.raises(Backpressure, match="queue full"):
        eng.submit(prompt)
    # draining the queue re-opens admission
    eng.run()
    eng.submit(prompt)


def test_router_group_same_length_within_token_budget():
    sc = ServeConfig(slots=8, max_len=64, max_new_tokens=4)
    r = Router(sc, queue_limit=None)
    for plen in (5, 5, 3, 5, 3):
        r.submit(np.arange(1, plen + 1, dtype=np.int32))
    g = r.pop_group(8, token_budget=64)
    assert [q.prompt.size for q in g] == [5, 5, 5]  # same-length, FIFO-biased
    g2 = r.pop_group(8, token_budget=64)
    assert [q.prompt.size for q in g2] == [3, 3]
    assert not r.queue
    # the token budget caps the group — but the leader always ships
    for plen in (6, 6, 6):
        r.submit(np.arange(1, plen + 1, dtype=np.int32))
    g3 = r.pop_group(8, token_budget=12)
    assert len(g3) == 2
    g4 = r.pop_group(8, token_budget=1)  # smaller than one prompt: no deadlock
    assert len(g4) == 1


def test_router_pop_group_block_pairing_validated():
    """Regression: block_budget without block_cost crashed with a bare
    ``TypeError`` deep in the accounting loop — now a clear ValueError at
    call time, before any request is inspected."""
    sc = ServeConfig(slots=4, max_len=64, max_new_tokens=4)
    r = Router(sc, queue_limit=None)
    r.submit(np.arange(1, 6, dtype=np.int32))
    with pytest.raises(ValueError, match="block_budget and block_cost"):
        r.pop_group(4, token_budget=64, block_budget=8)
    with pytest.raises(ValueError, match="block_budget and block_cost"):
        r.pop_group(4, token_budget=64, block_cost=lambda req: 1)
    assert len(r.queue) == 1  # nothing consumed by the failed calls


def test_router_deadline_expires_queued_and_active(lm_setup):
    cfg, params = lm_setup
    now = [0.0]
    sc = ServeConfig(slots=1, max_len=32, max_new_tokens=8)
    eng = ServeEngine(params, cfg, sc, clock=lambda: now[0])
    fast = eng.submit(np.array([3, 1, 4], np.int32), deadline_s=5.0)
    slow = eng.submit(np.array([1, 5, 9], np.int32), deadline_s=0.5)
    eng.step()  # fast occupies the single lane; slow waits
    now[0] = 1.0  # slow's deadline passes while queued
    eng.step()
    assert eng.router.done[slow].status == "timeout"
    assert eng.router.done[slow].out == []
    now[0] = 6.0  # fast's deadline passes while ACTIVE: partial output
    eng.step()
    req = eng.router.done[fast]
    assert req.status == "timeout" and 0 < len(req.out) < 8
    assert eng.active_lanes() == 0


# --------------------------------------------------------------- cache utils
def test_scatter_cache_matches_per_slot_updates(lm_setup):
    cfg, params = lm_setup
    pool = lm.init_cache(cfg, 4, 16)
    rng = np.random.default_rng(0)
    sub = jax.tree.map(
        lambda a: jnp.asarray(rng.standard_normal((a.shape[0], 2) + a.shape[2:])
                              .astype(np.float32)).astype(a.dtype), pool)
    slots = [3, 1]
    got = lm.scatter_cache(pool, sub, slots)

    want = pool
    for i, s in enumerate(slots):
        want = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small[:, i:i + 1].astype(big.dtype), s, axis=1),
            want, sub)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- sharded pool
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs the forced 8-device host mesh (CI mesh-8)")
def test_sharded_pool_on_8_device_mesh(lm_setup):
    """The plane's slot pool really shards over the (data × model) mesh and
    the sharded fleet still matches the reference server bit-exactly."""
    from repro.launch.mesh import make_host_mesh

    cfg, params = lm_setup
    sc = ServeConfig(slots=8, max_len=48, max_new_tokens=4)
    rng = np.random.default_rng(5)
    prompts = _prompts(10, rng)

    srv = Server(params, cfg, sc)
    for p in prompts:
        srv.submit(p)
    ref = srv.run()

    mesh = make_host_mesh(model=2)  # (data=4, model=2)
    eng = ServeEngine(params, cfg, sc, mesh=mesh)
    rids = [eng.submit(p) for p in prompts]
    got = eng.run()
    for i, rid in enumerate(rids):
        assert got[rid] == ref[i]
    # proof of sharding: a kv-cache leaf spans more than one device
    leaf = jax.tree.leaves(eng.planes[0].cache)[0]
    assert len(leaf.sharding.device_set) > 1
    assert len({s.device for s in leaf.addressable_shards}) > 1


# ---------------------------------------------------------------- paged KV
def _reference(params, cfg, sc, prompts):
    srv = Server(params, cfg, sc)
    for p in prompts:
        srv.submit(p)
    return srv.run()


@pytest.mark.parametrize("block_size", [4, 5, 16])
@pytest.mark.parametrize("planes", [1, 2])
def test_paged_engine_bit_identical_to_server(lm_setup, block_size, planes):
    """Paged pool (incl. a block size that does NOT divide max_len) must
    generate EXACTLY what the contiguous reference server generates: the
    length mask zeroes the stale block tail, so gathering whole blocks can
    never change a logit."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=5, eos_id=7)
    rng = np.random.default_rng(3)
    prompts = _prompts(7, rng)
    ref = _reference(params, cfg, sc, prompts)

    eng = ServeEngine(params, cfg,
                      ServeConfig(slots=2, max_len=48, max_new_tokens=5,
                                  eos_id=7, block_size=block_size),
                      planes=planes)
    rids = [eng.submit(p) for p in prompts]
    got = eng.run()
    for i, rid in enumerate(rids):
        assert got[rid] == ref[i], f"request {i} diverged (bs={block_size})"


def test_paged_small_pool_bit_identical_and_smaller(lm_setup):
    """The memory claim: a pool sized to LIVE tokens (not slots x max_len)
    serves the same workload bit-identically with a measurably smaller
    resident KV cache than the contiguous plane."""
    from repro.serve import InferencePlane, PagedInferencePlane

    cfg, params = lm_setup
    base = dict(slots=4, max_len=48, max_new_tokens=6)
    sc = ServeConfig(**base, block_size=4, pool_blocks=16)  # 16*4=64 << 4*48
    rng = np.random.default_rng(11)
    prompts = _prompts(8, rng)
    ref = _reference(params, cfg, ServeConfig(**base), prompts)

    eng = ServeEngine(params, cfg, sc)
    rids = [eng.submit(p) for p in prompts]
    got = eng.run()
    for i, rid in enumerate(rids):
        assert got[rid] == ref[i]

    paged = eng.planes[0].cache_bytes()
    contiguous = InferencePlane(params, cfg, ServeConfig(**base)).cache_bytes()
    assert paged < contiguous, (paged, contiguous)
    assert isinstance(eng.planes[0], PagedInferencePlane)


def test_paged_never_fits_rejected_at_submit(lm_setup):
    """A request whose lifetime block need exceeds the whole pool can never
    run: ValueError at submit (waiting would deadlock the queue head)."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=20,
                     block_size=4, pool_blocks=3)
    eng = ServeEngine(params, cfg, sc)
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(np.arange(1, 9, dtype=np.int32))  # needs ceil(28/4)=7 > 3


def test_paged_pool_backpressure_defers_not_drops(lm_setup):
    """A pool with room for ~one request at a time still completes every
    admitted request: the router's block budget defers admission (head of
    line WAITS for retirements) — nothing is dropped, nothing OOMs."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=4, max_len=48, max_new_tokens=6,
                     block_size=4, pool_blocks=4)
    rng = np.random.default_rng(7)
    prompts = _prompts(5, rng, lo=2, hi=8)  # each needs <= 4 blocks
    ref = _reference(params, cfg,
                     ServeConfig(slots=4, max_len=48, max_new_tokens=6),
                     prompts)
    eng = ServeEngine(params, cfg, sc)
    rids = [eng.submit(p) for p in prompts]
    got = eng.run()
    for i, rid in enumerate(rids):
        assert got[rid] == ref[i]
    assert eng.planes[0].pool.available == 4  # all blocks returned


def test_paged_one_pull_per_decode_step(lm_setup):
    """The paged plane keeps the sync discipline: block tables are uploaded
    (host→device, free) but the step still costs ONE device→host pull."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=4, max_len=48, max_new_tokens=8, block_size=8)
    eng = ServeEngine(params, cfg, sc)
    for _ in range(4):
        eng.submit(np.array([3, 1, 4, 1, 5], np.int32))
    with count_transfers() as c:
        eng.step()  # 1 batched prefill + 1 decode
    assert c["pulls"] == 2
    with count_transfers() as c:
        eng.step()
    assert c["pulls"] == 1


def test_router_block_budget_caps_group():
    """pop_group with a block budget: the group's summed cost must fit; a
    leader that doesn't fit yields an EMPTY group and stays queued."""
    sc = ServeConfig(slots=8, max_len=64, max_new_tokens=4)
    r = Router(sc, queue_limit=None)
    for _ in range(3):
        r.submit(np.arange(1, 6, dtype=np.int32))  # plen 5, lifetime 9
    cost = lambda req: 3  # 3 blocks each
    g = r.pop_group(8, token_budget=64, block_budget=7, block_cost=cost)
    assert len(g) == 2  # third would need 9 > 7
    g2 = r.pop_group(8, token_budget=64, block_budget=2, block_cost=cost)
    assert g2 == [] and len(r.queue) == 1  # head-of-line waits, stays queued
    g3 = r.pop_group(8, token_budget=64, block_budget=3, block_cost=cost)
    assert len(g3) == 1 and not r.queue
