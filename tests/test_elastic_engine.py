"""Elastic shrink-and-resume, single-host fault injection (ISSUE 2 tentpole):

heartbeat flags a worker → ``plan_remesh`` shrinks the data axis →
the latest checkpoint restores into the new mesh → training resumes
deterministically from the same (seed, epoch, step), with the per-worker
batch re-scaled by ``scale_batch_or_steps``.

The fault is injected through :class:`ElasticConfig`'s two fakes — ``clock``
(a mutable list standing in for ``time.monotonic``) and ``step_feed`` (the
heartbeat transport, which simply stops reporting the "dead" rank while the
clock jumps past the timeout) — so the whole chain runs on one host with no
real worker loss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Placement, WindowSpec
from repro.core.distributed import dp_size
from repro.data import make_traffic_series
from repro.distributed import scale_batch_or_steps
from repro.launch.mesh import make_host_mesh, shrink_mesh
from repro.optim import AdamConfig
from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig

ENTRIES, NODES, HORIZON, B, WORLD = 120, 3, 2, 2, 4
SPEC = WindowSpec(horizon=HORIZON, input_len=HORIZON)
DEAD_RANK, DEAD_AT_STEP = 1, 3


def _params():
    return {"w": jnp.full((NODES, 2), 0.1, jnp.float32)}


def _loss_fn(p, x, y):
    pred = x[:, -1] * p["w"]
    return jnp.mean((pred - y[:, 0]) ** 2), {}


class OneDeadWorker:
    """step_feed fake: rank ``DEAD_RANK`` stops heartbeating at global step
    ``dead_after`` while the shared fake clock jumps past the heartbeat
    timeout, so the very next poll flags it DEAD.  After the re-mesh the
    world has shrunk and every surviving rank beats normally."""

    def __init__(self, clock, dead_after: int = DEAD_AT_STEP):
        self.clock = clock
        self.dead_after = dead_after

    def __call__(self, step: int, world: int) -> dict:
        self.clock[0] += 1.0
        beats = {r: (step, None) for r in range(world)}
        if world == WORLD and step >= self.dead_after:
            del beats[DEAD_RANK]
            self.clock[0] += 100.0  # fly past the 50 s timeout
        return beats


def _elastic_pipe(ckpt_dir: str, *, epochs: int = 2,
                  dead_after: int = DEAD_AT_STEP):
    clock = [0.0]
    elastic = ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                            step_feed=OneDeadWorker(clock, dead_after))
    return build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, _params(),
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=WORLD, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=epochs, log_every=1,
                                            ckpt_dir=ckpt_dir)),
        elastic=elastic)


def test_shrink_and_resume_full_chain(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    pipe = _elastic_pipe(ckpt_dir)
    old_global = pipe.global_batch
    state, history = pipe.fit(eval_fn=None)

    # 1. the heartbeat monitor flagged the dead worker and plan_remesh
    #    dropped exactly it, shrinking the data axis 4 -> 3
    assert len(pipe.restarts) == 1
    rec = pipe.restarts[0]
    assert rec["plan"].dropped_workers == (DEAD_RANK,)
    assert rec["plan"].mesh_shape == (WORLD - 1, 1)

    # 2. the engine re-scaled the per-worker batch per scale_batch_or_steps
    per, glob = scale_batch_or_steps(old_global, old_dp=WORLD,
                                     new_dp=WORLD - 1)
    assert pipe.world == WORLD - 1
    assert pipe.config.batch_per_rank == per
    assert pipe.global_batch == glob
    assert dp_size(pipe.mesh) == min(WORLD - 1, len(jax.devices()))

    # 3. resumed from the same (seed, epoch, step): the failure checkpoint
    #    carries (epoch 0, 3 steps done) and no epoch is lost or repeated
    assert rec["epoch"] == 0 and rec["step"] == DEAD_AT_STEP
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    # the global step counter stays MONOTONIC across the re-mesh, so the
    # newest checkpoint is always the highest-numbered one
    from repro.distributed import latest_step
    assert latest_step(ckpt_dir) == max(h["step"] for h in history)
    # the sampler seed is unchanged — the resumed epoch draws from the same
    # deterministic (seed, epoch) schedule at the new world size
    assert pipe.config.seed == 7
    assert jax.tree.leaves(state)  # training actually produced a state


def test_shrink_and_resume_is_deterministic(tmp_path):
    """Two elastic runs with the same fault schedule are bit-identical."""
    s1, h1 = _elastic_pipe(str(tmp_path / "a")).fit(eval_fn=None)
    s2, h2 = _elastic_pipe(str(tmp_path / "b")).fit(eval_fn=None)
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h["step"] for h in h1] == [h["step"] for h in h2]
    l1 = [h["loss"] for h in h1 if "loss" in h]
    l2 = [h["loss"] for h in h2 if "loss" in h]
    assert l1 == l2


def test_restart_on_epoch_boundary_keeps_summary(tmp_path):
    """A fault landing exactly on an epoch's final step must not eat the
    epoch summary: the health poll for that step runs AFTER the summary is
    appended, and the resumed run skips the fully-done epoch wholesale."""
    pipe = _elastic_pipe(str(tmp_path / "ck"), dead_after=10)  # spe == 10
    assert pipe.steps_per_epoch == 10
    _, history = pipe.fit(eval_fn=None)
    assert len(pipe.restarts) == 1
    assert pipe.restarts[0]["step"] == 10
    summaries = [h["epoch"] for h in history if "epoch_time_s" in h]
    assert summaries == [0, 1]  # epoch 0's summary survived the restart
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))


def test_elastic_requires_ckpt_dir():
    clock = [0.0]
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, _params(),
        PipelineConfig(batch_per_rank=B, world=WORLD,
                       loop=TrainLoopConfig(epochs=1)),
        elastic=ElasticConfig(clock=lambda: clock[0]))
    with pytest.raises(ValueError, match="ckpt_dir"):
        pipe.fit(eval_fn=None)


def test_shrink_mesh_keeps_model_axis_whole():
    mesh = make_host_mesh()
    n = len(jax.devices())
    smaller = shrink_mesh(mesh, max(n - 1, 1))
    assert int(smaller.shape.get("model", 1)) == 1
    assert dp_size(smaller) == max(min(n - 1, dp_size(mesh)), 1)
    # shrinking to at-or-above the physical pool is the identity
    assert shrink_mesh(mesh, n + 1) is mesh
