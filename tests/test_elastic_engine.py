"""Elastic shrink-and-resume + grow-and-resume, single-host fault injection:

heartbeat flags a worker → ``plan_remesh`` shrinks the data axis →
the latest checkpoint restores into the new mesh → training resumes
deterministically from the same (seed, epoch, step), with the per-worker
batch re-scaled by ``scale_batch_or_steps``.  When the dropped worker
heartbeats again, the inverse GROW plan re-admits it: the mesh re-expands,
the per-worker batch scales back down against the BASE global batch, and
the checkpoint restores into the larger topology (ISSUE 3 tentpole).

The fault is injected through :class:`ElasticConfig`'s two fakes — ``clock``
(a mutable list standing in for ``time.monotonic``) and ``step_feed`` (the
heartbeat transport, which simply stops reporting the "dead" rank while the
clock jumps past the timeout, then reports it from OUTSIDE the shrunk world
to announce its return) — so the whole chain runs on one host with no real
worker loss.  The same chain over real processes and a real transport is
exercised by ``tests/multihost.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Placement, WindowSpec
from repro.core.distributed import dp_size
from repro.data import make_traffic_series
from repro.distributed import scale_batch_or_steps
from repro.launch.mesh import make_host_mesh, shrink_mesh
from repro.optim import AdamConfig
from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig

ENTRIES, NODES, HORIZON, B, WORLD = 120, 3, 2, 2, 4
SPEC = WindowSpec(horizon=HORIZON, input_len=HORIZON)
DEAD_RANK, DEAD_AT_STEP = 1, 3


def _params():
    return {"w": jnp.full((NODES, 2), 0.1, jnp.float32)}


def _loss_fn(p, x, y):
    pred = x[:, -1] * p["w"]
    return jnp.mean((pred - y[:, 0]) ** 2), {}


class OneDeadWorker:
    """step_feed fake: rank ``dead_rank`` stops heartbeating at global step
    ``dead_after`` while the shared fake clock jumps past the heartbeat
    timeout, so the very next poll flags it DEAD.  After the re-mesh the
    world has shrunk and every surviving rank beats normally."""

    def __init__(self, clock, dead_after: int = DEAD_AT_STEP,
                 dead_rank: int = DEAD_RANK):
        self.clock = clock
        self.dead_after = dead_after
        self.dead_rank = dead_rank

    def __call__(self, step: int, world: int) -> dict:
        self.clock[0] += 1.0
        beats = {r: (step, None) for r in range(world)}
        if world == WORLD and step >= self.dead_after:
            del beats[self.dead_rank]
            self.clock[0] += 100.0  # fly past the 50 s timeout
        return beats


def _elastic_pipe(ckpt_dir: str, *, epochs: int = 2,
                  dead_after: int = DEAD_AT_STEP, dead_rank: int = DEAD_RANK):
    clock = [0.0]
    elastic = ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                            step_feed=OneDeadWorker(clock, dead_after,
                                                    dead_rank))
    return build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, _params(),
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=WORLD, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=epochs, log_every=1,
                                            ckpt_dir=ckpt_dir)),
        elastic=elastic)


def test_shrink_and_resume_full_chain(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    pipe = _elastic_pipe(ckpt_dir)
    old_global = pipe.global_batch
    state, history = pipe.fit(eval_fn=None)

    # 1. the heartbeat monitor flagged the dead worker and plan_remesh
    #    dropped exactly it, shrinking the data axis 4 -> 3
    assert len(pipe.restarts) == 1
    rec = pipe.restarts[0]
    assert rec["plan"].dropped_workers == (DEAD_RANK,)
    assert rec["plan"].mesh_shape == (WORLD - 1, 1)

    # 2. the engine re-scaled the per-worker batch per scale_batch_or_steps
    per, glob = scale_batch_or_steps(old_global, old_dp=WORLD,
                                     new_dp=WORLD - 1)
    assert pipe.world == WORLD - 1
    assert pipe.config.batch_per_rank == per
    assert pipe.global_batch == glob
    assert dp_size(pipe.mesh) == min(WORLD - 1, len(jax.devices()))

    # 3. resumed from the same (seed, epoch, step): the failure checkpoint
    #    carries (epoch 0, 3 steps done) and no epoch is lost or repeated
    assert rec["epoch"] == 0 and rec["step"] == DEAD_AT_STEP
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    # the global step counter stays MONOTONIC across the re-mesh, so the
    # newest checkpoint is always the highest-numbered one
    from repro.distributed import latest_step
    assert latest_step(ckpt_dir) == max(h["step"] for h in history)
    # the sampler seed is unchanged — the resumed epoch draws from the same
    # deterministic (seed, epoch) schedule at the new world size
    assert pipe.config.seed == 7
    assert jax.tree.leaves(state)  # training actually produced a state


def test_shrink_and_resume_is_deterministic(tmp_path):
    """Two elastic runs with the same fault schedule are bit-identical."""
    s1, h1 = _elastic_pipe(str(tmp_path / "a")).fit(eval_fn=None)
    s2, h2 = _elastic_pipe(str(tmp_path / "b")).fit(eval_fn=None)
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h["step"] for h in h1] == [h["step"] for h in h2]
    l1 = [h["loss"] for h in h1 if "loss" in h]
    l2 = [h["loss"] for h in h2 if "loss" in h]
    assert l1 == l2


def test_restart_on_epoch_boundary_keeps_summary(tmp_path):
    """A fault landing exactly on an epoch's final step must not eat the
    epoch summary: the health poll for that step runs AFTER the summary is
    appended, and the resumed run skips the fully-done epoch wholesale."""
    pipe = _elastic_pipe(str(tmp_path / "ck"), dead_after=10)  # spe == 10
    assert pipe.steps_per_epoch == 10
    _, history = pipe.fit(eval_fn=None)
    assert len(pipe.restarts) == 1
    assert pipe.restarts[0]["step"] == 10
    summaries = [h["epoch"] for h in history if "epoch_time_s" in h]
    assert summaries == [0, 1]  # epoch 0's summary survived the restart
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))


def test_elastic_requires_ckpt_dir():
    clock = [0.0]
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, _params(),
        PipelineConfig(batch_per_rank=B, world=WORLD,
                       loop=TrainLoopConfig(epochs=1)),
        elastic=ElasticConfig(clock=lambda: clock[0]))
    with pytest.raises(ValueError, match="ckpt_dir"):
        pipe.fit(eval_fn=None)


class DeadThenRecovered:
    """step_feed fake for the full shrink→grow loop: ``dead_ranks`` stop
    heartbeating at step ``dead_after`` (clock flies past the timeout, so the
    next poll plans a shrink); from step ``recover_after`` the lost workers
    heartbeat again from OUTSIDE the shrunk world (rank ids ≥ world — the
    target fleet's numbering), which the engine turns into a grow plan."""

    def __init__(self, clock, dead_ranks=(DEAD_RANK,),
                 dead_after: int = DEAD_AT_STEP, recover_after: int = 6):
        self.clock = clock
        self.dead_ranks = tuple(dead_ranks)
        self.dead_after = dead_after
        self.recover_after = recover_after
        self.killed = False

    def __call__(self, step: int, world: int) -> dict:
        self.clock[0] += 1.0
        beats = {r: (step, None) for r in range(world)}
        if not self.killed and world == WORLD and step >= self.dead_after:
            for r in self.dead_ranks:
                del beats[r]
            self.clock[0] += 100.0  # fly past the 50 s timeout
            self.killed = True
        if world < WORLD and step >= self.recover_after:
            for i in range(len(self.dead_ranks)):
                beats[world + i] = (step, None)
        return beats


def _grow_pipe(ckpt_dir: str, *, dead_ranks=(DEAD_RANK,), epochs: int = 2,
               dead_after: int = DEAD_AT_STEP, recover_after: int = 6,
               elastic: bool = True, mesh=None):
    clock = [0.0]
    cfg = ElasticConfig(
        heartbeat_timeout=50.0, clock=lambda: clock[0],
        step_feed=DeadThenRecovered(clock, dead_ranks, dead_after,
                                    recover_after)) if elastic else None
    return build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC,
        make_host_mesh() if mesh is None else mesh,
        _loss_fn, _params(),
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=WORLD, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=epochs, log_every=1,
                                            ckpt_dir=ckpt_dir)),
        elastic=cfg)


def test_grow_and_resume_full_chain(tmp_path):
    """Shrink 4→3 on worker loss, then grow 3→4 when it returns: the grow
    plan re-admits the worker, the per-worker batch inverse-scales back to
    the original, and training resumes at the checkpoint coordinates."""
    pipe = _grow_pipe(str(tmp_path / "ck"))
    state, history = pipe.fit(eval_fn=None)

    assert [r["kind"] for r in pipe.restarts] == ["shrink", "grow"]
    shrink, grow = pipe.restarts
    assert shrink["plan"].dropped_workers == (DEAD_RANK,)
    assert shrink["world"] == WORLD - 1
    # the grow plan re-admitted one worker (announced as rank 3 — outside
    # the shrunk world) and the mesh axis re-expanded
    assert grow["plan"].readmitted_workers == (WORLD - 1,)
    assert grow["plan"].mesh_shape == (WORLD, 1)
    assert grow["world"] == WORLD
    # inverse batch scaling: back to the BASE per-rank batch and global batch
    assert pipe.world == WORLD
    assert pipe.config.batch_per_rank == B
    assert pipe.global_batch == B * WORLD
    assert dp_size(pipe.mesh) == min(WORLD, len(jax.devices()))
    # monotonic step counter across BOTH re-meshes; both epochs summarised
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    from repro.distributed import latest_step
    assert latest_step(str(tmp_path / "ck")) == max(h["step"] for h in history)
    assert jax.tree.leaves(state)


def test_grow_trajectory_bit_identical_when_batch_divides(tmp_path):
    """Losing HALF the fleet and growing back preserves the global batch
    exactly (8/2 and 8/4 both divide), so every drawn batch — and therefore
    the whole loss trajectory — is bit-identical to an uninterrupted run.
    Epoch-end eval is left on (``eval_fn="auto"``): the eval pools are
    re-placed alongside the train series on every re-mesh, and because the
    global batch is preserved the eval chunk plan — and the window-weighted
    ``val_mae`` — must ALSO be bit-identical in whatever topology the epoch
    boundary lands in (ISSUE 4: eval works across shrink/grow re-meshes).

    Pinned to a 1-device mesh (logical worlds) so the compiled program is
    the same in every phase: bit-identity across a PHYSICAL topology change
    needs the device layout held constant, which is what tests/multihost.py
    arranges (2 devices throughout) — on a multi-device host a shrink here
    really re-carves the mesh and float reduction order may differ."""
    from jax.sharding import Mesh
    one_dev = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                   ("data", "model"))
    smooth, smooth_hist = _grow_pipe(str(tmp_path / "a"), elastic=False,
                                     mesh=one_dev).fit()
    pipe = _grow_pipe(str(tmp_path / "b"), dead_ranks=(1, 2), mesh=one_dev)
    bumpy, bumpy_hist = pipe.fit()

    assert [r["kind"] for r in pipe.restarts] == ["shrink", "grow"]
    assert pipe.restarts[0]["world"] == WORLD - 2
    assert pipe.restarts[0]["batch_per_rank"] == 2 * B   # 8 / 2
    assert pipe.restarts[1]["world"] == WORLD
    assert pipe.restarts[1]["batch_per_rank"] == B       # 8 / 4 — inverse
    for a, b in zip(jax.tree.leaves(smooth), jax.tree.leaves(bumpy)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    s_losses = {h["step"]: h["loss"] for h in smooth_hist if "loss" in h}
    b_losses = {h["step"]: h["loss"] for h in bumpy_hist if "loss" in h}
    assert s_losses == b_losses
    # eval parity across the re-meshed run: same chunks, same weights, same
    # program — bit-identical val_mae for every summarised epoch
    s_evals = {h["epoch"]: h["val_mae"] for h in smooth_hist
               if "epoch_time_s" in h}
    b_evals = {h["epoch"]: h["val_mae"] for h in bumpy_hist
               if "epoch_time_s" in h}
    assert set(s_evals) == {0, 1}
    assert b_evals == s_evals


def test_meta_round_trip_across_two_remeshes(tmp_path):
    """(epoch, done_in_epoch) must survive steps_per_epoch changing TWICE
    (shrink 4→3 then grow 3→4, global batch 8→9→8, spe 10→9→10): positions
    keep advancing, the interrupted epoch is summarised exactly once, and
    the monotonic counter never lets a stale checkpoint win."""
    ckpt = str(tmp_path / "ck")
    pipe = _grow_pipe(ckpt, dead_after=12, recover_after=15)
    assert pipe.steps_per_epoch == 10
    _, history = pipe.fit(eval_fn=None)

    assert [r["kind"] for r in pipe.restarts] == ["shrink", "grow"]
    shrink, grow = pipe.restarts
    # the shrink lands mid-epoch-1 and the checkpoint meta carries its
    # coordinates under the OLD grid (spe 10: step 12 = epoch 1, 2 done)
    assert (shrink["epoch"], shrink["step"]) == (1, 12)
    # the grow lands under the SHRUNK grid (spe 9) and still resumes inside
    # epoch 1 — the meta was written against the grid that produced it.
    # (The returned worker announces from step 15 and is re-admitted on its
    # 3rd announcement — the readmit_after_beats flap debounce.)
    assert grow["epoch"] == 1 and grow["step"] == 17
    # batch inverse-scaled from the BASE global batch (8→9→8), not from the
    # inflated intermediate (which would compound: ceil(9/4)*4 = 12)
    assert shrink["batch_per_rank"] == 3 and shrink["global_batch"] == 9
    assert grow["batch_per_rank"] == B
    assert pipe.global_batch == B * WORLD
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    from repro.distributed import checkpoint_meta, latest_step
    assert latest_step(ckpt) == max(h["step"] for h in history)
    # the final checkpoint reads as the start of the after-last epoch
    assert checkpoint_meta(ckpt) == {"epoch": 2, "done_in_epoch": 0}


@pytest.fixture(scope="module")
def smooth_losses(tmp_path_factory):
    """The uninterrupted reference trajectory — computed once for the whole
    fault matrix (it does not depend on where or whom the fault hits)."""
    pipe = _grow_pipe(str(tmp_path_factory.mktemp("smooth")), elastic=False)
    hist = pipe.fit(eval_fn=None)[1]
    return {h["step"]: h["loss"] for h in hist if "loss" in h}


@pytest.mark.parametrize("dead_at", [1, 4, 9, 10])
def test_fault_matrix_rank_agnostic_trajectories(tmp_path, dead_at,
                                                 smooth_losses):
    """Kill EACH rank at step boundary ``dead_at`` of a 2-epoch run: the
    shrink→resume loss trajectory must be identical regardless of WHICH
    rank died (the sampler depends only on (seed, epoch, world), never on
    worker identity), and the pre-kill prefix must match the uninterrupted
    run bit-for-bit regardless of WHEN the failure lands."""
    smooth = smooth_losses
    trajectories = []
    for rank in range(WORLD):
        pipe = _elastic_pipe(str(tmp_path / f"r{rank}"), dead_after=dead_at,
                             dead_rank=rank)
        _, history = pipe.fit(eval_fn=None)
        assert len(pipe.restarts) == 1
        # A worker that dies before its FIRST beat (dead_at=1) gets one poll
        # of grace — the monitor times never-beaten workers from the first
        # poll, so a slow compile can't read as death — and is flagged on
        # the next poll instead.
        detect = dead_at if dead_at > 1 else 2
        assert (pipe.restarts[0]["epoch"], pipe.restarts[0]["step"]) == \
            (detect // 10, detect)
        losses = {h["step"]: h["loss"] for h in history if "loss" in h}
        trajectories.append(losses)
        # prefix before the kill is bit-identical to the uninterrupted run
        assert all(losses[s] == smooth[s] for s in range(1, detect + 1))
        steps = sorted(losses)
        assert steps == list(range(1, max(steps) + 1))  # no gaps, no dups
    assert all(t == trajectories[0] for t in trajectories[1:])


def test_shrink_mesh_keeps_model_axis_whole():
    mesh = make_host_mesh()
    n = len(jax.devices())
    smaller = shrink_mesh(mesh, max(n - 1, 1))
    assert int(smaller.shape.get("model", 1)) == 1
    assert dp_size(smaller) == max(min(n - 1, dp_size(mesh)), 1)
    # shrinking to at-or-above the physical pool is the identity
    assert shrink_mesh(mesh, n + 1) is mesh
