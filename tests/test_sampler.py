"""Samplers: determinism, coverage, disjointness — the properties that make
communication-free global shuffling and fault-tolerant resume possible."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GlobalShuffleSampler, LocalBatchShuffleSampler, ShardInfo
from repro.core.sampler import local_shuffle_sampler


def test_global_shuffle_deterministic_and_disjoint():
    ids = np.arange(64, dtype=np.int32)
    world = 4
    samplers = [GlobalShuffleSampler(ids, 4, ShardInfo(r, world), seed=3)
                for r in range(world)]
    for epoch in (0, 1):
        grids = [s.epoch(epoch) for s in samplers]
        # all ranks agree on the permutation => per-step batches are disjoint
        for step in range(grids[0].shape[0]):
            seen = np.concatenate([g[step] for g in grids])
            assert len(np.unique(seen)) == len(seen)
        # determinism: same (seed, epoch) -> same grid
        again = samplers[0].epoch(epoch)
        assert np.array_equal(grids[0], again)
    # different epochs shuffle differently (global shuffling, not fixed)
    assert not np.array_equal(samplers[0].epoch(0), samplers[0].epoch(1))


def test_global_shuffle_epoch_covers_all():
    ids = np.arange(60, dtype=np.int32)
    s = GlobalShuffleSampler(ids, 5, ShardInfo(0, 1), seed=0)
    grid = s.epoch(0)
    assert sorted(grid.reshape(-1)) == sorted(ids)


def test_epoch_global_matches_per_rank():
    """The SPMD path (one sharded batch) sees the same ids as per-rank paths."""
    ids = np.arange(64, dtype=np.int32)
    world, b = 4, 4
    full = GlobalShuffleSampler(ids, b, ShardInfo(0, world), seed=9).epoch_global(2)
    for r in range(world):
        rank_grid = GlobalShuffleSampler(ids, b, ShardInfo(r, world), seed=9).epoch(2)
        assert np.array_equal(full.reshape(-1, world, b)[:, r, :], rank_grid)


def test_local_batch_shuffle_fixed_partition():
    """Generalized variant (§5.4): partition fixed, only batch ORDER changes."""
    ids = np.arange(48, dtype=np.int32)
    s = LocalBatchShuffleSampler(ids, 4, ShardInfo(1, 4), seed=0)
    e0, e1 = s.epoch(0), s.epoch(1)
    # same batches as sets (content fixed within the rank's partition)
    set0 = {tuple(b) for b in e0}
    set1 = {tuple(b) for b in e1}
    assert set0 == set1
    # the rank's partition is the second quarter
    assert set(e0.reshape(-1)) <= set(range(12, 24))


def test_local_sample_shuffle_differs_from_batch_shuffle():
    ids = np.arange(48, dtype=np.int32)
    s = local_shuffle_sampler(ids, 4, ShardInfo(0, 4), seed=0)
    e0, e1 = s.epoch(0), s.epoch(1)
    # samples are re-mixed across batches (not just reordered)
    assert {tuple(b) for b in e0} != {tuple(b) for b in e1}
    # but stay within the rank's fixed partition
    assert set(e0.reshape(-1)) == set(range(12))


def test_feed_is_first_class_and_assembles_epoch_global():
    """feed(rank, epoch) is the primitive: column block r of epoch_global,
    and identical to what rank r's own sampler would draw."""
    ids = np.arange(64, dtype=np.int32)
    world, b = 4, 3
    for make in (GlobalShuffleSampler, LocalBatchShuffleSampler,
                 local_shuffle_sampler):
        s0 = make(ids, b, ShardInfo(0, world), seed=2)
        for epoch in (0, 3):
            cols = np.concatenate([s0.feed(r, epoch) for r in range(world)],
                                  axis=1)
            assert np.array_equal(cols, s0.epoch_global(epoch))
        for r in range(world):
            sib = make(ids, b, ShardInfo(r, world), seed=2)
            assert np.array_equal(s0.feed(r, 1), sib.epoch(1))
            assert np.array_equal(sib.feed(r, 1), sib.epoch(1))


@given(n=st.integers(16, 200), world=st.sampled_from([1, 2, 4, 8]),
       b=st.integers(1, 4), seed=st.integers(0, 10))
@settings(max_examples=50, deadline=None)
def test_global_shuffle_shapes(n, world, b, seed):
    ids = np.arange(n, dtype=np.int32)
    if n < world * b:
        with pytest.raises(ValueError):
            GlobalShuffleSampler(ids, b, ShardInfo(0, world), seed=seed)
        return
    s = GlobalShuffleSampler(ids, b, ShardInfo(0, world), seed=seed)
    grid = s.epoch(0)
    assert grid.shape == (n // (world * b), b)
