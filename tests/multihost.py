"""True multi-process ``jax.distributed`` harness for the elastic pipeline.

Everything the elastic chain claims is proven here on REAL processes — the
first time the repo's distributed data plane runs outside a single-host
simulation:

- N CPU processes join a real ``jax.distributed`` gang (gloo collectives,
  coordinator on a driver-chosen free port, one XLA-forced CPU device each);
- each process trains from its OWN per-rank feed
  (``DataPlane.process_ranks`` → ``make_array_from_process_local_data``) —
  no process ever materialises the global index grid;
- heartbeats ride the real file transport (``hb_<rank>.json`` in the shared
  run dir), not an injected fake;
- a worker is killed mid-epoch: the next collective on the survivor errors
  out ("connection closed by peer"), the survivor attributes the death via
  transport staleness, checkpoints are already durable to the failed step
  (``ckpt_every=1`` + the engine's crash-path flush), and it exits with the
  shrink verdict for the driver (the external launcher) to act on;
- the driver relaunches the survivor alone (world 1, per-rank batch
  inverse-scaled up, same GLOBAL batch) and it resumes at the same
  (seed, epoch, step);
- the dead worker "returns" (an announcer process heartbeating its rank from
  outside the shrunk world); the running trainer plans the GROW re-mesh and
  exits for relaunch;
- the driver relaunches the full 2-process gang (per-rank batch scaled back
  down) which finishes the run;
- epoch-end EVAL rides the same distributed data plane (ISSUE 4 tentpole):
  each process scores only its own rank-block of the val pool
  (``DataPlane.eval_feed``), the ragged tail is scored once replicated, and
  the window-weighted ``val_mae`` rows must come out bit-identical to the
  single-host reference — in every phase, across the kill→shrink→grow cycle;
- the interrupted phases of the grow test run the ASYNC FEED PIPELINE at
  ``--prefetch-depth 2 --staleness 0`` (ISSUE 6) against a synchronous
  reference, so the bit-identity headline is also the distributed
  staleness-0 identity — prefetched feeds drain cleanly through every
  kill/shrink/grow re-mesh (evidence key ``prefetch_bit_identical``);
- every phase appends to ONE crash-durable ``history.jsonl`` sink
  (leader-gated ``LeaderHistorySink`` over ``JsonlHistorySink``): after all
  three relaunches each step row and each epoch/eval row appears exactly
  once (idempotent resume);
- the KILL-RANK-0 cycle (ISSUE 5 tentpole proof) repeats the whole loop
  with the DECIDER/WRITER as the victim: process 0 — classically the only
  heartbeat decider, checkpoint writer, plan emitter and history sink —
  dies mid-epoch.  Rank 1 attributes the death via its own (symmetric)
  transport snapshot, assumes leadership (lowest live rank,
  ``repro.distributed.leader``), durably writes its warm-standby
  checkpoint of the exact failure step (the victim runs ``ckpt_every=0``,
  so the resume point can ONLY have come from the successor's takeover),
  decides the shrink plan itself, flushes the buffered history rows, and
  exits 75 like any other re-mesh; shrink → resume → grow then proceed as
  before, and the merged losses/val_mae stay bit-identical to the
  uninterrupted reference (evidence key: ``leader_failover``).

The device-level topology is held constant across phases (2 devices total:
2 procs × 1 dev, or 1 proc × 2 forced devs) so every phase compiles the
same partitioned program over the same global batch — which is what makes
the headline assertion possible: the merged loss trajectory of the
interrupted, re-meshed run is **bit-identical** to an uninterrupted
single-host run.

Run it:  ``python -m pytest -q tests/multihost.py``  (not collected by the
tier-1 suite — the driver spawns ~7 jax subprocesses and takes ~1 min).
The driver writes ``results/multihost_evidence.json`` for CI artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import pytest

# ----------------------------------------------------------------- constants
ENTRIES, NODES = 120, 3
GLOBAL_BATCH = 4
FLEET = 2            # the full gang: 2 real processes
SEED = 7
EPOCHS = 2
DIE_AT_STEP = 7      # mid-epoch 0 (20 steps per epoch)
HB_TIMEOUT = 1.5     # seconds of real-clock silence = dead
STEP_DELAY = 0.1     # paces the loop so the driver can react mid-run
EXIT_REMESH = 75     # "relaunch me into the planned topology"
EXIT_KILLED = 17     # the victim's deliberate crash


# ===================================================================== worker
def _run_worker(args: argparse.Namespace) -> None:
    """One training process.  Under ``--nprocs > 1`` it joins the
    jax.distributed gang; exit codes tell the driver what happened."""
    import jax

    if args.nprocs > 1:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        if args.external_coordinator:
            # The kill-rank-0 phases host the PJRT coordination service in
            # the driver's own ``coordinator`` subprocess — the launcher's
            # fault domain — instead of inside process 0.  With the service
            # embedded in rank 0, rank 0's death takes the rendezvous
            # service down with it and every survivor's coordination client
            # LOG(QFATAL)s ("Terminating process because the JAX
            # distributed service detected fatal errors") before any
            # fault-handling code can run: the fleet commits suicide over a
            # lost coordinator, the exact single-owner failure this PR
            # removes.  Decoupled, a worker death — ANY worker — degrades
            # to a failed gloo collective the survivor catches and
            # attributes.  jax.distributed only hosts the service when
            # process_id == 0, so stubbing the factory is all it takes for
            # rank 0 to connect as a plain client like everyone else.
            import types

            from jax._src.lib import xla_extension
            xla_extension.get_distributed_runtime_service = \
                lambda *a, **kw: types.SimpleNamespace(shutdown=lambda: None)
        jax.distributed.initialize(f"127.0.0.1:{args.coordinator_port}",
                                   args.nprocs, args.rank)

    import jax.numpy as jnp
    import numpy as np

    from repro.core import Placement, WindowSpec
    from repro.data import make_traffic_series
    from repro.distributed import LeaderHistorySink, LeaderTracker
    from repro.distributed.transport import FileHeartbeatTransport
    from repro.launch.mesh import make_host_mesh
    from repro.optim import AdamConfig
    from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
    from repro.train import TrainLoopConfig
    from repro.train.loop import RestartSignal

    out = args.out
    hb = FileHeartbeatTransport(os.path.join(out, "hb"))
    # Leader succession (ISSUE 5): the decider/writer is no longer pinned to
    # process 0 — the lowest LIVE rank owns checkpoint writes, plan emission
    # and the durable history, so the run survives the death of rank 0 too.
    tracker = (LeaderTracker(args.world, timeout=args.hb_timeout)
               if args.elastic else None)

    def is_leader() -> bool:
        return (tracker.is_leader() if tracker is not None
                else jax.process_index() == 0)

    def loss_fn(p, x, y):
        return jnp.mean((x[:, -1] * p["w"] - y[:, 0]) ** 2), {}

    params = {"w": jnp.full((NODES, 2), 0.1, jnp.float32)}
    owned: list[int] = []

    def emitter(step: int) -> None:
        time.sleep(args.step_delay)
        for r in owned:
            hb.emit(r, step)
        if args.die_at_step and step >= args.die_at_step:
            os._exit(EXIT_KILLED)  # simulated crash: beats stop, sockets drop

    elastic = None
    if args.elastic:
        # EVERY process polls the (symmetric) file transport — that is what
        # keeps a potential successor's monitor primed — but only the
        # current leader acts on a verdict (the engine gates plans on
        # is_leader()).
        elastic = ElasticConfig(
            heartbeat_timeout=args.hb_timeout,
            emitter=emitter,
            step_feed=hb.step_feed,
            leader=tracker,
            remesh="relaunch",
            target_world=args.target_world or None)

    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), WindowSpec(horizon=2, input_len=2),
        make_host_mesh(), loss_fn, params,
        PipelineConfig(batch_per_rank=args.batch_per_rank,
                       placement=Placement.REPLICATED, world=args.world,
                       seed=SEED, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=EPOCHS, log_every=1,
                                            ckpt_every=args.ckpt_every,
                                            ckpt_dir=os.path.join(out, "ck"),
                                            prefetch_depth=args.prefetch_depth,
                                            staleness=args.staleness)),
        elastic=elastic)
    ranks = pipe.dataplane.process_ranks
    owned.extend(ranks if ranks is not None else range(pipe.world))
    if tracker is not None:
        tracker.bind(owned)

    # ONE durable sink across every phase/relaunch in this run dir: rows are
    # fsynced as they land and duplicate (epoch, step) rows from a resumed
    # epoch tail are suppressed — the idempotency the driver asserts.  The
    # sink is leader-gated on every process: the leader's rows go durable
    # immediately, a standby buffers and only touches the shared file after
    # a succession takeover (flush_as_leader below).
    sink = LeaderHistorySink(os.path.join(out, "history.jsonl"), is_leader)
    outcome: dict = {"phase": args.phase, "world": args.world,
                     "nprocs": args.nprocs, "rank": args.rank,
                     "batch_per_rank": args.batch_per_rank,
                     "process_ranks": list(owned)}
    code = 0
    try:
        # eval_fn defaults to "auto": epoch-end val_mae through the
        # distributed eval feeds — the metric the driver asserts parity on.
        _, history = pipe.fit(resume=True, history_sink=sink)
        outcome["status"] = "done"
    except RestartSignal as sig:
        plan = sig.plan
        outcome.update({
            "status": "remesh", "kind": plan.kind, "reason": plan.reason,
            "dropped_workers": list(plan.dropped_workers),
            "readmitted_workers": list(plan.readmitted_workers),
            "decided_by": plan.decided_by,
            "leader": getattr(sig, "leader", True),
            "epoch": sig.epoch, "step": sig.step,
        })
        code = EXIT_REMESH
    except Exception as e:
        # A collective died under us: a peer is gone.  Attribute the death
        # through the transport (whose beats went silent?), then run leader
        # SUCCESSION: if the dead peer was the leader, the lowest surviving
        # rank — us — takes over every writer duty it held (durably writes
        # the warm-standby checkpoint of the failure step, decides the
        # shrink plan, flushes the buffered history rows) before handing
        # the driver the shrink verdict.
        others = [r for r in range(args.world) if r not in owned]
        deadline = time.time() + 4 * args.hb_timeout
        dead: list[int] = []
        while time.time() < deadline and not dead:
            snap = hb.snapshot()
            dead = [r for r in others
                    if r not in snap or snap[r]["age"] > args.hb_timeout]
            if not dead:
                time.sleep(0.15)
        dead = dead or others
        succession = pipe.succeed_as_leader(dead)
        flushed = sink.flush_as_leader()
        outcome.update({"status": "peer-failure",
                        "error": f"{type(e).__name__}: {e}"[:300],
                        "dead_workers": dead})
        if succession is not None:
            plan = succession["plan"]
            outcome.update({
                "leader_rank": succession["leader"],
                "ckpt_takeover_step": succession["ckpt_step"],
                "history_rows_flushed": flushed,
                "kind": plan.kind if plan is not None else None,
                "reason": plan.reason if plan is not None else None,
                "decided_by": plan.decided_by if plan is not None else None,
                "shrink_workers": (list(plan.dropped_workers)
                                   if plan is not None else []),
            })
        code = EXIT_REMESH
    if is_leader():  # evaluated AFTER any succession: the new leader writes
        rows = sink.rows  # what THIS incarnation contributed to the sink
        steps = [h["step"] for h in rows if "epoch_time_s" not in h]
        outcome["steps"] = [min(steps), max(steps)] if steps else []
        with open(os.path.join(out, f"history_{args.phase}.json"), "w") as f:
            json.dump(rows, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(out, f"outcome_{args.phase}.json"), "w") as f:
            json.dump(outcome, f)
            f.flush()
            os.fsync(f.fileno())
    sys.stdout.flush()
    sys.stderr.flush()
    # os._exit: after a peer death, jax.distributed's shutdown barrier would
    # abort the process and scramble the exit code the driver relies on.
    os._exit(code)


# ================================================================ coordinator
def _run_coordinator(args: argparse.Namespace) -> None:
    """Host the PJRT coordination service in its own process (the external
    launcher's fault domain) so the gang's rendezvous does not share fate
    with any worker — the topology that makes a rank-0 death survivable.
    The driver kills us once the phase is over."""
    from jax._src.lib import xla_extension

    svc = xla_extension.get_distributed_runtime_service(
        f"[::]:{args.coordinator_port}", args.nprocs)
    try:
        while True:
            time.sleep(1.0)
    finally:
        svc.shutdown()


# ================================================================== announcer
def _run_announcer(args: argparse.Namespace) -> None:
    """The returned worker's rejoin agent: heartbeat a rank from OUTSIDE the
    running world until the trainer plans the grow (driver kills us)."""
    from repro.distributed.transport import FileHeartbeatTransport

    hb = FileHeartbeatTransport(os.path.join(args.out, "hb"))
    step = 0
    while True:
        hb.emit(args.rank, step)
        step += 1
        time.sleep(0.1)


# ============================================================== serve worker
def _run_serve_worker(args: argparse.Namespace) -> None:
    """One serving host (PR 9): a single-plane ``ServeWorker`` pumping file
    mailboxes, announcing liveness through the shared heartbeat dir.  The
    driver-side ``FleetEngine`` assigns work, detects this process's death
    via beat silence, and re-prefills its in-flight requests on survivors.

    Every incarnation gets an attempt-suffixed spool (``w{rank}_a{attempt}``)
    so a relaunch never re-reads the ghost's half-consumed mailbox; params
    come from the same seeded init on every host, so the fleet is weight-
    identical by construction (a real deployment would load a checkpoint)."""
    import jax

    from repro.configs import LM_ARCHS
    from repro.distributed.transport import FileHeartbeatTransport
    from repro.models.lm import model as lm
    from repro.serve import FileMailbox, ServeConfig, ServeWorker

    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)
    sc = ServeConfig(slots=args.slots, max_len=args.max_len,
                     max_new_tokens=args.max_new,
                     block_size=args.block_size or None,
                     pool_blocks=args.pool_blocks or None)
    spool = os.path.join(args.out, f"w{args.rank}_a{args.attempt}")
    worker = ServeWorker(
        params, cfg, sc, worker_id=args.rank, attempt=args.attempt,
        inbox=FileMailbox(os.path.join(spool, "in")),
        outbox=FileMailbox(os.path.join(spool, "out")),
        heartbeat=FileHeartbeatTransport(os.path.join(args.out, "hb")))
    worker.run(step_delay=args.step_delay)
    os._exit(0)  # clean stop: the coordinator told us to


# =================================================================== driver
def _wait(proc, *, timeout: float, what: str) -> int:
    try:
        return proc.wait(timeout=timeout)
    except Exception:
        proc.kill()
        proc.wait()
        pytest.fail(f"{what} did not finish within {timeout}s")


def _read_json(path: str):
    with open(path) as f:
        return json.load(f)


def _losses(history: list[dict]) -> dict[int, float]:
    return {h["step"]: h["loss"] for h in history
            if "loss" in h and "epoch_time_s" not in h}


def _evals(history: list[dict]) -> dict[int, float]:
    """epoch -> val_mae from the epoch summary rows."""
    return {h["epoch"]: h["val_mae"] for h in history
            if "epoch_time_s" in h and "val_mae" in h}


def _read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _hb_step(run: str, rank: int) -> int:
    try:
        return _read_json(os.path.join(run, "hb", f"hb_{rank}.json"))["step"]
    except (OSError, ValueError, KeyError):
        return -1


def _ckpt_steps(run: str) -> list[int]:
    try:
        return sorted(int(n.split("_")[1])
                      for n in os.listdir(os.path.join(run, "ck"))
                      if n.startswith("step_"))
    except OSError:
        return []


def _merge_evidence(results_dir: str, updates: dict) -> None:
    """Read-merge-write ``multihost_evidence.json``: the kill-rank-1 and
    kill-rank-0 tests each contribute their keys without clobbering the
    other's (CI asserts fields from both before uploading the artifact)."""
    path = os.path.join(results_dir, "multihost_evidence.json")
    evidence: dict = {}
    try:
        evidence = _read_json(path)
    except (OSError, ValueError):
        pass
    evidence.update(updates)
    with open(path, "w") as f:
        json.dump(evidence, f, indent=1)


def _worker_argv(*, phase: str, out: str, rank: int = 0, nprocs: int = 1,
                 world: int, batch_per_rank: int, port: int = 0,
                 elastic: bool = True, die_at: int = 0,
                 target_world: int = 0, ckpt_every: int = 1,
                 external_coordinator: bool = False,
                 prefetch_depth: int = 0, staleness: int = 0) -> list:
    argv = ["worker", "--phase", phase, "--out", out, "--rank", rank,
            "--nprocs", nprocs, "--coordinator-port", port,
            "--world", world, "--batch-per-rank", batch_per_rank,
            "--hb-timeout", HB_TIMEOUT, "--step-delay", STEP_DELAY,
            "--ckpt-every", ckpt_every,
            "--prefetch-depth", prefetch_depth, "--staleness", staleness]
    if elastic:
        argv.append("--elastic")
    if external_coordinator:
        argv.append("--external-coordinator")
    if die_at:
        argv += ["--die-at-step", die_at]
    if target_world:
        argv += ["--target-world", target_world]
    return argv


def test_elastic_grow_and_resume_on_real_processes(tmp_path, free_port,
                                                   mh_spawn, results_dir):
    """Worker death → shrink → resume at the same (seed, epoch, step) →
    worker return → grow with inverse batch scaling → losses bit-identical
    to an uninterrupted single-host run.  ~1 min, 7 subprocesses.

    Every interrupted phase runs the ASYNC FEED PIPELINE at staleness 0
    (``--prefetch-depth 2``) while the reference stays synchronous — so the
    bit-identity headline doubles as the distributed staleness-0 identity
    (ISSUE 6): prefetched feeds + drain-on-remesh reproduce the synchronous
    trajectory exactly, through the kill→shrink→grow cycle, on real
    ``jax.distributed`` processes (evidence key ``prefetch_bit_identical``).
    """
    ref = str(tmp_path / "ref")
    run = str(tmp_path / "run")
    os.makedirs(ref)
    os.makedirs(run)

    # ---- reference: uninterrupted single-host run, same 2-device program
    p = mh_spawn(_worker_argv(phase="ref", out=ref, world=FLEET,
                              batch_per_rank=GLOBAL_BATCH // FLEET,
                              elastic=False),
                 devices=2, log=os.path.join(ref, "ref.log"))
    assert _wait(p, timeout=240, what="reference run") == 0
    ref_hist = _read_json(os.path.join(ref, "history_ref.json"))
    ref_losses = _losses(ref_hist)
    total_steps = max(ref_losses)

    # ---- phase A: the real 2-process jax.distributed gang; rank 1 dies
    port = free_port()
    argv = dict(out=run, nprocs=FLEET, world=FLEET,
                batch_per_rank=GLOBAL_BATCH // FLEET, port=port,
                target_world=FLEET, prefetch_depth=2, staleness=0)
    p0 = mh_spawn(_worker_argv(phase="a", rank=0, **argv),
                  devices=1, log=os.path.join(run, "a0.log"))
    p1 = mh_spawn(_worker_argv(phase="a", rank=1, die_at=DIE_AT_STEP, **argv),
                  devices=1, log=os.path.join(run, "a1.log"))
    assert _wait(p1, timeout=240, what="phase A victim") == EXIT_KILLED
    assert _wait(p0, timeout=240, what="phase A survivor") == EXIT_REMESH
    out_a = _read_json(os.path.join(run, "outcome_a.json"))
    assert out_a["status"] == "peer-failure"
    assert out_a["dead_workers"] == [1]
    hist_a = _read_json(os.path.join(run, "history_a.json"))
    losses_a = _losses(hist_a)
    assert max(losses_a) == DIE_AT_STEP  # crashed at the very next step

    # ---- phase B: survivor relaunched alone — world 1, per-rank batch
    #      inverse-scaled UP (global batch preserved), resumes mid-epoch.
    #      The heartbeat dir is deliberately NOT cleaned: the dead worker's
    #      stale hb_1.json is still there, and the relaunched trainer must
    #      not misread it as the worker having returned (the transport
    #      primes its poll baseline with pre-existing files).
    pb = mh_spawn(_worker_argv(phase="b", out=run, world=1,
                               batch_per_rank=GLOBAL_BATCH,
                               target_world=FLEET, prefetch_depth=2,
                               staleness=0),
                  devices=2, log=os.path.join(run, "b.log"))
    # once the survivor has visibly resumed, the dead worker "returns"
    deadline = time.time() + 120
    while _hb_step(run, 0) < DIE_AT_STEP + 3:
        assert time.time() < deadline, "phase B never advanced past resume"
        assert pb.poll() is None, "phase B exited before the worker returned"
        time.sleep(0.1)
    ann = mh_spawn(["announce", "--out", run, "--rank", 1])
    assert _wait(pb, timeout=240, what="phase B trainer") == EXIT_REMESH
    ann.kill()
    out_b = _read_json(os.path.join(run, "outcome_b.json"))
    assert out_b["status"] == "remesh" and out_b["kind"] == "grow"
    assert out_b["readmitted_workers"] == [1]
    hist_b = _read_json(os.path.join(run, "history_b.json"))
    losses_b = _losses(hist_b)
    # resumed at the same (seed, epoch, step): the step after the last
    # durable checkpoint, with no gap and no repeat
    assert min(losses_b) == DIE_AT_STEP + 1
    grow_step = out_b["step"]

    # ---- phase C: the full gang again — per-rank batch scaled back DOWN
    #      (stale announcer beats likewise left in place)
    port_c = free_port()
    argv_c = dict(out=run, nprocs=FLEET, world=FLEET,
                  batch_per_rank=GLOBAL_BATCH // FLEET, port=port_c,
                  target_world=FLEET, prefetch_depth=2, staleness=0)
    c0 = mh_spawn(_worker_argv(phase="c", rank=0, **argv_c),
                  devices=1, log=os.path.join(run, "c0.log"))
    c1 = mh_spawn(_worker_argv(phase="c", rank=1, **argv_c),
                  devices=1, log=os.path.join(run, "c1.log"))
    assert _wait(c0, timeout=240, what="phase C rank 0") == 0
    assert _wait(c1, timeout=240, what="phase C rank 1") == 0
    out_c = _read_json(os.path.join(run, "outcome_c.json"))
    assert out_c["status"] == "done"
    hist_c = _read_json(os.path.join(run, "history_c.json"))
    losses_c = _losses(hist_c)
    assert min(losses_c) == grow_step + 1
    assert max(losses_c) == total_steps

    # ---- the headline: the merged interrupted-run trajectory is
    #      BIT-IDENTICAL to the uninterrupted single-host reference
    merged = {**losses_a, **losses_b, **losses_c}
    assert sorted(merged) == list(range(1, total_steps + 1))
    assert merged == ref_losses
    # both epochs were summarised exactly once across the three phases
    epochs = [h["epoch"] for h in hist_a + hist_b + hist_c
              if "epoch_time_s" in h]
    assert epochs == [0, 1]

    # ---- distributed EVAL parity (ISSUE 4): every epoch's val_mae — scored
    #      through per-rank eval feeds in whatever topology the phase ran —
    #      is bit-identical to the single-host window-weighted reference,
    #      across the kill→shrink→grow cycle.
    ref_evals = _evals(ref_hist)
    assert set(ref_evals) == {0, 1}
    merged_evals = {**_evals(hist_a), **_evals(hist_b), **_evals(hist_c)}
    assert merged_evals == ref_evals

    # ---- the ONE durable history.jsonl spanning all three relaunches:
    #      every step row and every epoch/eval row appears exactly once
    #      (JsonlHistorySink suppressed any resume re-logs) and the whole
    #      file equals the uninterrupted reference.
    durable = _read_jsonl(os.path.join(run, "history.jsonl"))
    d_steps = [h["step"] for h in durable if "epoch_time_s" not in h]
    assert sorted(d_steps) == list(range(1, total_steps + 1))
    assert sorted(h["epoch"] for h in durable if "epoch_time_s" in h) == [0, 1]
    assert _losses(durable) == ref_losses
    assert _evals(durable) == ref_evals

    _merge_evidence(results_dir, {
        "fleet": FLEET, "global_batch": GLOBAL_BATCH,
        "total_steps": total_steps, "killed_at_step": DIE_AT_STEP,
        "grow_at_step": grow_step,
        "phases": [out_a, out_b, out_c],
        "bit_identical_to_reference": merged == ref_losses,
        "eval_bit_identical_to_reference": merged_evals == ref_evals,
        "val_mae_per_epoch": ref_evals,
        "durable_history_idempotent": len(d_steps) == len(set(d_steps)),
        # ISSUE 6: phases a/b/c ran the async feed pipeline at staleness 0
        # against a SYNCHRONOUS reference — losses and val_mae identical.
        "prefetch_bit_identical": {
            "prefetch_depth": 2, "staleness": 0,
            "losses": merged == ref_losses,
            "val_mae": merged_evals == ref_evals,
        },
    })


def test_kill_rank0_leader_succession(tmp_path, free_port, mh_spawn,
                                      results_dir):
    """Survive the death of RANK 0 — until this PR the single heartbeat
    decider, checkpoint writer, plan emitter and history sink, whose loss
    therefore killed the whole run.  Process 0 dies mid-epoch; rank 1
    attributes the death via its own transport snapshot, assumes leadership
    (lowest live rank), durably writes the warm-standby checkpoint of the
    exact failure step, decides the shrink plan and flushes the durable
    history — then the usual shrink → resume → grow cycle runs and the
    merged losses/val_mae come out bit-identical to the uninterrupted
    reference.  The victim runs with ``ckpt_every=0`` (no periodic saves),
    so the resume checkpoint can ONLY have been written by the successor:
    the takeover is load-bearing, not a shadow of rank 0's writes."""
    ref = str(tmp_path / "ref")
    run = str(tmp_path / "run")
    os.makedirs(ref)
    os.makedirs(run)

    # ---- reference: uninterrupted single-host run, same 2-device program
    p = mh_spawn(_worker_argv(phase="ref", out=ref, world=FLEET,
                              batch_per_rank=GLOBAL_BATCH // FLEET,
                              elastic=False),
                 devices=2, log=os.path.join(ref, "ref.log"))
    assert _wait(p, timeout=240, what="reference run") == 0
    ref_hist = _read_json(os.path.join(ref, "history_ref.json"))
    ref_losses = _losses(ref_hist)
    ref_evals = _evals(ref_hist)
    total_steps = max(ref_losses)

    # ---- phase KA: the 2-process gang; RANK 0 — the leader — dies.  It
    #      writes no periodic checkpoints (ckpt_every=0), so the only
    #      durable step state can come from rank 1's succession takeover.
    #      The coordination service runs in the driver's own subprocess
    #      (the launcher's fault domain): embedded in rank 0 it would die
    #      with it and the PJRT client would QFATAL every survivor before
    #      succession could run.
    port = free_port()
    coord = mh_spawn(["coordinator", "--out", run, "--nprocs", FLEET,
                      "--coordinator-port", port],
                     log=os.path.join(run, "coord_ka.log"))
    argv = dict(out=run, nprocs=FLEET, world=FLEET,
                batch_per_rank=GLOBAL_BATCH // FLEET, port=port,
                target_world=FLEET, external_coordinator=True)
    p0 = mh_spawn(_worker_argv(phase="ka", rank=0, die_at=DIE_AT_STEP,
                               ckpt_every=0, **argv),
                  devices=1, log=os.path.join(run, "ka0.log"))
    p1 = mh_spawn(_worker_argv(phase="ka", rank=1, **argv),
                  devices=1, log=os.path.join(run, "ka1.log"))
    assert _wait(p0, timeout=240, what="phase KA victim (rank 0)") == EXIT_KILLED
    assert _wait(p1, timeout=240, what="phase KA successor (rank 1)") == EXIT_REMESH
    coord.kill()
    # the outcome file exists at all because rank 1 took over writer duty
    out_a = _read_json(os.path.join(run, "outcome_ka.json"))
    assert out_a["rank"] == 1 and out_a["status"] == "peer-failure"
    assert out_a["dead_workers"] == [0]
    # succession: rank 1 is the leader and DECIDED the shrink itself
    assert out_a["leader_rank"] == 1
    assert out_a["kind"] == "shrink" and out_a["decided_by"] == 1
    assert out_a["shrink_workers"] == [0]
    # checkpoint-writer succession: the takeover wrote the failure step,
    # and it is the ONLY durable checkpoint in the run dir
    assert out_a["ckpt_takeover_step"] == DIE_AT_STEP
    assert _ckpt_steps(run) == [DIE_AT_STEP]
    hist_a = _read_json(os.path.join(run, "history_ka.json"))
    losses_a = _losses(hist_a)
    assert max(losses_a) == DIE_AT_STEP

    # ---- phase KB: the survivor relaunches alone and resumes from the
    #      successor-written checkpoint — no step lost, none repeated.
    pb = mh_spawn(_worker_argv(phase="kb", out=run, world=1,
                               batch_per_rank=GLOBAL_BATCH,
                               target_world=FLEET),
                  devices=2, log=os.path.join(run, "kb.log"))
    deadline = time.time() + 120
    while _hb_step(run, 0) < DIE_AT_STEP + 3:
        assert time.time() < deadline, "phase KB never advanced past resume"
        assert pb.poll() is None, "phase KB exited before the worker returned"
        time.sleep(0.1)
    ann = mh_spawn(["announce", "--out", run, "--rank", 1])
    assert _wait(pb, timeout=240, what="phase KB trainer") == EXIT_REMESH
    ann.kill()
    out_b = _read_json(os.path.join(run, "outcome_kb.json"))
    assert out_b["status"] == "remesh" and out_b["kind"] == "grow"
    assert out_b["readmitted_workers"] == [1]
    losses_b = _losses(_read_json(os.path.join(run, "history_kb.json")))
    assert min(losses_b) == DIE_AT_STEP + 1
    grow_step = out_b["step"]

    # ---- phase KC: the full gang again finishes the run (same decoupled
    #      coordination-service topology, fresh service for the new gang)
    port_c = free_port()
    coord_c = mh_spawn(["coordinator", "--out", run, "--nprocs", FLEET,
                        "--coordinator-port", port_c],
                       log=os.path.join(run, "coord_kc.log"))
    argv_c = dict(out=run, nprocs=FLEET, world=FLEET,
                  batch_per_rank=GLOBAL_BATCH // FLEET, port=port_c,
                  target_world=FLEET, external_coordinator=True)
    c0 = mh_spawn(_worker_argv(phase="kc", rank=0, **argv_c),
                  devices=1, log=os.path.join(run, "kc0.log"))
    c1 = mh_spawn(_worker_argv(phase="kc", rank=1, **argv_c),
                  devices=1, log=os.path.join(run, "kc1.log"))
    assert _wait(c0, timeout=240, what="phase KC rank 0") == 0
    assert _wait(c1, timeout=240, what="phase KC rank 1") == 0
    coord_c.kill()
    out_c = _read_json(os.path.join(run, "outcome_kc.json"))
    assert out_c["status"] == "done"
    losses_c = _losses(_read_json(os.path.join(run, "history_kc.json")))
    assert min(losses_c) == grow_step + 1 and max(losses_c) == total_steps

    # ---- headline: losing the DECIDER costs nothing — merged losses and
    #      eval rows are bit-identical to the uninterrupted reference
    merged = {**losses_a, **losses_b, **losses_c}
    assert sorted(merged) == list(range(1, total_steps + 1))
    assert merged == ref_losses
    merged_evals = {**_evals(hist_a),
                    **_evals(_read_json(os.path.join(run, "history_kb.json"))),
                    **_evals(_read_json(os.path.join(run, "history_kc.json")))}
    assert set(ref_evals) == {0, 1}
    assert merged_evals == ref_evals

    # ---- the ONE durable history.jsonl spans the leader handover too:
    #      rank 0's pre-death rows + the successor's flush + both relaunches
    #      land every row exactly once, equal to the reference.
    durable = _read_jsonl(os.path.join(run, "history.jsonl"))
    d_steps = [h["step"] for h in durable if "epoch_time_s" not in h]
    assert sorted(d_steps) == list(range(1, total_steps + 1))
    assert _losses(durable) == ref_losses
    assert _evals(durable) == ref_evals

    _merge_evidence(results_dir, {
        "eval_bit_identical_to_reference": merged_evals == ref_evals,
        "leader_failover": {
            "killed_rank": 0, "killed_at_step": DIE_AT_STEP,
            "successor": out_a["leader_rank"],
            "attributed_dead": out_a["dead_workers"],
            "shrink_decided_by": out_a["decided_by"],
            "ckpt_takeover_step": out_a["ckpt_takeover_step"],
            "history_rows_flushed": out_a.get("history_rows_flushed"),
            "grow_at_step": grow_step,
            "phases": [out_a, out_b, out_c],
            "bit_identical_to_reference": merged == ref_losses,
            "eval_bit_identical_to_reference": merged_evals == ref_evals,
        },
    })


def test_two_process_feed_assembly_matches_single_host(tmp_path, free_port,
                                                       mh_spawn, results_dir):
    """Minimal data-plane check without faults: an uninterrupted 2-process
    jax.distributed run (per-process feeds + make_array_from_process_local_
    data) is bit-identical to the single-host lock-step simulation."""
    ref = str(tmp_path / "ref")
    run = str(tmp_path / "run")
    os.makedirs(ref)
    os.makedirs(run)
    p = mh_spawn(_worker_argv(phase="ref", out=ref, world=FLEET,
                              batch_per_rank=GLOBAL_BATCH // FLEET,
                              elastic=False),
                 devices=2, log=os.path.join(ref, "ref.log"))
    assert _wait(p, timeout=240, what="single-host reference") == 0
    port = free_port()
    argv = dict(out=run, nprocs=FLEET, world=FLEET,
                batch_per_rank=GLOBAL_BATCH // FLEET, port=port)
    p0 = mh_spawn(_worker_argv(phase="mp", rank=0, **argv),
                  devices=1, log=os.path.join(run, "mp0.log"))
    p1 = mh_spawn(_worker_argv(phase="mp", rank=1, **argv),
                  devices=1, log=os.path.join(run, "mp1.log"))
    assert _wait(p0, timeout=240, what="2-process rank 0") == 0
    assert _wait(p1, timeout=240, what="2-process rank 1") == 0
    ref_hist = _read_json(os.path.join(ref, "history_ref.json"))
    mp_hist = _read_json(os.path.join(run, "history_mp.json"))
    ref_losses, mp_losses = _losses(ref_hist), _losses(mp_hist)
    assert mp_losses == ref_losses
    # eval rode the distributed eval feeds on the 2-process gang: each
    # process scored only its rank-block columns + the replicated tail, and
    # the window-weighted val_mae is bit-identical to the single-host value
    ref_evals, mp_evals = _evals(ref_hist), _evals(mp_hist)
    assert set(ref_evals) == {0, 1}
    assert mp_evals == ref_evals
    with open(os.path.join(results_dir, "multihost_feed_parity.json"),
              "w") as f:
        json.dump({"steps": len(mp_losses),
                   "bit_identical": mp_losses == ref_losses,
                   "eval_bit_identical": mp_evals == ref_evals,
                   "val_mae_per_epoch": ref_evals}, f, indent=1)


def test_serve_fleet_kill_plane_drill(tmp_path, mh_spawn, results_dir):
    """PR 9/10 elastic-serving drill on REAL processes, at temperature > 0:
    two paged serving workers behind a driver-side ``FleetEngine`` over file
    mailboxes + the file heartbeat transport.  Every request decodes SAMPLED
    (request-keyed draws, per-request seeds).  Worker 1 is SIGKILLed
    mid-decode with requests in flight; the coordinator attributes the death
    by beat silence, re-prefills the victim's requests on the survivor from
    prompt + generated prefix — and because draws are keyed by
    (seed, rid, absolute position), the continuation is EXACT even while
    sampling: the whole wave stays bit-identical to the in-process reference
    ``Server``.  A fresh incarnation of worker 1 then re-joins (bumped
    attempt, new spool) and serves a second wave — also bit-identical.
    Evidence merges under ``serve_fleet`` + ``serve_fleet_sampled``."""
    import jax
    import numpy as np

    from repro.configs import LM_ARCHS
    from repro.distributed.transport import FileHeartbeatTransport
    from repro.models.lm import model as lm
    from repro.serve import FileMailbox, FleetEngine, ServeConfig, Server

    run = str(tmp_path / "serve")
    os.makedirs(run)
    SLOTS, MAX_LEN, BUDGET, BS, TEMP = 2, 48, 12, 4, 0.7
    sc = ServeConfig(slots=SLOTS, max_len=MAX_LEN, max_new_tokens=BUDGET,
                     block_size=BS)
    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)  # == every worker's init
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 120, size=int(rng.integers(2, 10)))
               for _ in range(8)]

    # in-process contiguous reference: the bit-identity anchor.  It serves
    # the prompt set TWICE so its rids (0..15) line up with the fleet's two
    # waves — keyed draws fold in the rid, so wave 2's request i (rid 8+i)
    # must be compared against the reference request with the SAME rid.
    srv = Server(params, cfg, ServeConfig(slots=SLOTS, max_len=MAX_LEN,
                                          max_new_tokens=BUDGET))
    for _wave in range(2):
        for i, p in enumerate(prompts):
            srv.submit(p, temperature=TEMP, seed=100 + i)
    ref = srv.run()

    hb = FileHeartbeatTransport(os.path.join(run, "hb"))
    fleet = FleetEngine(sc, world=2, hb_timeout=HB_TIMEOUT,
                        step_feed=lambda: hb.step_feed(0, 2))

    def attach_and_spawn(wid: int, attempt: int):
        spool = os.path.join(run, f"w{wid}_a{attempt}")
        fleet.attach(wid, attempt=attempt,
                     send=FileMailbox(os.path.join(spool, "in")),
                     recv=FileMailbox(os.path.join(spool, "out")))
        return mh_spawn(
            ["serve-worker", "--out", run, "--rank", wid,
             "--attempt", attempt, "--slots", SLOTS, "--max-len", MAX_LEN,
             "--max-new", BUDGET, "--block-size", BS,
             "--step-delay", 0.05],
            devices=1, log=os.path.join(run, f"w{wid}_a{attempt}.log"))

    procs = {wid: attach_and_spawn(wid, 0) for wid in range(2)}

    # wait out jax import/compile before racing the heartbeat timeout
    deadline = time.time() + 240
    while _hb_step(run, 0) < 0 or _hb_step(run, 1) < 0:
        assert time.time() < deadline, "serve workers never came up"
        time.sleep(0.1)

    # ---- wave 1: kill worker 1 the moment it has partial output in flight
    rids = [fleet.submit(p, temperature=TEMP, seed=100 + i)
            for i, p in enumerate(prompts)]
    killed_with: list[int] = []
    while fleet.pending():
        fleet.tick()
        if not killed_with:
            infl = fleet.workers[1].inflight
            partial = [len(r.out) for r, _ in infl.values()
                       if 0 < len(r.out) < r.budget]
            if partial:
                procs[1].kill()  # SIGKILL mid-decode: beats stop dead
                procs[1].wait()
                killed_with = partial
        assert time.time() < deadline, "wave 1 never drained"
        time.sleep(0.05)
    assert killed_with, "kill window missed: worker 1 never held partial work"
    res = fleet.results()
    wave1_ok = all(res[rid] == ref[rid] for rid in rids)
    assert wave1_ok, "wave 1 diverged from the reference after the kill"
    survivor_served = fleet.workers[0].served
    assert fleet.workers[1].served + survivor_served == len(prompts)

    # ---- rejoin: fresh incarnation of worker 1 (attempt 1, fresh spool);
    #      its resumed beats flip the tracker live again before wave 2
    procs[1] = attach_and_spawn(1, 1)
    while 1 not in set(fleet.tracker.live()):
        assert time.time() < deadline, "worker 1 never re-joined"
        fleet.tick()
        time.sleep(0.1)

    rids2 = [fleet.submit(p, temperature=TEMP, seed=100 + i)
             for i, p in enumerate(prompts)]
    while fleet.pending():
        fleet.tick()
        assert time.time() < deadline, "wave 2 never drained"
        time.sleep(0.05)
    res2 = fleet.results()
    wave2_ok = all(res2[rid] == ref[rid] for rid in rids2)
    assert wave2_ok, "wave 2 diverged after the rejoin"
    rejoined_served = fleet.workers[1].served
    assert rejoined_served > 0, "returned worker was never assigned work"

    fleet.stop_workers()
    assert _wait(procs[0], timeout=60, what="serve worker 0 stop") == 0
    assert _wait(procs[1], timeout=60, what="serve worker 1 stop") == 0

    _merge_evidence(results_dir, {
        "serve_fleet": {
            "workers": 2, "slots_per_worker": SLOTS, "block_size": BS,
            "requests_per_wave": len(prompts), "budget": BUDGET,
            "killed_worker": 1, "partial_tokens_at_kill": killed_with,
            "survivor_served_wave1": survivor_served,
            "rejoined_served_wave2": rejoined_served,
            "wave1_bit_identical": wave1_ok,
            "wave2_bit_identical": wave2_ok,
        },
        # PR 10: the SAME drill ran with sampled decoding — the restore
        # across a SIGKILL is exact at temperature > 0, not just greedy
        "serve_fleet_sampled": {
            "temperature": TEMP,
            "per_request_seeds": [100 + i for i in range(len(prompts))],
            "wave1_bit_identical_across_kill": wave1_ok,
            "wave2_bit_identical_after_rejoin": wave2_ok,
        },
    })


# ====================================================================== main
def _main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("role", choices=["worker", "announce", "coordinator",
                                     "serve-worker"])
    ap.add_argument("--phase", default="run")
    ap.add_argument("--out", required=True)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--nprocs", type=int, default=1)
    ap.add_argument("--coordinator-port", type=int, default=0)
    ap.add_argument("--world", type=int, default=FLEET)
    ap.add_argument("--batch-per-rank", type=int,
                    default=GLOBAL_BATCH // FLEET)
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--die-at-step", type=int, default=0)
    ap.add_argument("--target-world", type=int, default=0)
    ap.add_argument("--hb-timeout", type=float, default=HB_TIMEOUT)
    ap.add_argument("--step-delay", type=float, default=STEP_DELAY)
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="async feed pipeline depth (0 = synchronous); the "
                         "grow test runs its interrupted phases pipelined "
                         "at staleness 0 against a synchronous reference — "
                         "the distributed staleness-0 identity (ISSUE 6)")
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="step-checkpoint cadence; 0 disables periodic "
                         "saves (the kill-rank-0 phase runs its victim "
                         "with 0 so the resume point can ONLY come from "
                         "the successor's takeover checkpoint)")
    ap.add_argument("--external-coordinator", action="store_true",
                    help="the PJRT coordination service is hosted by the "
                         "driver's coordinator subprocess, not process 0 "
                         "(required for a survivable rank-0 death)")
    # serve-worker knobs (PR 9 elastic-serving drill)
    ap.add_argument("--attempt", type=int, default=0,
                    help="mailbox incarnation of this serve worker; the "
                         "coordinator bumps it on every relaunch so a "
                         "returned host never re-reads its ghost's spool")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged-KV block size (0 = contiguous lanes)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="usable paged-pool blocks (0 = contiguous capacity)")
    args = ap.parse_args()
    if args.role == "announce":
        _run_announcer(args)
    elif args.role == "coordinator":
        _run_coordinator(args)
    elif args.role == "serve-worker":
        _run_serve_worker(args)
    else:
        _run_worker(args)


if __name__ == "__main__":
    _main()
