"""Async feed prefetch pipeline (ISSUE 6): unit pins on the two-stage
:class:`~repro.pipeline.prefetch.FeedPrefetcher` plus the end-to-end
staleness identities through ``Engine.fit``:

- the prefetcher yields ``transfer(row)`` for every feed row IN ORDER, at
  every (depth, staleness, chunk) combination;
- staleness 0 runs the transfer on the CALLER thread (the synchronous op
  order — the identity's mechanism), staleness >= 1 on the transfer thread;
- stage 1's run-ahead is bounded by ``depth`` blocks;
- background errors surface at the consumer, ``close()`` is idempotent and
  closes the source generator (the drain the elastic re-mesh relies on);
- a pipelined fit at staleness 0 AND 1 is bit-identical to the synchronous
  fit — losses and final state — including straight through an elastic
  shrink (the in-process fault harness from test_elastic_engine);
- the DataPlane's replicated eval-tail row is built once and cached.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Placement, WindowSpec
from repro.data import make_traffic_series
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamConfig
from repro.pipeline import (ElasticConfig, FeedPrefetcher, PipelineConfig,
                            PrefetchPlan, build_dataplane, build_pipeline)
from repro.train import TrainLoopConfig

# ---------------------------------------------------------------- PrefetchPlan


def test_plan_defaults_and_validation():
    plan = PrefetchPlan()
    assert (plan.depth, plan.staleness, plan.chunk) == (2, 0, 8)
    with pytest.raises(ValueError, match="depth"):
        PrefetchPlan(depth=0)
    with pytest.raises(ValueError, match="staleness"):
        PrefetchPlan(staleness=-1)
    with pytest.raises(ValueError, match="chunk"):
        PrefetchPlan(chunk=0)


# ------------------------------------------------------- FeedPrefetcher units

def _blocks(n_rows: int, chunk: int, width: int = 3):
    """A grid_stream-shaped iterator: [<=chunk, width] blocks of row ids."""
    grid = np.arange(n_rows * width).reshape(n_rows, width)
    for lo in range(0, n_rows, chunk):
        yield grid[lo:lo + chunk]


@pytest.mark.parametrize("staleness", [0, 1, 3])
@pytest.mark.parametrize("depth,chunk", [(1, 1), (2, 4), (3, 7)])
def test_yields_every_row_in_order(staleness, depth, chunk):
    n_rows = 17  # deliberately not a multiple of any chunk above
    got = list(FeedPrefetcher(
        _blocks(n_rows, chunk), lambda row: row * 10,
        PrefetchPlan(depth=depth, staleness=staleness, chunk=chunk)))
    assert len(got) == n_rows
    expect = np.arange(n_rows * 3).reshape(n_rows, 3) * 10
    assert np.array_equal(np.stack(got), expect)


@pytest.mark.parametrize("staleness,same_thread", [(0, True), (1, False)])
def test_transfer_thread_matches_staleness_contract(staleness, same_thread):
    """staleness 0 transfers on the consumer thread (the exact synchronous
    op order — what makes the identity provable); staleness >= 1 moves the
    transfer onto the dedicated stage-2 thread."""
    idents = set()

    def transfer(row):
        idents.add(threading.get_ident())
        return row

    list(FeedPrefetcher(_blocks(6, 2), transfer,
                        PrefetchPlan(staleness=staleness)))
    assert (threading.get_ident() in idents) == same_thread
    if not same_thread:
        assert len(idents) == 1  # one transfer thread, not many


def test_host_stage_runahead_bounded_by_depth():
    """Stage 1 may hold at most ``depth`` queued blocks plus the one block
    in its hand — consuming nothing must not materialize the whole epoch."""
    pulled = [0]

    def counting_blocks():
        for b in _blocks(100, 1):
            pulled[0] += 1
            yield b

    depth = 3
    pf = FeedPrefetcher(counting_blocks(), lambda r: r,
                        PrefetchPlan(depth=depth, staleness=0, chunk=1))
    deadline = time.monotonic() + 2.0
    while pulled[0] < depth + 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)  # would overshoot here if the bound were broken
    assert pulled[0] == depth + 1
    pf.close()
    assert pulled[0] <= depth + 2


@pytest.mark.parametrize("staleness", [0, 1])
def test_source_error_surfaces_at_consumer(staleness):
    def broken():
        yield np.zeros((2, 3), np.int32)
        raise RuntimeError("feed exploded")

    pf = FeedPrefetcher(broken(), lambda r: r, PrefetchPlan(staleness=staleness))
    with pytest.raises(RuntimeError, match="feed exploded"):
        list(pf)


def test_transfer_error_surfaces_at_consumer():
    def bad_transfer(row):
        raise ValueError("transfer exploded")

    pf = FeedPrefetcher(_blocks(4, 2), bad_transfer, PrefetchPlan(staleness=1))
    with pytest.raises(ValueError, match="transfer exploded"):
        list(pf)


@pytest.mark.parametrize("staleness", [0, 2])
def test_close_is_idempotent_and_closes_source(staleness):
    closed = []

    def tracked():
        try:
            yield from _blocks(50, 2)
        finally:
            closed.append(True)

    pf = FeedPrefetcher(tracked(), lambda r: r,
                        PrefetchPlan(staleness=staleness))
    next(pf)  # pipeline is live
    pf.close()
    pf.close()  # second drain is a no-op, not an error
    assert closed == [True]
    with pytest.raises(StopIteration):
        next(pf)
    for t in (pf._host_thread, pf._dev_thread):
        assert t is None or not t.is_alive()


# --------------------------------------------------- end-to-end fit identity

NODES, ENTRIES, B, WORLD = 3, 120, 2, 4
SPEC = WindowSpec(horizon=2, input_len=2)


def _loss_fn(p, x, y):
    pred = x[:, -1] * p["w"]
    return jnp.mean((pred - y[:, 0]) ** 2), {}


def _fit(depth: int, stale: int, *, placement=Placement.REPLICATED,
         world=WORLD, chunk: int = 8):
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, {"w": jnp.full((NODES, 2), 0.1, jnp.float32)},
        PipelineConfig(batch_per_rank=B, placement=placement, world=world,
                       seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=2, log_every=1,
                                            eval_every=0,
                                            prefetch_depth=depth,
                                            staleness=stale,
                                            prefetch_chunk=chunk)))
    state, hist = pipe.fit(eval_fn=None)
    return state, [h["loss"] for h in hist if "loss" in h]


@pytest.mark.parametrize("placement",
                         [Placement.REPLICATED, Placement.PARTITIONED])
@pytest.mark.parametrize("stale,chunk", [(0, 8), (0, 3), (1, 8), (2, 5)])
def test_pipelined_fit_bit_identical_to_synchronous(placement, stale, chunk):
    """The acceptance identity, in-process: at staleness 0 the pipeline is
    bit-identical BY CONSTRUCTION (same caller-thread op order); at
    staleness >= 1 it is still bit-identical HERE because feeds are pure and
    the same bytes reach the same compiled program — only the timing moves."""
    ref_state, ref_losses = _fit(0, 0, placement=placement)
    state, losses = _fit(2, stale, placement=placement, chunk=chunk)
    assert losses == ref_losses
    for a, b in zip(jax.tree.leaves(ref_state), jax.tree.leaves(state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_elastic_shrink_bit_identical(tmp_path):
    """kill→shrink with the prefetcher ON: the engine drains the in-flight
    pipeline at the RestartSignal, re-meshes, and resumes — and the whole
    trajectory is bit-identical to the synchronous elastic run (same fault
    schedule, same checkpoints).  The real 2-process version of this pin is
    tests/multihost.py's ``prefetch_bit_identical`` evidence."""
    from tests.test_elastic_engine import OneDeadWorker

    def run(tag: str, depth: int, stale: int):
        clock = [0.0]
        pipe = build_pipeline(
            make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
            _loss_fn, {"w": jnp.full((NODES, 2), 0.1, jnp.float32)},
            PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                           world=WORLD, seed=7, adam=AdamConfig(lr=1e-2),
                           loop=TrainLoopConfig(epochs=2, log_every=1,
                                                ckpt_dir=str(tmp_path / tag),
                                                prefetch_depth=depth,
                                                staleness=stale)),
            elastic=ElasticConfig(heartbeat_timeout=50.0,
                                  clock=lambda: clock[0],
                                  step_feed=OneDeadWorker(clock)))
        state, hist = pipe.fit(eval_fn=None)
        assert len(pipe.restarts) == 1  # the fault actually fired
        return state, [(h["step"], h["loss"]) for h in hist if "loss" in h]

    ref_state, ref_losses = run("sync", 0, 0)
    for stale in (0, 1):
        state, losses = run(f"s{stale}", 2, stale)
        assert losses == ref_losses
        for a, b in zip(jax.tree.leaves(ref_state), jax.tree.leaves(state)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- DataPlane prefetch API

def _plane(world=1, placement=Placement.REPLICATED):
    return build_dataplane(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        PipelineConfig(batch_per_rank=B, placement=placement, world=world,
                       seed=7))


def test_eval_tail_batch_cached_once():
    dp = _plane(world=4)  # val pool 12, global batch 8 -> ragged tail of 4
    n, batch = dp.eval_tail_batch("val")
    assert n == len(dp.eval_tail("val")) and n > 0
    assert np.array_equal(
        np.asarray(batch), np.asarray(
            dp.batch_of_starts(dp.eval_tail("val"), replicate=True)))
    n2, batch2 = dp.eval_tail_batch("val")
    assert n2 == n and batch2 is batch  # the cached row, not a rebuild


def test_prefetch_transfer_selects_mode():
    dp = _plane()
    assert dp.prefetch_transfer(0) == dp.batch_of_starts
    if dp.can_defer_transfer():
        assert dp.prefetch_transfer(1) == dp.host_batch_of_starts
        row = dp.epoch_global(0)[0]
        # deferred mode: host bytes equal the committed device batch's bytes
        assert np.array_equal(dp.host_batch_of_starts(row),
                              np.asarray(dp.batch_of_starts(row)))
    sharded = _plane(world=2, placement=Placement.PARTITIONED)
    if not sharded.can_defer_transfer():
        assert sharded.prefetch_transfer(1) == sharded.batch_of_starts


def test_grid_stream_resumes_mid_epoch():
    """grid_stream(start=k) is the suffix the engine consumes after an
    elastic resume lands mid-epoch."""
    dp = _plane(world=2)
    grid = dp.epoch_grid(3)
    rows = np.concatenate(list(dp.grid_stream(3, start=2, chunk=3)))
    assert np.array_equal(rows, grid[2:])
