"""The paper's core claim, proven structurally: index-batching feeds the model
BIT-IDENTICAL batches to materialised (Alg.-1) batching — so accuracy parity
(paper Fig. 5 / Table 3) holds by construction."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (IndexDataset, WindowSpec, gather_batch,
                        gather_batch_fused, gather_batch_take, lm_window_batch,
                        materialize_windows)
from repro.data import make_traffic_series


@st.composite
def window_case(draw):
    t = draw(st.integers(20, 120))
    n = draw(st.integers(1, 8))
    f = draw(st.integers(1, 3))
    in_len = draw(st.integers(1, 6))
    hor = draw(st.integers(1, 6))
    if in_len + hor >= t:
        in_len, hor = 2, 2
    b = draw(st.integers(1, 8))
    last = t - (in_len + hor)
    starts = draw(st.lists(st.integers(0, last), min_size=b, max_size=b))
    return t, n, f, in_len, hor, np.asarray(starts, np.int32)


@given(window_case())
@settings(max_examples=60, deadline=None)
def test_index_equals_materialized(case):
    """Property: every (x, y) from the index path == the Alg.-1 snapshot."""
    t, n, f, in_len, hor, starts = case
    series = np.random.default_rng(42).standard_normal((t, n, f)).astype(np.float32)
    xs, ys = materialize_windows(series, starts, in_len, hor)
    xg, yg = gather_batch(jnp.asarray(series), jnp.asarray(starts),
                          input_len=in_len, horizon=hor)
    assert np.array_equal(xs, np.asarray(xg))
    assert np.array_equal(ys, np.asarray(yg))


@given(window_case())
@settings(max_examples=30, deadline=None)
def test_gather_variants_agree(case):
    """dynamic-slice, fused-span and take-based gathers are interchangeable."""
    t, n, f, in_len, hor, starts = case
    series = jnp.asarray(
        np.random.default_rng(7).standard_normal((t, n, f)).astype(np.float32))
    s = jnp.asarray(starts)
    a = gather_batch(series, s, input_len=in_len, horizon=hor)
    b = gather_batch_take(series, s, input_len=in_len, horizon=hor)
    c = gather_batch_fused(series, s, input_len=in_len, horizon=hor)
    d = gather_batch_fused(series, s, input_len=in_len, horizon=hor,
                           use_pallas=True)
    for other in (b, c, d):
        assert np.array_equal(np.asarray(a[0]), np.asarray(other[0]))
        assert np.array_equal(np.asarray(a[1]), np.asarray(other[1]))


def test_lm_window_batch_shift():
    stream = jnp.arange(100, dtype=jnp.int32)
    toks, labels = lm_window_batch(stream, jnp.asarray([0, 10]), seq_len=5)
    assert np.array_equal(np.asarray(toks), [[0, 1, 2, 3, 4], [10, 11, 12, 13, 14]])
    assert np.array_equal(np.asarray(labels), [[1, 2, 3, 4, 5], [11, 12, 13, 14, 15]])


def test_index_dataset_accounting():
    series = make_traffic_series(300, 10)
    spec = WindowSpec(horizon=6, input_len=6)
    ds = IndexDataset.from_raw(series, spec)
    assert ds.n_windows == 300 - 12 + 1
    # the compact representation is much smaller than materialised snapshots
    assert ds.nbytes_index() < 0.15 * ds.nbytes_materialized()
    # splits follow the paper's 70/10/20
    assert len(ds.train_windows) == round(ds.n_windows * 0.7)


def test_index_dataset_standardisation_matches_alg1():
    """Normalising the single series == normalising every snapshot (Alg. 1)."""
    raw = make_traffic_series(200, 5)
    spec = WindowSpec(horizon=4)
    ds = IndexDataset.from_raw(raw, spec)
    x, _ = gather_batch(jnp.asarray(ds.series), jnp.asarray(ds.starts[:10]),
                        input_len=4, horizon=4)
    # manually standardise the raw snapshots with the same scaler
    xs, _ = materialize_windows(raw, ds.starts[:10], 4, 4)
    xs = xs.copy()
    xs[..., 0] = (xs[..., 0] - ds.scaler.mean) / ds.scaler.std
    assert np.allclose(np.asarray(x), xs, atol=1e-6)


def test_to_device_is_single_transfer():
    ds = IndexDataset.from_raw(make_traffic_series(50, 4), WindowSpec(horizon=3))
    ds2 = ds.to_device()
    assert isinstance(ds2.series, jnp.ndarray)
    assert np.allclose(np.asarray(ds2.series), ds.series)
