"""Window math + the paper's memory model (eqs. 1 & 2, Table 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import windows as W
from repro.data.registry import TABLE1


def test_window_counts_exact():
    spec = W.WindowSpec(horizon=3)
    # series of 10 steps, span 6 -> starts 0..4
    assert W.num_windows(10, spec) == 5
    assert list(W.window_starts(10, spec)) == [0, 1, 2, 3, 4]


def test_window_counts_paper_vs_exact():
    # T' == T == horizon: paper count == exact count
    spec = W.WindowSpec(horizon=12)
    assert W.num_windows(1000, spec, "exact") == W.num_windows(1000, spec, "paper")
    # differs when input_len != horizon
    spec2 = W.WindowSpec(horizon=3, input_len=5)
    assert W.num_windows(100, spec2, "exact") == 100 - 8 + 1


@given(entries=st.integers(1, 500), horizon=st.integers(1, 20),
       stride=st.integers(1, 5))
@settings(max_examples=200, deadline=None)
def test_window_starts_all_valid(entries, horizon, stride):
    spec = W.WindowSpec(horizon=horizon, stride=stride)
    starts = W.window_starts(entries, spec)
    # every start admits a full (x, y) span
    assert all(s + spec.span <= entries for s in starts)
    # maximal: one more window would overflow
    if len(starts) and stride == 1:
        assert starts[-1] + spec.span == entries


def test_eq1_memory_growth_formula():
    """Paper eq. (1): size = 2[(entries − (2h−1)) × h × nodes × features]."""
    e, h, n, f = 1000, 12, 50, 2
    spec = W.WindowSpec(horizon=h)
    got = W.materialized_bytes(e, n, f, spec, dtype_bytes=8, counting="paper")
    expect = 2 * (e - (2 * h - 1)) * h * n * f * 8
    assert got == expect


def test_eq2_index_batching_formula():
    e, h, n, f = 1000, 12, 50, 2
    spec = W.WindowSpec(horizon=h)
    got = W.index_batching_bytes(e, n, f, spec, dtype_bytes=8, index_bytes=8,
                                 counting="paper")
    assert got == e * n * f * 8 + (e - (2 * h - 1)) * 8


@pytest.mark.parametrize("name,rel_tol", [
    ("metr-la", 0.01), ("pems-bay", 0.01), ("pems-all-la", 0.01), ("pems", 0.001),
])
def test_table1_post_preprocessing_sizes(name, rel_tol):
    """Reproduce the paper's Table 1 'Size After Preprocessing' (f64, GiB).

    Table 1 numbers match entries − 2·horizon windows (DESIGN.md §7).
    """
    d = TABLE1[name]
    spec = W.WindowSpec(horizon=d.horizon)
    got = W.materialized_bytes(d.entries, d.nodes, d.features, spec,
                               dtype_bytes=8, counting="table")
    assert got == pytest.approx(d.table1_post_bytes, rel=rel_tol), (
        f"{name}: {got / 2**30:.2f} GiB vs paper {d.table1_post_bytes / 2**30:.2f}")


def test_pems_memory_reduction_89pct():
    """The paper's headline: up to 89% peak-memory reduction on PeMS-scale data."""
    d = TABLE1["pems"]
    spec = W.WindowSpec(horizon=d.horizon)
    red = W.memory_reduction(d.entries, d.nodes, d.features, spec)
    assert red > 0.89


@given(n=st.integers(1, 1000), train=st.floats(0.1, 0.8),
       val=st.floats(0.0, 0.19))
@settings(max_examples=100, deadline=None)
def test_split_partitions(n, train, val):
    tr, va, te = W.split_windows(n, train, val)
    joined = np.concatenate([tr, va, te])
    assert np.array_equal(joined, np.arange(n))
