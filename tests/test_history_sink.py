"""JsonlHistorySink: crash-durable, resume-idempotent history (ISSUE 4
satellite bugfix).

An exit-75 relaunch restores the latest checkpoint, which may sit BEFORE
rows that were already logged (ckpt_every coarser than log_every, or a crash
between a mid-epoch checkpoint and the epoch summary).  The resumed run then
re-RUNS the tail of the epoch — training needs the steps — and re-logs step
rows and the epoch summary (with its eval metrics) under the same
``(epoch, step)`` coordinates.  The sink must keep the durable history free
of those duplicates while still accepting every genuinely new row.
"""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.train.loop import JsonlHistorySink, TrainLoopConfig, run_training


class _StubSampler:
    steps_per_epoch = 4

    def epoch_global(self, epoch):
        return np.arange(4)[:, None] + 10 * epoch


def _stub_step(state, batch):
    return state, {"loss": jnp.asarray(float(batch[0]))}


def _run(sink, *, start_epoch=0, start_step=0, start_done=None, eval_fn=None):
    return run_training(
        state={}, train_step=_stub_step, sampler=_StubSampler(),
        batch_of_starts=lambda row: row,
        loop=TrainLoopConfig(epochs=1, log_every=1),
        eval_fn=eval_fn, start_epoch=start_epoch, start_step=start_step,
        start_done_in_epoch=start_done, history_sink=sink)


def test_sink_rows_are_durable_and_loadable(tmp_path):
    path = str(tmp_path / "h.jsonl")
    sink = JsonlHistorySink(path)
    _run(sink, eval_fn=lambda st: {"val_mae": 1.25})
    sink.close()
    durable = JsonlHistorySink(path).load()
    assert durable == sink.rows
    steps = [r["step"] for r in durable if "epoch_time_s" not in r]
    assert steps == [1, 2, 3, 4]
    summaries = [r for r in durable if "epoch_time_s" in r]
    assert len(summaries) == 1 and summaries[0]["val_mae"] == 1.25


def test_sink_suppresses_duplicate_rows_on_resume(tmp_path):
    """Simulated crash-after-summary: the first incarnation logged the whole
    epoch (summary + eval row included) but the last durable checkpoint was
    mid-epoch, so the relaunch resumes at done=2 and re-runs steps 3..4 and
    the summary.  The durable file must contain each row exactly once."""
    path = str(tmp_path / "h.jsonl")
    first = JsonlHistorySink(path)
    _run(first, eval_fn=lambda st: {"val_mae": 2.0})
    first.close()

    relaunch = JsonlHistorySink(path)  # fresh process, same durable file
    _run(relaunch, start_step=2, start_done=2,
         eval_fn=lambda st: {"val_mae": 2.0})
    assert relaunch.rows == []  # everything it re-logged was already durable
    relaunch.close()

    durable = JsonlHistorySink(path).load()
    keys = [(("summary" if "epoch_time_s" in r else "step"),
             r.get("epoch"), r.get("step")) for r in durable]
    assert len(keys) == len(set(keys))
    steps = [r["step"] for r in durable if "epoch_time_s" not in r]
    assert steps == [1, 2, 3, 4]
    assert sum("epoch_time_s" in r for r in durable) == 1


def test_sink_accepts_new_rows_after_resume(tmp_path):
    """A resume that runs PAST the previously-durable point keeps appending:
    only the overlap is suppressed, nothing new is lost."""
    path = str(tmp_path / "h.jsonl")
    first = JsonlHistorySink(path)
    # first incarnation crashed after logging steps 1..2 (no summary yet)
    first.append({"step": 1, "epoch": 0, "loss": 0.5})
    first.append({"step": 2, "epoch": 0, "loss": 0.4})
    first.close()
    relaunch = JsonlHistorySink(path)
    _run(relaunch)  # full epoch re-run: logs steps 1..4 + summary
    # the overlap (1..2) was suppressed; the new tail and summary landed
    assert [r["step"] for r in relaunch.rows
            if "epoch_time_s" not in r] == [3, 4]
    assert sum("epoch_time_s" in r for r in relaunch.rows) == 1
    relaunch.close()
    durable = JsonlHistorySink(path).load()
    assert sorted(r["step"] for r in durable
                  if "epoch_time_s" not in r) == [1, 2, 3, 4]
    assert sum("epoch_time_s" in r for r in durable) == 1


def test_sink_tolerates_torn_final_line(tmp_path):
    """A crash mid-write leaves a torn last line: the row was not durable,
    so the reload drops it and the relaunch may re-log it."""
    path = str(tmp_path / "h.jsonl")
    sink = JsonlHistorySink(path)
    sink.append({"step": 1, "epoch": 0, "loss": 0.5})
    sink.close()
    with open(path, "a") as f:
        f.write('{"step": 2, "epoch": 0, "lo')  # torn by the "crash"
    relaunch = JsonlHistorySink(path)
    assert [r["step"] for r in relaunch.load()] == [1]
    assert relaunch.append({"step": 2, "epoch": 0, "loss": 0.25})  # re-logged
    assert not relaunch.append({"step": 1, "epoch": 0, "loss": 0.5})
    relaunch.close()
    assert [r["step"] for r in JsonlHistorySink(path).load()] == [1, 2]


def test_sink_is_a_dropin_for_the_list_protocol(tmp_path):
    """run_training only calls .append(row); the sink's accepted-row list
    mirrors exactly what a plain-list sink would have captured on a fresh
    run."""
    path = str(tmp_path / "h.jsonl")
    plain: list = []
    _run(plain)
    sink = JsonlHistorySink(path)
    _run(sink)
    sink.close()
    strip = lambda rows: [{k: v for k, v in r.items() if k != "epoch_time_s"}
                          for r in rows]
    assert strip(sink.rows) == strip(plain)
