"""Heartbeat transports (``repro.distributed.transport``): the file and TCP
transports must emit exactly the events the :class:`HeartbeatMonitor`
consumes — and crucially, ``step_feed`` must only report ranks that beat
SINCE THE LAST POLL, or a dead worker's stale file would keep refreshing its
liveness and the monitor could never flag it.

The integration test drives a real pipeline through a real file transport
end-to-end: the emitter hook writes beats, ``step_feed`` reads them back,
and a worker that stops emitting is flagged, shrunk away, and re-admitted
when its beats resume — the same chain ``tests/multihost.py`` runs over real
processes.
"""
import json
import os
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed import (FileHeartbeatTransport, TcpHeartbeatCollector,
                               TcpHeartbeatEmitter, make_transport)


# ------------------------------------------------------------- file transport
def test_file_transport_reports_only_fresh_beats(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 5)
    t.emit(1, 5, step_time=0.25)
    assert t.step_feed(5, 2) == {0: (5, None), 1: (5, 0.25)}
    # no new beats since the poll: nothing reported (stale ≠ alive)
    assert t.step_feed(6, 2) == {}
    t.emit(0, 6)
    assert t.step_feed(6, 2) == {0: (6, None)}


def test_file_transport_same_step_rebeat_is_fresh(tmp_path):
    """A re-announced step (worker restarted and re-sent step 0) must still
    count as a fresh beat — freshness is keyed on the emit seq, not step."""
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 0)
    assert t.step_feed(0, 1) == {0: (0, None)}
    t.emit(0, 0)
    assert t.step_feed(1, 1) == {0: (0, None)}


def test_file_transport_cross_instance_and_unknown_ranks(tmp_path):
    """Separate transport instances over one directory see each other's
    beats (that IS the same-host multi-process design), including ranks
    outside the poller's world — a returned worker announcing itself.
    Only beats emitted AFTER the poller was built count."""
    monitor = FileHeartbeatTransport(str(tmp_path))
    worker = FileHeartbeatTransport(str(tmp_path))
    worker.emit(0, 3)
    worker.emit(7, 3)  # rank 7 of a 2-world poll: an outsider
    beats = monitor.step_feed(3, 2)
    assert beats == {0: (3, None), 7: (3, None)}


def test_file_transport_ignores_beats_predating_the_poller(tmp_path):
    """A RELAUNCHED trainer reuses the shared heartbeat directory: a dead
    worker's stale file must not read as a fresh beat on the first poll —
    else every relaunch would instantly plan a spurious grow toward a
    worker that is still down.  Only post-construction emits report."""
    before = FileHeartbeatTransport(str(tmp_path))
    before.emit(1, 7)   # the dead worker's last beat, pre-relaunch
    relaunched = FileHeartbeatTransport(str(tmp_path))
    assert relaunched.step_feed(8, 1) == {}           # stale: not reported
    before.emit(1, 0)   # the worker REALLY returns (fresh emit, any step)
    assert relaunched.step_feed(9, 1) == {1: (0, None)}


def test_file_transport_snapshot_ages(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 9)
    snap = t.snapshot()
    assert snap[0]["step"] == 9
    assert 0 <= snap[0]["age"] < 5.0


def test_file_transport_ignores_torn_writes(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 1)
    with open(os.path.join(str(tmp_path), "hb_1.json"), "w") as f:
        f.write('{"rank": 1, "st')  # torn mid-write
    assert t.step_feed(1, 2) == {0: (1, None)}


# -------------------------------------------------------------- tcp transport
def _poll_until(fn, *, timeout=5.0):
    deadline = time.time() + timeout
    acc = {}
    while time.time() < deadline:
        acc.update(fn())
        if acc:
            return acc
        time.sleep(0.01)
    return acc


def test_tcp_transport_round_trip():
    coll = TcpHeartbeatCollector(port=0)
    try:
        em = TcpHeartbeatEmitter(coll.address)
        em.emit(1, 4, step_time=0.5)
        beats = _poll_until(lambda: coll.step_feed(4, 2))
        assert beats == {1: (4, 0.5)}
        # the collector can emit for its own local ranks without dialling
        coll.emit(0, 4)
        assert coll.step_feed(4, 2) == {0: (4, None)}
        assert coll.step_feed(5, 2) == {}  # nothing fresh
        em.close()
    finally:
        coll.close()


def test_tcp_emitter_survives_dead_collector():
    """Emit must be fire-and-forget: a vanished collector cannot take the
    training loop down — silence is the signal, not an exception."""
    coll = TcpHeartbeatCollector(port=0)
    addr = coll.address
    coll.close()
    em = TcpHeartbeatEmitter(addr)
    em.emit(0, 1)  # must not raise
    em.close()


def test_make_transport_factory(tmp_path):
    t = make_transport(f"file:{tmp_path}")
    assert isinstance(t, FileHeartbeatTransport)
    coll = make_transport("tcp://127.0.0.1:0", serve=True)
    try:
        assert isinstance(coll, TcpHeartbeatCollector)
        em = make_transport(coll.address and f"tcp://{coll.address}")
        assert isinstance(em, TcpHeartbeatEmitter)
        em.close()
    finally:
        coll.close()
    with pytest.raises(ValueError, match="heartbeat transport"):
        make_transport("carrier-pigeon:/loft")


# ------------------------------------------------------- chaos property tests
# The contracts every transport must hold under ADVERSARIAL interleavings —
# concurrent emitters racing the poller, duplicate/out-of-order seqs from
# restarted emitters, torn beat files, emitters outliving a collector.
# Leader succession leans on these: every survivor derives its verdict from
# this state, so the contracts must hold on every process, not just rank 0.
# (hypothesis where installed; the conftest seeded fallback otherwise.)

@settings(max_examples=15, deadline=None)
@given(plans=st.lists(st.lists(st.integers(0, 60), min_size=1, max_size=6),
                      min_size=1, max_size=5))
def test_file_transport_concurrent_emitters_chaos(plans):
    """One emitter thread per rank (the real topology: every rank has
    exactly one owner) hammers a shared directory while the monitor polls
    concurrently.  Under every interleaving: polls never crash on
    mid-replace files, report only ranks that actually emitted — with step
    values those ranks actually sent — every rank's LAST beat is
    eventually reported, and a quiescent transport reports nothing."""
    import tempfile
    d = tempfile.mkdtemp()
    monitor = FileHeartbeatTransport(d)
    emitters = [FileHeartbeatTransport(d) for _ in plans]
    polled: list[dict] = []
    stop = threading.Event()

    def poll_loop():
        while not stop.is_set():
            polled.append(monitor.step_feed(0, len(plans)))

    def emit_loop(rank, steps):
        for s in steps:
            emitters[rank].emit(rank, s)

    poller = threading.Thread(target=poll_loop)
    workers = [threading.Thread(target=emit_loop, args=(r, steps))
               for r, steps in enumerate(plans)]
    poller.start()
    for t in workers:
        t.start()
    for t in workers:
        t.join()
    stop.set()
    poller.join()
    polled.append(monitor.step_feed(0, len(plans)))  # drain the final state
    reported: dict[int, list] = {}
    for beats in polled:
        for rank, (step, _) in beats.items():
            reported.setdefault(rank, []).append(step)
    assert set(reported) == set(range(len(plans)))
    for rank, steps in enumerate(plans):
        assert set(reported[rank]) <= set(steps)
        assert reported[rank][-1] == steps[-1]
    assert monitor.step_feed(1, len(plans)) == {}    # stale ≠ alive


@settings(max_examples=25, deadline=None)
@given(seqs=st.lists(st.integers(1, 5), min_size=1, max_size=8))
def test_file_transport_seq_gate_duplicates_and_reordering(seqs):
    """The freshness gate is `seq CHANGED since the last poll`: a re-written
    identical seq is silent, ANY change — including a seq going BACKWARDS,
    a restarted emitter re-counting from 1 — reports fresh, and the poll
    after is always empty."""
    import tempfile
    t = FileHeartbeatTransport(tempfile.mkdtemp())
    last = None
    for i, seq in enumerate(seqs):
        with open(os.path.join(t.dir, "hb_0.json"), "w") as f:
            json.dump({"rank": 0, "step": i, "seq": seq,
                       "step_time": None, "wall": time.time()}, f)
        beats = t.step_feed(i, 1)
        assert beats == ({} if seq == last else {0: (i, None)})
        assert t.step_feed(i, 1) == {}
        last = seq


@settings(max_examples=25, deadline=None)
@given(cut=st.integers(0, 70), step=st.integers(0, 99))
def test_file_transport_torn_write_fuzz(cut, step):
    """A beat file torn at ANY byte offset: the poller never crashes, never
    reports the torn rank, keeps reporting healthy ranks, and picks the
    beat up as soon as the file is completed."""
    import tempfile
    t = FileHeartbeatTransport(tempfile.mkdtemp())
    t.emit(1, step)
    payload = json.dumps({"rank": 0, "step": step, "seq": 1,
                          "step_time": None, "wall": time.time()})
    with open(os.path.join(t.dir, "hb_0.json"), "w") as f:
        f.write(payload[:min(cut, len(payload) - 1)])  # always truncated
    assert t.step_feed(step, 2) == {1: (step, None)}
    snap = t.snapshot()                    # snapshot shares the robustness
    assert 1 in snap and 0 not in snap
    with open(os.path.join(t.dir, "hb_0.json"), "w") as f:
        f.write(payload)
    assert t.step_feed(step, 2) == {0: (step, None)}


@settings(max_examples=10, deadline=None)
@given(plans=st.lists(st.lists(st.integers(0, 30), min_size=1, max_size=5),
                      min_size=1, max_size=4))
def test_tcp_collector_concurrent_emitters_chaos(plans):
    """One emitter thread per rank into one collector: every rank's final
    beat is eventually reported, reported steps are only ones that rank
    sent, and once the streams drain a poll reports nothing new."""
    coll = TcpHeartbeatCollector(port=0)
    try:
        def emit_loop(rank, steps):
            em = TcpHeartbeatEmitter(coll.address)
            for s in steps:
                em.emit(rank, s)
            em.close()

        workers = [threading.Thread(target=emit_loop, args=(r, steps))
                   for r, steps in enumerate(plans)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        reported: dict[int, list] = {}
        deadline = time.time() + 10

        def all_finals_in() -> bool:
            return all(reported.get(r) and reported[r][-1] == steps[-1]
                       for r, steps in enumerate(plans))

        while time.time() < deadline and not all_finals_in():
            for rank, (step, _) in coll.step_feed(0, len(plans)).items():
                reported.setdefault(rank, []).append(step)
            time.sleep(0.01)
        assert all_finals_in()
        for rank, steps in enumerate(plans):
            assert set(reported[rank]) <= set(steps)
        time.sleep(0.2)                      # let any in-flight drain settle
        coll.step_feed(0, len(plans))
        assert coll.step_feed(1, len(plans)) == {}
    finally:
        coll.close()


def test_tcp_emitter_reconnects_after_collector_restart():
    """Emitter reconnect mid-poll: the collector dies and is reborn on the
    SAME address (a restarted monitor host); the fire-and-forget emitter
    re-dials on a later beat — dropping, never raising — and beats flow
    into the reborn collector's fresh poll baseline."""
    coll = TcpHeartbeatCollector(port=0)
    addr, port = coll.address, coll.port
    em = TcpHeartbeatEmitter(addr)
    em.emit(0, 1)
    assert _poll_until(lambda: coll.step_feed(1, 1)) == {0: (1, None)}
    coll.close()
    em.emit(0, 2)  # lands in a dead socket / dropped: silence, no exception
    reborn = TcpHeartbeatCollector(host="127.0.0.1", port=port)
    try:
        beats, step = {}, 3
        deadline = time.time() + 10
        while time.time() < deadline and 0 not in beats:
            em.emit(0, step)
            step += 1
            beats.update(reborn.step_feed(step, 1))
            time.sleep(0.02)
        assert 0 in beats
    finally:
        em.close()
        reborn.close()


# ------------------------------------------- failover list + peer mirroring
def _free_port() -> int:
    import socket
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_tcp_failover_list_mirroring_and_succession():
    """The leader-succession transport topology end to end: collectors on
    a ``tcp://a:p,b:p`` failover list peer-mirror, so the STANDBY's beat
    table (snapshot AND step_feed baseline) is primed with beats that were
    only ever sent to the primary — exactly what a successor needs for
    death attribution.  When the primary's host dies, emitters fail over
    down the list and beats land on the standby directly."""
    spec = f"tcp://127.0.0.1:{_free_port()},127.0.0.1:{_free_port()}"
    primary = make_transport(spec, serve=True, serve_index=0)
    standby = make_transport(spec, serve=True, serve_index=1)
    em = make_transport(spec)
    try:
        assert isinstance(em, TcpHeartbeatEmitter)
        em.emit(2, 5, step_time=0.1)   # a worker's beat, dialled to PRIMARY
        primary.emit(0, 5)             # the primary host's own local rank
        for coll in (primary, standby):  # BOTH see both (mirroring)
            acc = {}
            deadline = time.time() + 10
            while time.time() < deadline and set(acc) != {0, 2}:
                acc.update(coll.step_feed(5, 3))
                time.sleep(0.01)
            assert acc == {0: (5, None), 2: (5, 0.1)}
        assert standby.snapshot()[2]["step"] == 5   # primed for attribution
        # rank 0's host dies; the emitter fails over to the standby
        primary.close()
        acc, step = {}, 6
        deadline = time.time() + 15
        while time.time() < deadline and 2 not in acc:
            em.emit(2, step)
            step += 1
            acc.update(standby.step_feed(step, 3))
            time.sleep(0.02)
        assert 2 in acc                # the successor keeps collecting
    finally:
        em.close()
        standby.close()
        primary.close()


# --------------------------------------------- end-to-end through the engine
def test_pipeline_shrinks_and_grows_through_file_transport(tmp_path):
    """The full elastic loop over a REAL transport, one host: every rank's
    beats go through hb_<rank>.json files; rank 1 stops writing at step 3
    (flagged dead via the transport's since-last-poll contract), and from
    step 6 beats for a rank OUTSIDE the shrunk world announce its return.
    The run must shrink, resume, grow back, and finish both epochs."""
    import jax

    from repro.core import Placement, WindowSpec
    from repro.data import make_traffic_series
    from repro.optim import AdamConfig
    from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
    from repro.train import TrainLoopConfig

    world, b = 4, 2
    spec = WindowSpec(horizon=2, input_len=2)
    transport = FileHeartbeatTransport(str(tmp_path / "hb"))
    clock = [0.0]
    killed = [False]  # the worker dies once, not on every return to world 4

    def emitter(step: int) -> None:
        # The test's fault schedule, expressed purely as WHO EMITS: the
        # monitor side never sees injected events, only real files.
        clock[0] += 1.0
        current_world = pipe.world
        if current_world == world and step >= 3 and not killed[0]:
            live = [r for r in range(world) if r != 1]
            clock[0] += 100.0  # fake clock flies past the timeout
            killed[0] = True
        elif current_world < world:
            live = list(range(current_world))
            if step >= 6:
                live.append(current_world)  # the returned worker announces
        else:
            live = list(range(world))
        for r in live:
            transport.emit(r, step)

    params = {"w": np.full((3, 2), 0.1, np.float32)}

    def loss_fn(p, x, y):
        import jax.numpy as jnp
        return jnp.mean((x[:, -1] * p["w"] - y[:, 0]) ** 2), {}

    from repro.launch.mesh import make_host_mesh
    pipe = build_pipeline(
        make_traffic_series(120, 3), spec, make_host_mesh(),
        loss_fn, params,
        PipelineConfig(batch_per_rank=b, placement=Placement.REPLICATED,
                       world=world, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=2, log_every=1,
                                            ckpt_dir=str(tmp_path / "ck"))),
        elastic=ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                              emitter=emitter,
                              step_feed=transport.step_feed))
    _, history = pipe.fit(eval_fn=None)
    assert [r["kind"] for r in pipe.restarts] == ["shrink", "grow"]
    assert pipe.restarts[0]["plan"].dropped_workers == (1,)
    assert pipe.world == world and pipe.config.batch_per_rank == b
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    # the transport's files carry the whole fleet's final state
    snap = transport.snapshot()
    assert set(snap) >= set(range(world - 1))
