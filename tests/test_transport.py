"""Heartbeat transports (``repro.distributed.transport``): the file and TCP
transports must emit exactly the events the :class:`HeartbeatMonitor`
consumes — and crucially, ``step_feed`` must only report ranks that beat
SINCE THE LAST POLL, or a dead worker's stale file would keep refreshing its
liveness and the monitor could never flag it.

The integration test drives a real pipeline through a real file transport
end-to-end: the emitter hook writes beats, ``step_feed`` reads them back,
and a worker that stops emitting is flagged, shrunk away, and re-admitted
when its beats resume — the same chain ``tests/multihost.py`` runs over real
processes.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.distributed import (FileHeartbeatTransport, TcpHeartbeatCollector,
                               TcpHeartbeatEmitter, make_transport)


# ------------------------------------------------------------- file transport
def test_file_transport_reports_only_fresh_beats(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 5)
    t.emit(1, 5, step_time=0.25)
    assert t.step_feed(5, 2) == {0: (5, None), 1: (5, 0.25)}
    # no new beats since the poll: nothing reported (stale ≠ alive)
    assert t.step_feed(6, 2) == {}
    t.emit(0, 6)
    assert t.step_feed(6, 2) == {0: (6, None)}


def test_file_transport_same_step_rebeat_is_fresh(tmp_path):
    """A re-announced step (worker restarted and re-sent step 0) must still
    count as a fresh beat — freshness is keyed on the emit seq, not step."""
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 0)
    assert t.step_feed(0, 1) == {0: (0, None)}
    t.emit(0, 0)
    assert t.step_feed(1, 1) == {0: (0, None)}


def test_file_transport_cross_instance_and_unknown_ranks(tmp_path):
    """Separate transport instances over one directory see each other's
    beats (that IS the same-host multi-process design), including ranks
    outside the poller's world — a returned worker announcing itself.
    Only beats emitted AFTER the poller was built count."""
    monitor = FileHeartbeatTransport(str(tmp_path))
    worker = FileHeartbeatTransport(str(tmp_path))
    worker.emit(0, 3)
    worker.emit(7, 3)  # rank 7 of a 2-world poll: an outsider
    beats = monitor.step_feed(3, 2)
    assert beats == {0: (3, None), 7: (3, None)}


def test_file_transport_ignores_beats_predating_the_poller(tmp_path):
    """A RELAUNCHED trainer reuses the shared heartbeat directory: a dead
    worker's stale file must not read as a fresh beat on the first poll —
    else every relaunch would instantly plan a spurious grow toward a
    worker that is still down.  Only post-construction emits report."""
    before = FileHeartbeatTransport(str(tmp_path))
    before.emit(1, 7)   # the dead worker's last beat, pre-relaunch
    relaunched = FileHeartbeatTransport(str(tmp_path))
    assert relaunched.step_feed(8, 1) == {}           # stale: not reported
    before.emit(1, 0)   # the worker REALLY returns (fresh emit, any step)
    assert relaunched.step_feed(9, 1) == {1: (0, None)}


def test_file_transport_snapshot_ages(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 9)
    snap = t.snapshot()
    assert snap[0]["step"] == 9
    assert 0 <= snap[0]["age"] < 5.0


def test_file_transport_ignores_torn_writes(tmp_path):
    t = FileHeartbeatTransport(str(tmp_path))
    t.emit(0, 1)
    with open(os.path.join(str(tmp_path), "hb_1.json"), "w") as f:
        f.write('{"rank": 1, "st')  # torn mid-write
    assert t.step_feed(1, 2) == {0: (1, None)}


# -------------------------------------------------------------- tcp transport
def _poll_until(fn, *, timeout=5.0):
    deadline = time.time() + timeout
    acc = {}
    while time.time() < deadline:
        acc.update(fn())
        if acc:
            return acc
        time.sleep(0.01)
    return acc


def test_tcp_transport_round_trip():
    coll = TcpHeartbeatCollector(port=0)
    try:
        em = TcpHeartbeatEmitter(coll.address)
        em.emit(1, 4, step_time=0.5)
        beats = _poll_until(lambda: coll.step_feed(4, 2))
        assert beats == {1: (4, 0.5)}
        # the collector can emit for its own local ranks without dialling
        coll.emit(0, 4)
        assert coll.step_feed(4, 2) == {0: (4, None)}
        assert coll.step_feed(5, 2) == {}  # nothing fresh
        em.close()
    finally:
        coll.close()


def test_tcp_emitter_survives_dead_collector():
    """Emit must be fire-and-forget: a vanished collector cannot take the
    training loop down — silence is the signal, not an exception."""
    coll = TcpHeartbeatCollector(port=0)
    addr = coll.address
    coll.close()
    em = TcpHeartbeatEmitter(addr)
    em.emit(0, 1)  # must not raise
    em.close()


def test_make_transport_factory(tmp_path):
    t = make_transport(f"file:{tmp_path}")
    assert isinstance(t, FileHeartbeatTransport)
    coll = make_transport("tcp://127.0.0.1:0", serve=True)
    try:
        assert isinstance(coll, TcpHeartbeatCollector)
        em = make_transport(coll.address and f"tcp://{coll.address}")
        assert isinstance(em, TcpHeartbeatEmitter)
        em.close()
    finally:
        coll.close()
    with pytest.raises(ValueError, match="heartbeat transport"):
        make_transport("carrier-pigeon:/loft")


# --------------------------------------------- end-to-end through the engine
def test_pipeline_shrinks_and_grows_through_file_transport(tmp_path):
    """The full elastic loop over a REAL transport, one host: every rank's
    beats go through hb_<rank>.json files; rank 1 stops writing at step 3
    (flagged dead via the transport's since-last-poll contract), and from
    step 6 beats for a rank OUTSIDE the shrunk world announce its return.
    The run must shrink, resume, grow back, and finish both epochs."""
    import jax

    from repro.core import Placement, WindowSpec
    from repro.data import make_traffic_series
    from repro.optim import AdamConfig
    from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
    from repro.train import TrainLoopConfig

    world, b = 4, 2
    spec = WindowSpec(horizon=2, input_len=2)
    transport = FileHeartbeatTransport(str(tmp_path / "hb"))
    clock = [0.0]
    killed = [False]  # the worker dies once, not on every return to world 4

    def emitter(step: int) -> None:
        # The test's fault schedule, expressed purely as WHO EMITS: the
        # monitor side never sees injected events, only real files.
        clock[0] += 1.0
        current_world = pipe.world
        if current_world == world and step >= 3 and not killed[0]:
            live = [r for r in range(world) if r != 1]
            clock[0] += 100.0  # fake clock flies past the timeout
            killed[0] = True
        elif current_world < world:
            live = list(range(current_world))
            if step >= 6:
                live.append(current_world)  # the returned worker announces
        else:
            live = list(range(world))
        for r in live:
            transport.emit(r, step)

    params = {"w": np.full((3, 2), 0.1, np.float32)}

    def loss_fn(p, x, y):
        import jax.numpy as jnp
        return jnp.mean((x[:, -1] * p["w"] - y[:, 0]) ** 2), {}

    from repro.launch.mesh import make_host_mesh
    pipe = build_pipeline(
        make_traffic_series(120, 3), spec, make_host_mesh(),
        loss_fn, params,
        PipelineConfig(batch_per_rank=b, placement=Placement.REPLICATED,
                       world=world, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=2, log_every=1,
                                            ckpt_dir=str(tmp_path / "ck"))),
        elastic=ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                              emitter=emitter,
                              step_feed=transport.step_feed))
    _, history = pipe.fit(eval_fn=None)
    assert [r["kind"] for r in pipe.restarts] == ["shrink", "grow"]
    assert pipe.restarts[0]["plan"].dropped_workers == (1,)
    assert pipe.world == world and pipe.config.batch_per_rank == b
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]
    # the transport's files carry the whole fleet's final state
    snap = transport.snapshot()
    assert set(snap) >= set(range(world - 1))
