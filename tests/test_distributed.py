"""Distributed substrate: checkpoint atomicity/elastic restore, heartbeat and
re-mesh policy (including the shrink/grow round-trip property), gradient
equivalence of the DP step, placement helpers."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import WindowSpec
from repro.core.distributed import (Placement, local_time_range, local_window_ids,
                                    series_sharding)
from repro.distributed import (Checkpointer, ElasticPlan, HeartbeatMonitor,
                               latest_step, plan_remesh, restore)
from repro.distributed.elastic import scale_batch_or_steps


# ------------------------------------------------------------------ checkpoint
def _tiny_state():
    k = jax.random.PRNGKey(0)
    return {"params": {"w": jax.random.normal(k, (4, 3)),
                       "stack": [jnp.arange(5.0), jnp.ones((2, 2))]},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    state = _tiny_state()
    ck.save(state, step=10)
    ck.wait()
    restored, step = restore(str(tmp_path), state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        ck.save(state, step=s)
    assert ck.steps() == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    state = _tiny_state()
    ck.save(state, step=5)
    path = os.path.join(str(tmp_path), "step_0000000005", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="checksum"):
        restore(str(tmp_path), state)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(_tiny_state(), step=1)
    bad = _tiny_state()
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), bad)


def test_checkpoint_async_overlaps_and_surfaces_errors(tmp_path):
    ck = Checkpointer(str(tmp_path / "ok"), keep=1)
    ck.save(_tiny_state(), step=1)  # async
    ck.save(_tiny_state(), step=2)  # waits for 1, then writes 2
    ck.wait()
    assert ck.steps() == [2]


def test_checkpoint_meta_roundtrip(tmp_path):
    """Run coordinates ride the manifest so an elastic restart can resume at
    the same (epoch, step) even when steps_per_epoch changed."""
    from repro.distributed import checkpoint_meta

    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(_tiny_state(), step=4, meta={"epoch": 1, "done_in_epoch": 2})
    assert checkpoint_meta(str(tmp_path)) == {"epoch": 1, "done_in_epoch": 2}
    ck.save(_tiny_state(), step=9)  # meta-less saves read back empty
    assert checkpoint_meta(str(tmp_path)) == {}
    assert checkpoint_meta(str(tmp_path), step=4) == {"epoch": 1,
                                                      "done_in_epoch": 2}


def test_elastic_restore_into_new_sharding(tmp_path):
    """Restart on a different topology: restore re-device_puts every leaf."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import AxisType, make_mesh

    ck = Checkpointer(str(tmp_path), async_write=False)
    state = _tiny_state()
    ck.save(state, step=3)
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    sh = NamedSharding(mesh, P())
    restored, _ = restore(str(tmp_path), state, shardings=sh)
    leaf = restored["params"]["w"]
    assert leaf.sharding == sh


# --------------------------------------------------------------------- elastic
def test_heartbeat_dead_and_straggler():
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout=10.0, straggler_factor=3.0,
                           clock=lambda: t[0])
    for step in range(1, 6):
        for w in range(3):  # worker 3 goes silent after step 1
            t[0] += 0.1
            mon.beat(w, step)
        if step == 1:
            mon.beat(3, 1)
    t[0] += 20.0
    for w in range(3):  # live workers keep beating after the gap
        mon.beat(w, 6)
    assert mon.dead() == [3]

    # straggler: worker 2 self-reports 10x slower compute per step
    t2 = [0.0]
    mon2 = HeartbeatMonitor(4, timeout=1e9, clock=lambda: t2[0])
    for step in range(1, 8):
        for w in range(4):
            t2[0] += 10.0  # wall time is the same for everyone (sync SPMD)
            mon2.beat(w, step, step_time=1.0 if w != 2 else 10.0)
    assert mon2.stragglers() == [2]
    assert mon2.unhealthy() == [2]


def test_plan_remesh_keeps_tp_groups_whole():
    # 16 hosts x 4 chips, TP=16 -> 4 hosts per group, 4 groups
    plan = plan_remesh(16, [5], model_parallel=16, chips_per_host=4)
    assert isinstance(plan, ElasticPlan)
    assert plan.mesh_shape == (3, 16)
    # the whole group containing host 5 (hosts 4-7) is dropped
    assert plan.dropped_workers == (4, 5, 6, 7)
    assert plan_remesh(16, [], model_parallel=16) is None


def test_plan_remesh_exhausted():
    with pytest.raises(RuntimeError):
        plan_remesh(4, [0, 1, 2, 3], model_parallel=4, chips_per_host=4)


def test_scale_batch_rules():
    per, glob = scale_batch_or_steps(1024, old_dp=16, new_dp=12)
    assert per * 12 >= 1024  # keep-global rounds up
    per2, glob2 = scale_batch_or_steps(1024, 16, 12, keep_global_batch=False)
    assert per2 == 64 and glob2 == 768


# -------------------------------------------- shrink/grow round-trip property
@settings(max_examples=80, deadline=None)
@given(base_world=st.integers(2, 8),
       batch_per_rank=st.integers(1, 8),
       events=st.lists(st.integers(0, 999), min_size=1, max_size=12))
def test_plan_roundtrip_restores_base_topology(base_world, batch_per_rank,
                                               events):
    """Arbitrary shrink/grow sequences through ``plan_remesh`` +
    ``scale_batch_or_steps`` (the engine's contract: ALWAYS re-scale
    against the BASE global batch) restore the BASE topology and global
    batch exactly once every worker has returned — and never compound the
    ceil inflation mid-sequence.  The victim of each shrink is drawn from
    the whole world INCLUDING rank 0 (the leader): the planner is
    rank-agnostic, succession (lowest surviving rank decides) is always
    well-defined, and a sequence that kills every leader in turn still
    round-trips."""
    base_global = base_world * batch_per_rank
    world = base_world
    for ev in events:
        shrink = (ev % 2 == 0 and world > 1) or world == base_world
        if shrink:
            victim = ev % world                   # may be 0 — the leader
            successor = 0 if victim else (1 if world > 1 else 0)
            plan = plan_remesh(world, [victim], model_parallel=1,
                               chips_per_host=1, decided_by=successor)
            assert plan.kind == "shrink"
            assert plan.dropped_workers == (victim,)
            assert plan.decided_by == successor   # rank 0's death included
            world -= 1
        else:
            back = 1 + ev % (base_world - world)  # grow by 1..missing
            plan = plan_remesh(world, [],
                               recovered=list(range(world, world + back)),
                               model_parallel=1, chips_per_host=1)
            assert plan.kind == "grow"
            assert len(plan.readmitted_workers) == back
            world += back
        # the engine's invariant: per-worker batch is ceil(BASE/world) at
        # every intermediate topology — scaling from the base never
        # compounds (feeding the inflated global back in WOULD)
        per, glob = scale_batch_or_steps(base_global, old_dp=base_world,
                                         new_dp=world)
        assert per == -(-base_global // world)
        assert glob == per * world
        assert glob >= base_global                # never loses windows
        assert glob - base_global < world         # inflation bounded < world
    # every worker returns: the inverse plans restore the base exactly
    while world < base_world:
        plan = plan_remesh(world, [],
                           recovered=list(range(world, base_world)),
                           model_parallel=1, chips_per_host=1)
        world += len(plan.readmitted_workers)
    per, glob = scale_batch_or_steps(base_global, old_dp=base_world,
                                     new_dp=world)
    assert world == base_world
    assert (per, glob) == (batch_per_rank, base_global)


@settings(max_examples=40, deadline=None)
@given(base_world=st.integers(2, 6), shrinks=st.integers(1, 4),
       batch_per_rank=st.integers(1, 5))
def test_compounding_ceil_inflation_is_real_and_avoided(base_world, shrinks,
                                                        batch_per_rank):
    """The failure mode the BASE-scaling contract exists to prevent: chain
    the scaling through each re-mesh's inflated output and the global batch
    is non-decreasing (and on non-dividing worlds grows); scale from the
    base and the round trip is exact."""
    base_global = base_world * batch_per_rank
    n = min(shrinks, base_world - 1)
    # the WRONG way: feed each inflated global back in
    chained = base_global
    for w in range(base_world - 1, base_world - 1 - n, -1):
        chained = scale_batch_or_steps(chained, old_dp=w + 1, new_dp=w)[1]
    for w in range(base_world - n + 1, base_world + 1):
        chained = scale_batch_or_steps(chained, old_dp=w - 1, new_dp=w)[1]
    assert chained >= base_global
    # the engine's way: always from the base — exact after the round trip
    assert scale_batch_or_steps(base_global, old_dp=base_world,
                                new_dp=base_world) == (batch_per_rank,
                                                       base_global)


# ------------------------------------------------------------------ placements
def test_local_time_ranges_partition():
    ranges = [local_time_range(105, r, 4) for r in range(4)]
    assert ranges[0][0] == 0 and ranges[-1][1] == 105
    for (a, b), (c, d) in zip(ranges, ranges[1:]):
        assert b == c  # contiguous, disjoint


def test_local_window_ids_interior_vs_halo():
    spec = WindowSpec(horizon=3, input_len=3)  # span 6
    world, entries = 4, 100
    interior = [local_window_ids(entries, spec, r, world, halo=False)
                for r in range(world)]
    halo = [local_window_ids(entries, spec, r, world, halo=True)
            for r in range(world)]
    # interior windows never leave the shard
    for r, ids in enumerate(interior):
        lo, hi = local_time_range(entries, r, world)
        assert all(lo <= s and s + spec.span <= hi for s in ids)
    # halo covers every global window exactly once
    all_halo = np.concatenate(halo)
    assert np.array_equal(np.sort(all_halo), np.arange(entries - spec.span + 1))


def test_dp_grad_equivalence_single_vs_sharded():
    """DP-sharded loss grads == single-device grads (the all-reduce inserted
    by the partitioner computes exactly the global batch gradient)."""
    from repro.optim import AdamConfig
    from repro.train.loop import init_train_state, make_train_step

    k = jax.random.PRNGKey(0)
    w0 = jax.random.normal(k, (8, 8))

    def loss_fn(p, batch):
        return jnp.mean((batch @ p["w"]) ** 2), {}

    adam = AdamConfig(lr=1e-2, grad_clip=None)
    step = make_train_step(loss_fn, adam, lambda s: 1e-2, donate=False)
    batch = jax.random.normal(k, (16, 8))
    s1, _ = step(init_train_state({"w": w0}, adam), batch)
    # microbatched (sequential halves) must agree bitwise-ish
    step2 = make_train_step(loss_fn, adam, lambda s: 1e-2, microbatches=2,
                            donate=False)
    s2, _ = step2(init_train_state({"w": w0}, adam), batch)
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                               np.asarray(s2["params"]["w"]), atol=1e-6)
