"""BlockPool property tests (PR 9 satellite): the paged-KV allocator.

The allocator's contract is what keeps paged decoding safe:
- alloc/free round-trip: every freed block is reusable, capacity is conserved;
- no double-assignment: a block is owned by at most one request at any time
  under arbitrary alloc/free churn (two lanes writing one physical block
  would silently corrupt each other's KV);
- exhaustion is ``Backpressure`` (admission-level, retryable) — NOT an OOM
  or a silent partial allocation;
- block 0 is the NULL block and is never handed out (dead decode lanes write
  through all-zero table rows into block 0 by construction);
- the block-table gather reassembles exactly the contiguous token line for
  EVERY block size, dividing ``max_len`` or not — the indexing identity the
  paged attention path stands on.

Runs under real hypothesis when installed, else the seeded-example fallback
from conftest.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import Backpressure, BlockPool, NULL_BLOCK


def test_null_block_reserved():
    pool = BlockPool(4, 8)
    got = pool.alloc(4)
    assert NULL_BLOCK not in got
    assert sorted(got) == [1, 2, 3, 4]


def test_blocks_for_ceil_division():
    pool = BlockPool(8, 4)
    assert [pool.blocks_for(t) for t in (1, 3, 4, 5, 8, 9)] == [1, 1, 1, 2, 2, 3]


def test_exhaustion_is_backpressure_and_atomic():
    """Over-ask raises Backpressure and allocates NOTHING (no partial grab
    that would leak blocks on the admission-retry path)."""
    pool = BlockPool(4, 8)
    pool.alloc(2)
    with pytest.raises(Backpressure):
        pool.alloc(3)
    assert pool.available == 2  # untouched by the failed alloc
    pool.alloc(2)  # the remaining blocks are still allocatable
    assert pool.available == 0


def test_double_free_rejected():
    pool = BlockPool(4, 8)
    blocks = pool.alloc(2)
    pool.free(blocks)
    with pytest.raises(ValueError):
        pool.free(blocks)
    with pytest.raises(ValueError):
        pool.free([NULL_BLOCK])  # the null block is never owned


def test_duplicate_ids_in_one_free_atomic():
    """Regression: ``free([b, b])`` passed the membership pre-check (both
    occurrences owned), then ``KeyError``-ed mid-loop with the pool HALF
    freed.  Duplicates must raise ValueError with the pool unchanged."""
    pool = BlockPool(4, 8)
    blocks = pool.alloc(3)
    with pytest.raises(ValueError, match="duplicate"):
        pool.free([blocks[0], blocks[1], blocks[0]])
    assert pool.available == 1  # nothing was freed by the failed call
    pool.free(blocks)  # every block is still owned and freeable once
    assert pool.available == 4


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(1, 24), block_size=st.integers(1, 16),
       seed=st.integers(0, 2**16))
def test_churn_never_double_assigns(num_blocks, block_size, seed):
    """Random alloc/free churn: live requests never share a block, freed
    blocks return, and available-count always equals capacity minus live."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(num_blocks, block_size)
    live: list[list[int]] = []
    for _ in range(60):
        if live and rng.random() < 0.45:
            blocks = live.pop(int(rng.integers(0, len(live))))
            pool.free(blocks)
        else:
            want = int(rng.integers(1, num_blocks + 1))
            try:
                live.append(pool.alloc(want))
            except Backpressure:
                assert want > pool.available  # only raised when it must be
        held = [b for blocks in live for b in blocks]
        assert len(held) == len(set(held)), "block double-assigned"
        assert NULL_BLOCK not in held
        assert pool.available == num_blocks - len(held)
    for blocks in live:
        pool.free(blocks)
    assert pool.available == num_blocks  # full round-trip


@settings(max_examples=60, deadline=None)
@given(block_size=st.integers(1, 12), max_len=st.integers(4, 48),
       batch=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_block_table_gather_matches_contiguous(block_size, max_len, batch,
                                               seed):
    """pool[table].reshape(b, -1)[:, :len] == the contiguous line, for every
    block size — including sizes that do NOT divide max_len (the tail block
    is partially filled; the gather view over-reads it, the length mask in
    the attention kernel is what ignores the stale tail)."""
    rng = np.random.default_rng(seed)
    max_blocks = -(-max_len // block_size)
    pool = BlockPool(batch * max_blocks, block_size)
    store = np.zeros((1 + pool.num_blocks, block_size), np.int64)
    tables = np.zeros((batch, max_blocks), np.int32)
    lines, lens = [], []
    for lane in range(batch):
        n = int(rng.integers(1, max_len + 1))
        line = rng.integers(1, 10**6, size=n)
        blocks = pool.alloc(pool.blocks_for(n))
        tables[lane, :len(blocks)] = blocks
        padded = np.zeros((len(blocks) * block_size,), np.int64)
        padded[:n] = line
        store[blocks] = padded.reshape(len(blocks), block_size)
        lines.append(line)
        lens.append(n)
    gathered = store[tables].reshape(batch, -1)
    for lane in range(batch):
        np.testing.assert_array_equal(gathered[lane, :lens[lane]],
                                      lines[lane])
    assert np.all(store[NULL_BLOCK] == 0)  # null block never written
