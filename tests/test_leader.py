"""Leader succession (``repro.distributed.leader``): the lowest live rank
owns the single-writer duties, and every duty survives the leader's death —

- :class:`LeaderTracker`: deterministic lowest-live-rank rule over the same
  seq-gated beat stream the monitor consumes; timeout-based and immediate
  (``note_dead``) succession; startup grace (never-beaten ranks are timed
  from the first observe, not construction);
- :class:`LeaderCheckpointer`: standbys hold warm host snapshots, the
  leader writes; ``takeover()`` durably lands the exact failure-step state;
- :class:`LeaderHistorySink`: standby rows buffer without touching the
  shared file; a takeover flush lands only the rows the dead leader never
  wrote (first-wins dedup);
- the ENGINE-level chain, single-host fault injection: the process owning
  ranks 1..3 watches rank 0 — the leader — go silent, times it out, takes
  the decider role, and the SHRINK plan that re-meshes the run is decided
  by rank 1 (``plan.decided_by``), not by a hung fleet.  The same chain
  over real processes is ``tests/multihost.py``'s kill-rank-0 cycle.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Placement, WindowSpec
from repro.data import make_traffic_series
from repro.distributed import (Checkpointer, LeaderCheckpointer,
                               LeaderHistorySink, LeaderTracker, latest_step,
                               restore)
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamConfig
from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig


# --------------------------------------------------------------- LeaderTracker
def test_lowest_live_rank_wins():
    clock = [0.0]
    t = LeaderTracker(4, [2, 3], timeout=5.0, clock=lambda: clock[0])
    t.observe({r: (1, None) for r in range(4)})
    assert t.leader() == 0 and not t.is_leader()
    clock[0] += 2.0
    t.observe({2: (2, None), 3: (2, None)})  # ranks 0/1 keep silent
    clock[0] += 4.0                          # 0 and 1 now 6 s stale
    assert t.live() == [2, 3]
    assert t.leader() == 2 and t.is_leader()


def test_never_beaten_ranks_get_startup_grace():
    """A rank that has not beaten yet is timed from the FIRST observe —
    construction-to-first-poll time (gloo init, jit compile) must not flip
    leadership away from a healthy rank 0."""
    clock = [100.0]
    t = LeaderTracker(2, [1], timeout=5.0, clock=lambda: clock[0])
    assert t.leader() == 0          # nothing observed at all: all live
    t.observe({1: (1, None)})       # first poll starts rank 0's clock
    clock[0] += 4.0
    assert t.leader() == 0          # within the grace window
    clock[0] += 2.0                 # 6 s since first observe: timed out
    t.observe({1: (2, None)})
    assert t.leader() == 1 and t.is_leader()


def test_note_dead_is_immediate_and_beats_heal():
    clock = [0.0]
    t = LeaderTracker(3, [1], timeout=1e9, clock=lambda: clock[0])
    t.observe({r: (1, None) for r in range(3)})
    t.note_dead([0])                # attribution: no timeout needed
    assert t.leader() == 1 and t.is_leader()
    t.observe({0: (5, None)})       # a fresh beat overrides the verdict
    assert t.leader() == 0 and not t.is_leader()


def test_last_survivor_leads_and_out_of_world_ignored():
    clock = [0.0]
    t = LeaderTracker(2, [1], timeout=1e9, clock=lambda: clock[0])
    t.note_dead([0])
    t.observe({7: (3, None)})       # a returned worker announcing: not in
    assert t.live() == [1]          # the current world, not in the vote
    assert t.is_leader()
    t.note_dead([1])                # everyone attributed dead — including us:
    assert t.leader() == 1          # someone must still write the post-mortem
    assert t.is_leader()


def test_reset_reprimes_for_new_topology():
    clock = [0.0]
    t = LeaderTracker(4, [1], timeout=5.0, clock=lambda: clock[0])
    t.note_dead([0])
    assert t.is_leader()
    t.reset(3)                      # in-process re-mesh: we own every rank
    assert t.world == 3 and t.own_ranks == {0, 1, 2}
    assert t.leader() == 0 and t.is_leader()


# ---------------------------------------------------------- LeaderCheckpointer
def _tiny_state():
    return {"w": jnp.arange(6.0).reshape(2, 3)}


def test_standby_holds_snapshot_takeover_writes(tmp_path):
    lead = [False]
    ck = LeaderCheckpointer(Checkpointer(str(tmp_path)), lambda: lead[0])
    ck.save(_tiny_state(), step=4, meta={"epoch": 0, "done_in_epoch": 4})
    assert latest_step(str(tmp_path)) is None   # standby: nothing durable
    assert ck.pending_step == 4
    lead[0] = True
    assert ck.takeover() == 4
    state, step = restore(str(tmp_path), _tiny_state())
    assert step == 4
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.arange(6.0).reshape(2, 3))
    from repro.distributed import checkpoint_meta
    assert checkpoint_meta(str(tmp_path)) == {"epoch": 0, "done_in_epoch": 4}
    assert ck.takeover() is None                # nothing pending twice


def test_leader_saves_land_directly_and_clear_pending(tmp_path):
    ck = LeaderCheckpointer(Checkpointer(str(tmp_path)), lambda: True)
    ck.save(_tiny_state(), step=1)
    ck.wait()
    assert latest_step(str(tmp_path)) == 1
    assert ck.pending_step is None and ck.takeover() is None


def test_standby_snapshot_survives_mutated_source(tmp_path):
    """The standby copy is HOST bytes, not a reference: mutating (or, in
    real life, donating/poisoning) the source arrays after save must not
    change what a takeover writes."""
    lead = [False]
    ck = LeaderCheckpointer(Checkpointer(str(tmp_path)), lambda: lead[0])
    state = {"w": np.arange(4.0)}
    ck.save(state, step=2)
    state["w"][:] = -1.0
    lead[0] = True
    ck.takeover()
    restored, _ = restore(str(tmp_path), {"w": np.zeros(4)})
    np.testing.assert_array_equal(restored["w"], np.arange(4.0))


# ----------------------------------------------------------- LeaderHistorySink
def test_standby_buffers_takeover_flushes_dedup(tmp_path):
    path = str(tmp_path / "h.jsonl")
    # the leader lands two rows, then "dies"
    dead = LeaderHistorySink(path, lambda: True)
    dead.append({"step": 1, "epoch": 0, "loss": 1.0})
    dead.append({"step": 2, "epoch": 0, "loss": 0.9})
    dead.close()
    # the standby logged the same rows plus one more the leader never wrote
    lead = [False]
    succ = LeaderHistorySink(path, lambda: lead[0])
    for row in ({"step": 1, "epoch": 0, "loss": 1.0},
                {"step": 2, "epoch": 0, "loss": 0.9},
                {"step": 3, "epoch": 0, "loss": 0.8}):
        succ.append(row)
    assert len(open(path).readlines()) == 2     # standby never touched it
    assert [r["step"] for r in succ.rows] == [1, 2, 3]
    lead[0] = True
    assert succ.flush_as_leader() == 1          # only step 3 was new
    assert [r["step"] for r in succ.load()] == [1, 2, 3]
    # post-takeover appends go straight to the durable file
    succ.append({"step": 4, "epoch": 0, "loss": 0.7})
    assert [r["step"] for r in succ.load()] == [1, 2, 3, 4]
    succ.close()


def test_buffer_standby_off_keeps_no_unflushable_copy(tmp_path):
    """Processes that can never lead (no tracker / beyond the failover
    list) must not accumulate an unflushable buffer for the whole run."""
    s = LeaderHistorySink(str(tmp_path / "h.jsonl"), lambda: False,
                          buffer_standby=False)
    for i in range(5):
        s.append({"step": i, "epoch": 0, "loss": 1.0})
    assert s._buffer == [] and len(s.rows) == 5
    s.bind(lambda: True)
    assert s.flush_as_leader() == 0      # nothing held, nothing to land
    s.close()


def test_takeover_truncates_dead_leaders_torn_tail(tmp_path):
    """The durable sink is opened lazily — ON takeover — so the torn row a
    leader died mid-write in is truncated exactly when the successor first
    touches the file, then re-landed from its buffer."""
    path = str(tmp_path / "h.jsonl")
    with open(path, "w") as f:
        f.write('{"step": 1, "epoch": 0, "loss": 1.0}\n')
        f.write('{"step": 2, "epoch": 0, "lo')       # died mid-write
    succ = LeaderHistorySink(path, lambda: False)
    succ.append({"step": 2, "epoch": 0, "loss": 0.9})
    succ.bind(lambda: True)
    assert succ.flush_as_leader() == 1
    rows = succ.load()
    assert [(r["step"], r["loss"]) for r in rows] == [(1, 1.0), (2, 0.9)]
    succ.close()


# ------------------------------------------- engine chain: the leader dies
ENTRIES, NODES, WORLD, B = 120, 3, 4, 2
SPEC = WindowSpec(horizon=2, input_len=2)


def _loss_fn(p, x, y):
    return jnp.mean((x[:, -1] * p["w"] - y[:, 0]) ** 2), {}


class LeaderDies:
    """step_feed fake: rank 0 — the decider — stops beating at step 3 while
    the fake clock flies past the timeout.  Every OTHER rank keeps beating;
    the pretend-process owning ranks 1..3 must take over and shrink."""

    def __init__(self, clock, dead_after: int = 3):
        self.clock = clock
        self.dead_after = dead_after

    def __call__(self, step: int, world: int) -> dict:
        self.clock[0] += 1.0
        beats = {r: (step, None) for r in range(world)}
        if world == WORLD and step >= self.dead_after:
            del beats[0]
            self.clock[0] += 100.0
        return beats


def test_dead_rank0_shrink_decided_by_successor(tmp_path):
    """A dead rank 0 yields a SHRINK plan decided by the successor, not a
    hung fleet: with leadership threaded through the health callback, the
    tracker times the old leader out on the same poll the monitor flags it,
    rank 1 passes the is-leader gate, and the plan it raises re-meshes the
    run to completion.  (The checkpoint restored into the shrunk mesh was
    written by the SUCCESSOR — its standby saves were the only ones this
    pretend-process could make durable.)"""
    clock = [0.0]
    tracker = LeaderTracker(WORLD, [1, 2, 3], timeout=50.0,
                            clock=lambda: clock[0])
    elastic = ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                            step_feed=LeaderDies(clock), leader=tracker)
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, {"w": jnp.full((NODES, 2), 0.1, jnp.float32)},
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=WORLD, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=2, log_every=1,
                                            ckpt_dir=str(tmp_path / "ck"))),
        elastic=elastic)
    assert not pipe.is_leader()  # rank 0 leads while it lives
    _, history = pipe.fit(eval_fn=None)

    assert len(pipe.restarts) == 1
    plan = pipe.restarts[0]["plan"]
    assert plan.kind == "shrink"
    assert plan.dropped_workers == (0,)          # the LEADER was the victim
    assert plan.decided_by == 1                  # ...and rank 1 decided
    assert pipe.world == WORLD - 1
    # after the in-process re-mesh this process owns the whole (renumbered)
    # world and leads it
    assert pipe.is_leader() and tracker.own_ranks == {0, 1, 2}
    # the run finished: both epochs summarised, steps monotonic, no dups
    steps = [h["step"] for h in history if "epoch_time_s" not in h]
    assert steps == sorted(steps) and len(steps) == len(set(steps))
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [0, 1]


def test_succeed_as_leader_takes_over_checkpoint_and_plan(tmp_path):
    """The post-collective-failure path, single-host: the run dies with a
    plain exception (a peer vanished mid-step), the launcher attributes
    rank 0, and ``succeed_as_leader`` must (a) flip leadership, (b) durably
    write the successor's warm-standby checkpoint — the ONLY durable state,
    since the pretend-rank-1 process was never the writer — and (c) return
    the shrink plan the successor decided."""
    clock = [0.0]
    tracker = LeaderTracker(2, [1], timeout=50.0, clock=lambda: clock[0])
    boom = RuntimeError("Gloo all-reduce failed: connection closed by peer")

    def step_feed(step: int, world: int) -> dict:
        clock[0] += 1.0
        if step >= 3:
            raise boom  # the collective dies under us mid-epoch
        return {r: (step, None) for r in range(world)}

    elastic = ElasticConfig(heartbeat_timeout=50.0, clock=lambda: clock[0],
                            step_feed=step_feed, leader=tracker,
                            remesh="relaunch")
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, {"w": jnp.full((NODES, 2), 0.1, jnp.float32)},
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=2, seed=7, adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=1, log_every=1,
                                            ckpt_every=1,
                                            ckpt_dir=str(tmp_path / "ck"))),
        elastic=elastic)
    with pytest.raises(RuntimeError, match="closed by peer"):
        pipe.fit(eval_fn=None)
    assert latest_step(str(tmp_path / "ck")) is None  # standby: none durable

    outcome = pipe.succeed_as_leader([0])
    assert outcome is not None
    assert outcome["leader"] == 1
    assert outcome["ckpt_step"] == 3                  # the failure step
    assert latest_step(str(tmp_path / "ck")) == 3     # ...now durable
    assert outcome["plan"].kind == "shrink"
    assert outcome["plan"].dropped_workers == (0,)
    assert outcome["plan"].decided_by == 1


def test_non_successor_does_not_take_over(tmp_path):
    """A survivor whose lowest live rank is NOT its own must stay a
    standby: no checkpoint write, no plan — the real successor owns both."""
    tracker = LeaderTracker(3, [2], timeout=1e9)
    elastic = ElasticConfig(leader=tracker, remesh="relaunch")
    pipe = build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, {"w": jnp.full((NODES, 2), 0.1, jnp.float32)},
        PipelineConfig(batch_per_rank=B, placement=Placement.REPLICATED,
                       world=3, seed=7,
                       loop=TrainLoopConfig(epochs=1,
                                            ckpt_dir=str(tmp_path / "ck"))),
        elastic=elastic)
    assert pipe.succeed_as_leader([0]) is None        # rank 1 outranks us
    assert not os.path.exists(str(tmp_path / "ck")) \
        or latest_step(str(tmp_path / "ck")) is None
