"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.diffusion_conv import diffusion_conv, diffusion_conv_ref
from repro.kernels.linear_scan import linear_scan, linear_scan_ref
from repro.kernels.window_gather import window_gather, window_gather_ref


# ------------------------------------------------------------- window_gather
@pytest.mark.parametrize("t,trail,span,b,dtype", [
    (64, (24, 2), 6, 8, np.float32),
    (100, (13,), 5, 4, np.float32),
    (50, (), 7, 3, np.float32),
    (256, (128,), 24, 16, np.float32),
    (64, (7, 3), 4, 2, np.int32),
    (40, (130,), 3, 5, np.float32),  # trailing dim not lane-aligned
])
def test_window_gather_matches_ref(t, trail, span, b, dtype):
    rng = np.random.default_rng(0)
    if np.issubdtype(dtype, np.integer):
        series = rng.integers(0, 100, size=(t,) + trail).astype(dtype)
    else:
        series = rng.standard_normal((t,) + trail).astype(dtype)
    starts = rng.integers(0, t - span + 1, size=b).astype(np.int32)
    ref = window_gather_ref(jnp.asarray(series), jnp.asarray(starts), span=span)
    pal = window_gather(jnp.asarray(series), jnp.asarray(starts), span=span,
                        use_pallas=True)
    assert np.array_equal(np.asarray(ref), np.asarray(pal))


@given(t=st.integers(10, 80), c=st.integers(1, 40), span=st.integers(1, 8),
       b=st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_window_gather_property(t, c, span, b):
    if span >= t:
        span = max(t - 1, 1)
    rng = np.random.default_rng(t * 31 + c)
    series = rng.standard_normal((t, c)).astype(np.float32)
    starts = rng.integers(0, t - span + 1, size=b).astype(np.int32)
    ref = window_gather_ref(jnp.asarray(series), jnp.asarray(starts), span=span)
    pal = window_gather(jnp.asarray(series), jnp.asarray(starts), span=span,
                        use_pallas=True)
    assert np.array_equal(np.asarray(ref), np.asarray(pal))


# --------------------------------------------------------------- linear_scan
@pytest.mark.parametrize("b,s,d,chunk", [
    (8, 64, 128, 32), (2, 37, 33, 16), (1, 5, 256, 8), (16, 512, 128, 256),
    (4, 128, 64, 128),
])
def test_linear_scan_matches_ref(b, s, d, chunk):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.7, 1.0, (b, s, d)).astype(np.float32))
    bb = jnp.asarray(rng.standard_normal((b, s, d)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    r_seq, r_last = linear_scan_ref(a, bb, h0)
    p_seq, p_last = linear_scan(a, bb, h0, use_pallas=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(r_seq), np.asarray(p_seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_last), np.asarray(p_last), atol=1e-5)


@given(b=st.integers(1, 8), s=st.integers(1, 100), d=st.sampled_from([8, 33, 128]),
       decay=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_linear_scan_property(b, s, d, decay):
    rng = np.random.default_rng(b * 7 + s)
    a = jnp.full((b, s, d), decay, jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, d)).astype(np.float32))
    r_seq, r_last = linear_scan_ref(a, bb, jnp.zeros((b, d)))
    p_seq, p_last = linear_scan(a, bb, None, use_pallas=True, chunk=32)
    np.testing.assert_allclose(np.asarray(r_seq), np.asarray(p_seq),
                               atol=1e-4, rtol=1e-4)


def test_linear_scan_identity_decay_is_cumsum():
    b, s, d = 2, 20, 8
    bb = jnp.asarray(np.random.default_rng(0).standard_normal((b, s, d)).astype(np.float32))
    seq, last = linear_scan(jnp.ones((b, s, d)), bb, None, use_pallas=True, chunk=5)
    np.testing.assert_allclose(np.asarray(seq), np.cumsum(np.asarray(bb), axis=1),
                               atol=1e-5)


# ------------------------------------------------------------ diffusion_conv
def _random_supports(rng, n):
    adj = rng.uniform(0, 1, (n, n)).astype(np.float32)
    adj[adj < 0.5] = 0
    np.fill_diagonal(adj, 1.0)
    fwd = adj / adj.sum(1, keepdims=True)
    rev = adj.T / adj.T.sum(1, keepdims=True)
    return jnp.asarray(fwd), jnp.asarray(rev)


@pytest.mark.parametrize("b,n,c,h,k,block", [
    (2, 24, 10, 8, 2, 8),
    (1, 16, 4, 4, 1, 16),
    (4, 50, 6, 12, 3, 16),  # N not multiple of block -> padding path
    (3, 128, 16, 32, 2, 128),
])
def test_diffusion_conv_matches_ref(b, n, c, h, k, block):
    rng = np.random.default_rng(5)
    sup = _random_supports(rng, n)
    x = jnp.asarray(rng.standard_normal((b, n, c)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(((1 + 2 * k) * c, h)).astype(np.float32) * 0.1)
    bias = jnp.asarray(rng.standard_normal((h,)).astype(np.float32))
    ref = diffusion_conv_ref(x, sup, w, bias, k_hops=k)
    pal = diffusion_conv(x, sup, w, bias, k_hops=k, use_pallas=True, block_n=block)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=2e-4, rtol=1e-4)


def test_diffusion_conv_grad_flows():
    """The Pallas op participates in autodiff (train path uses it)."""
    rng = np.random.default_rng(3)
    sup = _random_supports(rng, 16)
    x = jnp.asarray(rng.standard_normal((2, 16, 4)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((5 * 4, 8)).astype(np.float32) * 0.1)
    bias = jnp.zeros((8,))

    def loss(w):
        return jnp.sum(diffusion_conv_ref(x, sup, w, bias, k_hops=2) ** 2)

    g = jax.grad(loss)(w)
    assert np.isfinite(np.asarray(g)).all()


# ------------------------------------------------------------- auto dispatch
# ``impl="auto"`` routes through the measured dispatcher
# (repro.kernels.autotune).  Two identity contracts hold on CPU:
#  - mode="off": the static default on an interpret backend is the reference
#    lowering, so auto is bit-identical to ref for every op;
#  - mode="tune" for exact ops (window_gather is pure data movement): the
#    tuner's admission check rejects any candidate whose values differ from
#    the reference, so bit-identity holds no matter which candidate wins.

import tempfile  # noqa: E402

from repro.kernels import flash_attention  # noqa: E402
from repro.kernels.autotune import autotuning  # noqa: E402


@given(t=st.integers(12, 64), c=st.integers(1, 24), span=st.integers(2, 8),
       b=st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_window_gather_auto_bit_identical_when_tuned(t, c, span, b):
    span = min(span, t - 1)
    rng = np.random.default_rng(t * 131 + c)
    series = rng.standard_normal((t, c)).astype(np.float32)
    starts = rng.integers(0, t - span + 1, size=b).astype(np.int32)
    ref = window_gather_ref(jnp.asarray(series), jnp.asarray(starts),
                            span=span)
    with tempfile.TemporaryDirectory() as tmp:
        with autotuning(mode="tune", cache_dir=tmp, warmup=0, iters=1):
            out = window_gather(jnp.asarray(series), jnp.asarray(starts),
                                span=span, impl="auto")
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@given(t=st.integers(16, 64), c=st.integers(1, 16), b=st.integers(1, 5),
       s=st.integers(4, 24), d=st.sampled_from([4, 16, 33]),
       n=st.sampled_from([8, 24]))
@settings(max_examples=15, deadline=None)
def test_auto_dispatch_off_mode_bit_identical_all_ops(t, c, b, s, d, n):
    """auto's candidates run under jit, so the identity contract is jitted
    auto vs jitted ref — eager float math fuses differently and may differ
    in the last ulp."""
    import functools

    rng = np.random.default_rng(t * 7 + c * 3 + s)
    with autotuning(mode="off"):
        # window_gather: [t, c], b windows of span 5
        span = min(5, t - 1)
        series = jnp.asarray(rng.standard_normal((t, c)).astype(np.float32))
        starts = jnp.asarray(
            rng.integers(0, t - span + 1, b).astype(np.int32))
        wg = jax.jit(window_gather, static_argnames=("span", "impl"))
        assert np.array_equal(
            np.asarray(wg(series, starts, span=span, impl="auto")),
            np.asarray(wg(series, starts, span=span, impl="ref")))
        # linear_scan: [b, s, d]
        a = jnp.asarray(rng.uniform(0.7, 1.0, (b, s, d)).astype(np.float32))
        bb = jnp.asarray(rng.standard_normal((b, s, d)).astype(np.float32))
        ls = jax.jit(linear_scan, static_argnames=("impl",))
        for auto_leaf, ref_leaf in zip(ls(a, bb, impl="auto"),
                                       ls(a, bb, impl="ref")):
            assert np.array_equal(np.asarray(auto_leaf), np.asarray(ref_leaf))
        # flash_attention: [1, s, 4, d'] GQA 4:2
        q = jnp.asarray(rng.standard_normal((1, s, 4, 8)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, s, 2, 8)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, s, 2, 8)).astype(np.float32))
        fa = jax.jit(functools.partial(flash_attention, causal=True),
                     static_argnames=("impl",))
        assert np.array_equal(np.asarray(fa(q, k, v, impl="auto")),
                              np.asarray(fa(q, k, v, impl="ref")))
        # diffusion_conv: [b, n, c] with 2 supports, K=2
        sup = _random_supports(rng, n)
        x = jnp.asarray(rng.standard_normal((b, n, c)).astype(np.float32))
        w = jnp.asarray(
            rng.standard_normal((5 * c, 6)).astype(np.float32) * 0.1)
        bias = jnp.asarray(rng.standard_normal((6,)).astype(np.float32))
        dc = jax.jit(functools.partial(diffusion_conv, k_hops=2),
                     static_argnames=("impl",))
        assert np.array_equal(
            np.asarray(dc(x, sup, w, bias, impl="auto")),
            np.asarray(dc(x, sup, w, bias, impl="ref")))


def test_float_ops_auto_within_tolerance_when_tuned():
    """Tune mode may crown a non-reference lowering for the float kernels;
    the admission tolerance (allclose) is then the value contract."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.uniform(0.7, 1.0, (2, 32, 16)).astype(np.float32))
    bb = jnp.asarray(rng.standard_normal((2, 32, 16)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 8)).astype(np.float32))
    sup = _random_supports(rng, 16)
    x = jnp.asarray(rng.standard_normal((2, 16, 4)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((5 * 4, 6)).astype(np.float32) * 0.1)
    bias = jnp.zeros((6,), jnp.float32)
    with tempfile.TemporaryDirectory() as tmp:
        with autotuning(mode="tune", cache_dir=tmp, warmup=0, iters=1):
            ls_auto = linear_scan(a, bb, impl="auto")
            fa_auto = flash_attention(q, q, q, causal=True, impl="auto")
            dc_auto = diffusion_conv(x, sup, w, bias, k_hops=2, impl="auto")
    ls_ref = linear_scan(a, bb, impl="ref")
    fa_ref = flash_attention(q, q, q, causal=True, impl="ref")
    dc_ref = diffusion_conv(x, sup, w, bias, k_hops=2, impl="ref")
    for auto_leaf, ref_leaf in zip(ls_auto, ls_ref):
        np.testing.assert_allclose(np.asarray(auto_leaf),
                                   np.asarray(ref_leaf), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fa_auto), np.asarray(fa_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(dc_auto), np.asarray(dc_ref),
                               atol=2e-3, rtol=2e-3)
