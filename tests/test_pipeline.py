"""The `repro.pipeline` contract, per placement:

(a) the sampler/sharding pairing instantiated by `build_pipeline` matches the
    definition in `core/distributed.py`'s docstring;
(b) a 2-epoch CPU run is bit-identical to a kill-and-resume run through the
    checkpointer (deterministic (seed, epoch) sampling + step-granular
    checkpoints);
(c) every selectable gather reconstructs the same batches from the same
    starts.

Plus regression tests for the train-loop resume fixes and the microbatch
accumulator dtype policy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Placement, WindowSpec
from repro.core.distributed import data_axes, local_time_range
from repro.data import make_traffic_series
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamConfig
from repro.pipeline import GATHERS, PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig
from repro.train.loop import (init_train_state, make_train_step, run_training,
                              zero_grads_like)

ENTRIES, NODES, HORIZON, B, WORLD = 120, 3, 2, 4, 2
SPEC = WindowSpec(horizon=HORIZON, input_len=HORIZON)

EXPECTED_SAMPLER = {
    Placement.REPLICATED: "GlobalShuffleSampler",
    Placement.PARTITIONED: "ShardAlignedBatchSampler",
    Placement.ONDEMAND: "GlobalShuffleSampler",
}


def _params():
    return {"w": jnp.full((NODES, 2), 0.1, jnp.float32)}


def _loss_fn(p, x, y):
    pred = x[:, -1] * p["w"]  # [B, N, F]
    return jnp.mean((pred - y[:, 0]) ** 2), {}


def _pipe(placement, *, ckpt_dir=None, gather="slice", epochs=2, halo=True):
    return build_pipeline(
        make_traffic_series(ENTRIES, NODES), SPEC, make_host_mesh(),
        _loss_fn, _params(),
        PipelineConfig(
            batch_per_rank=B, placement=placement, world=WORLD, gather=gather,
            halo=halo, seed=11, adam=AdamConfig(lr=1e-2),
            loop=TrainLoopConfig(epochs=epochs, log_every=0,
                                 ckpt_dir=ckpt_dir)))


# ------------------------------------------------------- (a) placement pairing
@pytest.mark.parametrize("placement", list(Placement))
def test_sampler_sharding_pairing(placement):
    pipe = _pipe(placement)
    desc = pipe.describe()
    assert desc["sampler"] == EXPECTED_SAMPLER[placement]

    spec = desc["series_spec"]
    if placement is Placement.REPLICATED:
        # full series on every device: PartitionSpec() — no sharded axis
        assert spec == ()
    else:
        # PARTITIONED and ONDEMAND shard the TIME axis over the data axes
        first = spec[0]
        axes = set(first) if isinstance(first, tuple) else {first}
        assert axes == set(data_axes(pipe.mesh))

    grid = pipe.sampler.epoch_global(0)
    assert grid.shape == (pipe.steps_per_epoch, WORLD * B)
    if placement is Placement.PARTITIONED:
        # rank r's draws must start inside the time range of the series
        # shard rank r's device actually owns (local gathers, §5.4) — the
        # same boundaries series_sharding induces (local_time_range)
        blocks = grid.reshape(-1, WORLD, B)
        for r in range(WORLD):
            lo, hi = local_time_range(ENTRIES, r, WORLD)
            assert blocks[:, r, :].min() >= lo
            assert blocks[:, r, :].max() < hi
        # batch CONTENT is fixed (local batch shuffling): every drawn batch
        # in any epoch is one of the rank's pre-built batches; only the
        # choice/order rotates with the epoch
        for epoch in (0, 1):
            b1 = pipe.sampler.epoch_global(epoch).reshape(-1, WORLD, B)
            for r in range(WORLD):
                fixed = {tuple(row) for row in pipe.sampler.rank_batches[r]}
                assert {tuple(row) for row in b1[:, r, :]} <= fixed
        # cyclic rotation: an uneven rank's surplus batches are all visited
        # within ceil(n_batches / steps) epochs (no permanent truncation)
        for r in range(WORLD):
            fixed = {tuple(row) for row in pipe.sampler.rank_batches[r]}
            n_b = pipe.sampler.rank_batches[r].shape[0]
            need = -(-n_b // pipe.steps_per_epoch)
            seen = set()
            for e in range(need):
                rows = pipe.sampler.epoch_global(e).reshape(-1, WORLD, B)[:, r, :]
                seen |= {tuple(row) for row in rows}
            assert seen == fixed
    else:
        # global shuffling: different epochs draw different permutations
        assert not np.array_equal(grid, pipe.sampler.epoch_global(1))


# --------------------------------------------- (b) kill-and-resume determinism
@pytest.mark.parametrize("placement", list(Placement))
def test_resume_bit_identical(placement, tmp_path):
    straight, _ = _pipe(placement).fit(epochs=2, eval_fn=None)

    ckpt = str(tmp_path / placement.value)
    killed = _pipe(placement, ckpt_dir=ckpt)
    killed.fit(epochs=1, eval_fn=None)  # "killed" after epoch 0's checkpoint
    resumed, history = _pipe(placement, ckpt_dir=ckpt).fit(epochs=2,
                                                           eval_fn=None)
    # only epoch 1 ran after the resume
    assert [h["epoch"] for h in history if "epoch_time_s" in h] == [1]
    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- (c) gather agreement
@pytest.mark.parametrize("placement", list(Placement))
def test_gather_variants_agree_on_pipeline_batches(placement):
    pipe = _pipe(placement)
    starts = pipe.batch_of_starts(pipe.sampler.epoch_global(0)[0])
    results = {
        name: fn(pipe.dataset.series, starts,
                 input_len=SPEC.in_len, horizon=SPEC.horizon)
        for name, fn in GATHERS.items()
        if name != "lm"  # different contract: y = shift(x), token streams
    }
    ref_x, ref_y = results.pop("slice")
    assert ref_x.shape == (WORLD * B, SPEC.in_len, NODES, 2)
    for name, (x, y) in results.items():
        assert np.array_equal(np.asarray(ref_x), np.asarray(x)), name
        assert np.array_equal(np.asarray(ref_y), np.asarray(y)), name


@pytest.mark.parametrize("placement", list(Placement))
def test_fit_with_auto_gather_bit_identical_to_slice(placement, tmp_path):
    """gather="auto" fused into the train step (dispatch fires at trace
    time, tuning into a throwaway cache) must leave the training RESULT
    bit-identical to gather="slice" — every candidate the tuner can crown
    is exact data movement, so auto only ever changes speed, never values."""
    from repro.kernels.autotune import autotuning

    base, _ = _pipe(placement, gather="slice").fit(eval_fn=None)
    with autotuning(mode="tune", cache_dir=str(tmp_path), warmup=0, iters=1):
        tuned, _ = _pipe(placement, gather="auto").fit(eval_fn=None)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(tuned)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ per-rank feed contract
@pytest.mark.parametrize("placement", list(Placement))
def test_per_rank_feeds_assemble_epoch_global(placement):
    """epoch_global is ONLY the single-host assembly of the per-rank feed
    columns: concat([feed(r, e) for r in ranks], axis=1) == epoch_global(e)."""
    dp = _pipe(placement).dataplane
    for epoch in (0, 1, 5):
        cols = np.concatenate([dp.feed(r, epoch) for r in range(WORLD)], axis=1)
        assert np.array_equal(cols, dp.epoch_global(epoch))
        assert np.array_equal(dp.epoch_grid(epoch), dp.epoch_global(epoch))


# ------------------------------------------------------------ PARTITIONED halo
def test_partitioned_halo_knob_strictly_interior():
    """halo=False confines every sampled window to its rank's series shard
    (zero data communication); halo=True may spill span−1 steps (more
    samples).  Both surface through PipelineConfig."""
    from repro.core.distributed import local_time_range as ltr

    interior = _pipe(Placement.PARTITIONED, halo=False)
    spilling = _pipe(Placement.PARTITIONED, halo=True)
    assert interior.describe()["halo"] is False
    assert spilling.describe()["halo"] is True
    for r in range(WORLD):
        lo, hi = ltr(ENTRIES, r, WORLD)
        ids = interior.sampler.rank_ids[r]
        assert len(ids) > 0
        assert ids.min() >= lo and ids.max() + SPEC.span <= hi
    n_interior = sum(len(i) for i in interior.sampler.rank_ids)
    n_halo = sum(len(i) for i in spilling.sampler.rank_ids)
    assert n_halo >= n_interior


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="collectives need a >1-device mesh")
def test_partitioned_halo_false_step_is_communication_free():
    """With aligned feeds and halo=False (one rank per device shard), the
    ENGINE's compiled train step must contain zero data collectives — only
    the gradient all-reduce — while halo=True keeps the global-index
    lowering whose gather crosses shards.  Same starts, same loss."""
    from repro.core.distributed import dp_size
    from repro.core.index_dataset import IndexDataset
    from repro.launch.dryrun import collective_bytes
    from repro.train.loop import init_train_state

    mesh = make_host_mesh()
    dp = dp_size(mesh)
    raw = make_traffic_series(16 * dp, NODES)
    # widen the train split so every device shard holds train windows
    ds = IndexDataset.from_raw(raw, SPEC, train=0.97, val=0.01)

    def build(halo):
        return build_pipeline(
            raw, SPEC, mesh, _loss_fn, _params(),
            PipelineConfig(batch_per_rank=2, placement=Placement.PARTITIONED,
                           halo=halo, seed=0, adam=AdamConfig(lr=1e-2),
                           loop=TrainLoopConfig(epochs=1, log_every=0)),
            dataset=ds)

    interior, spilling = build(False), build(True)
    assert interior.describe()["sampler"] == "ShardAlignedBatchSampler"
    starts = interior.batch_of_starts(interior.sampler.epoch_global(0)[0])

    def data_bytes(pipe):
        state = init_train_state(_params(), pipe.config.adam)
        hlo = pipe.train_step.lower(state, starts).compile().as_text()
        coll = collective_bytes(hlo)
        return coll["total"] - coll["all-reduce"]

    assert data_bytes(interior) == 0
    assert data_bytes(spilling) > 0
    # both lowerings see the same windows -> same loss
    _, m_i = interior.train_step(init_train_state(_params(),
                                                  interior.config.adam), starts)
    _, m_s = spilling.train_step(init_train_state(_params(),
                                                  spilling.config.adam), starts)
    np.testing.assert_allclose(float(m_i["loss"]), float(m_s["loss"]),
                               rtol=1e-6)


# -------------------------------------------------------- evaluate ragged tail
def test_evaluate_includes_ragged_tail():
    """The final partial batch of a small split must contribute (window-
    weighted), not be silently dropped — the old loop truncated it and
    biased reported val/test MAE."""
    pipe = _pipe(Placement.REPLICATED)
    params = _params()
    pool = pipe.dataset.val_windows
    b = pipe.global_batch
    assert len(pool) % b != 0 and len(pool) > b  # the split has a ragged tail
    chunks = [pool[i:i + b] for i in range(0, len(pool), b)]
    losses = [float(pipe._eval_loss(params, pipe.batch_of_starts(c))[0])
              for c in chunks]
    expected = float(np.average(losses, weights=[len(c) for c in chunks]))
    got = pipe.evaluate(params)
    assert got == pytest.approx(expected)
    assert got != pytest.approx(losses[0])  # the old full-batches-only value


def test_evaluate_matches_hand_computed_ragged_tail():
    """Window-weighted ``evaluate`` against an expectation computed with
    NOTHING from the pipeline's compute path: numpy gathers on the
    standardized series, a numpy loss, a numpy weighted mean.  Pins the
    PR-2 behavior change (the tail contributes, weighted by its true window
    count) to first principles rather than to the jitted loss itself."""
    pipe = _pipe(Placement.REPLICATED)
    params = _params()
    pool = np.asarray(pipe.dataset.val_windows)
    series = np.asarray(pipe.dataset.series)         # [T, N, F], standardized
    starts = np.asarray(pipe.dataset.starts)
    b = pipe.global_batch
    assert len(pool) % b != 0 and len(pool) > b      # a genuine ragged tail
    w = np.asarray(params["w"], np.float32)

    def hand_loss(chunk):
        s = starts[chunk]
        x = np.stack([series[i:i + SPEC.in_len] for i in s])      # [c, L, N, F]
        y = np.stack([series[i + SPEC.in_len:i + SPEC.in_len + SPEC.horizon]
                      for i in s])                                # [c, H, N, F]
        return np.mean((x[:, -1] * w - y[:, 0]) ** 2, dtype=np.float32)

    chunks = [pool[i:i + b] for i in range(0, len(pool), b)]
    expected = float(np.average([hand_loss(c) for c in chunks],
                                weights=[len(c) for c in chunks]))
    assert pipe.evaluate(params) == pytest.approx(expected, rel=1e-5)
    # the tail really moves the answer: full-batches-only would be wrong
    full_only = float(np.mean([hand_loss(c) for c in chunks if len(c) == b]))
    assert expected != pytest.approx(full_only)


def test_distributed_eval_hand_computed_ragged_tail_weighting():
    """The DISTRIBUTED eval semantics from first principles (ISSUE 4): the
    expectation is computed with NOTHING from the pipeline — numpy gathers
    on the standardized series, a numpy loss, and the explicit
    (weighted_sum, weight) pair reduction over the per-rank eval-feed
    chunks + the ragged tail — exactly the psum-style combine evaluate()
    performs.  Also pins that the per-rank eval_feed columns reassemble
    precisely the chunks the reference scores (nothing dropped, nothing
    double-counted)."""
    pipe = _pipe(Placement.REPLICATED)  # world 2: a genuinely multi-rank plan
    params = _params()
    dp = pipe.dataplane
    pool = dp.eval_pool("val")
    series = np.asarray(pipe.dataset.series)
    starts = np.asarray(pipe.dataset.starts)
    b = pipe.global_batch
    steps = len(pool) // b
    tail = pool[steps * b:]
    assert steps >= 1 and len(tail) > 0  # full chunks AND a ragged tail
    w = np.asarray(params["w"], np.float32)

    def hand_loss(chunk):
        s = starts[np.asarray(chunk)]
        x = np.stack([series[i:i + SPEC.in_len] for i in s])
        y = np.stack([series[i + SPEC.in_len:i + SPEC.in_len + SPEC.horizon]
                      for i in s])
        return np.mean((x[:, -1] * w - y[:, 0]) ** 2, dtype=np.float32)

    # the chunks evaluate() scores are EXACTLY the rank-major assembly of
    # the per-rank eval feeds — the multi-process contract, checked here
    # against the raw pool slices
    rows = np.concatenate([dp.eval_feed(r) for r in range(WORLD)], axis=1)
    assert np.array_equal(rows, pool[:steps * b].reshape(steps, b))
    assert np.array_equal(np.concatenate([rows.ravel(), dp.eval_tail()]), pool)

    # the explicit (weighted_sum, weight) reduction: full chunks weigh b,
    # the tail weighs its true window count
    weighted_sum = np.float64(0.0)
    weight = np.float64(0.0)
    for i in range(steps):
        weighted_sum += np.float64(hand_loss(rows[i])) * b
        weight += b
    weighted_sum += np.float64(hand_loss(tail)) * len(tail)
    weight += len(tail)
    expected = float(weighted_sum / weight)

    assert pipe.evaluate(params) == pytest.approx(expected, rel=1e-5)
    # dropping the tail from the reduction must move the answer — the
    # ragged windows really are weighted in, not truncated
    assert expected != pytest.approx(float((weighted_sum - np.float64(
        hand_loss(tail)) * len(tail)) / (weight - len(tail))))


# ------------------------------------------------------------- LM gather entry
def test_lm_gather_entry_shift_windows():
    stream = jnp.arange(40, dtype=jnp.int32)
    starts = jnp.asarray([0, 3, 7], dtype=jnp.int32)
    x, y = GATHERS["lm"](stream, starts, input_len=5, horizon=1)
    np.testing.assert_array_equal(
        np.asarray(x), [np.arange(s, s + 5) for s in (0, 3, 7)])
    np.testing.assert_array_equal(
        np.asarray(y), [np.arange(s + 1, s + 6) for s in (0, 3, 7)])


def test_lm_pipeline_end_to_end():
    """The LM token-stream workload rides the pipeline via gather='lm'."""
    import dataclasses

    from repro.core.index_dataset import IndexDataset

    rng = np.random.default_rng(0)
    stream = rng.integers(0, 16, size=400).astype(np.int32)
    spec = WindowSpec(horizon=1, input_len=8)
    ds = IndexDataset.from_raw(stream, spec, scale_feature=None)
    ds = dataclasses.replace(ds, series=stream)  # tokens: no standardisation
    params = {"emb": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)}

    def loss_fn(p, toks, labels):
        return jnp.mean((p["emb"][toks] - p["emb"][labels]) ** 2), {}

    pipe = build_pipeline(
        stream, spec, make_host_mesh(), loss_fn, params,
        PipelineConfig(batch_per_rank=4, world=1, gather="lm", seed=3,
                       adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=1, log_every=1)),
        dataset=ds)
    state, history = pipe.fit(eval_fn=None)
    losses = [h["loss"] for h in history if "loss" in h]
    assert losses and all(np.isfinite(l) for l in losses)


def test_lm_eval_feeds_held_out_perplexity_parity():
    """LM epoch-end eval rides the SAME eval-feed machinery as the ST-GNN
    path (ISSUE 5 satellite, ex-ROADMAP item): ``Engine.evaluate`` over the
    ``lm`` gather's val pool must equal a from-first-principles numpy
    expectation — full chunks in pool order plus the ragged tail, combined
    through the explicit (weighted_sum, weight) reduction — and the
    launcher's ``val_ppl`` is just exp() of that number."""
    import dataclasses

    from repro.core.index_dataset import IndexDataset
    from repro.train.loop import combine_weighted

    rng = np.random.default_rng(1)
    vocab = 16
    stream = rng.integers(0, vocab, size=150).astype(np.int32)
    spec = WindowSpec(horizon=1, input_len=8)
    ds = IndexDataset.from_raw(stream, spec, scale_feature=None)
    ds = dataclasses.replace(ds, series=stream)  # tokens: no standardisation
    logits_w = rng.normal(size=(vocab, vocab)).astype(np.float32)
    params = {"w": jnp.asarray(logits_w)}

    def loss_fn(p, toks, labels):
        logp = jax.nn.log_softmax(p["w"][toks], axis=-1)      # [B, L, V]
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return jnp.mean(nll), {}

    pipe = build_pipeline(
        stream, spec, make_host_mesh(), loss_fn, params,
        PipelineConfig(batch_per_rank=4, world=1, gather="lm", seed=3,
                       adam=AdamConfig(lr=1e-2),
                       loop=TrainLoopConfig(epochs=1)),
        dataset=ds)
    pool = np.asarray(ds.val_windows)
    b = pipe.global_batch
    n_full = len(pool) // b
    # both eval paths in play: full chunks AND a ragged tail inside the
    # default max_batches budget
    assert 0 < n_full < 4 and len(pool) % b

    starts = np.asarray(ds.starts)

    def hand_nll(chunk):
        s = starts[chunk]
        x = np.stack([stream[i:i + spec.in_len] for i in s])
        y = np.stack([stream[i + 1:i + 1 + spec.in_len] for i in s])
        logits = logits_w[x].astype(np.float64)
        logp = logits - np.log(np.exp(logits).sum(-1, keepdims=True))
        return float(np.mean(-np.take_along_axis(logp, y[..., None], -1)))

    pairs = [(hand_nll(pool[i * b:(i + 1) * b]), b) for i in range(n_full)]
    pairs.append((hand_nll(pool[n_full * b:]), len(pool) - n_full * b))
    expected = combine_weighted(pairs)
    got = pipe.evaluate(params, split="val")
    assert got == pytest.approx(expected, rel=1e-5)
    assert np.isfinite(np.exp(got))  # the perplexity _train_lm logs


# ------------------------------------------------- train-loop resume hardening
class _StubSampler:
    steps_per_epoch = 4

    def epoch_global(self, epoch):
        return np.arange(4)[:, None] + 10 * epoch


def test_resume_past_partial_epoch_skips_cleanly():
    """start_step beyond an epoch must skip it wholesale: no over-large done
    count, no unbound-metrics crash on the fully-skipped epoch's summary."""
    ran = []

    def train_step(state, batch):
        ran.append(int(batch[0]))
        return state, {"loss": jnp.zeros(())}

    _, history = run_training(
        state={}, train_step=train_step, sampler=_StubSampler(),
        batch_of_starts=lambda row: row,
        loop=TrainLoopConfig(epochs=2, log_every=0),
        start_epoch=0, start_step=6)
    # epoch 0 (4 steps) fully done; epoch 1 resumes at its step 2
    assert ran == [12, 13]
    epochs_logged = [h["epoch"] for h in history if "epoch_time_s" in h]
    assert epochs_logged == [1]


def test_eval_every_sets_epoch_end_eval_cadence():
    """loop.eval_every gates eval_fn by EPOCH INDEX (resume-safe), not by
    call count: every 2nd epoch here, and 0 disables eval entirely."""
    def train_step(state, batch):
        return state, {"loss": jnp.zeros(())}

    def run(eval_every):
        calls = []
        _, history = run_training(
            state={}, train_step=train_step, sampler=_StubSampler(),
            batch_of_starts=lambda row: row,
            loop=TrainLoopConfig(epochs=4, log_every=0,
                                 eval_every=eval_every),
            eval_fn=lambda st: (calls.append(1), {"val_mae": 1.0})[1])
        evald = [h["epoch"] for h in history if "val_mae" in h]
        return calls, evald

    calls, evald = run(2)
    assert evald == [1, 3] and len(calls) == 2  # after epochs 2 and 4
    calls, evald = run(0)
    assert evald == [] and not calls
    calls, evald = run(1)
    assert evald == [0, 1, 2, 3]


def test_resume_mid_epoch_runs_remaining_steps():
    ran = []

    def train_step(state, batch):
        ran.append(int(batch[0]))
        return state, {"loss": jnp.zeros(())}

    run_training(
        state={}, train_step=train_step, sampler=_StubSampler(),
        batch_of_starts=lambda row: row,
        loop=TrainLoopConfig(epochs=1, log_every=0),
        start_epoch=0, start_step=3)
    assert ran == [3]


# --------------------------------------------- microbatch accumulator dtype
def test_zero_grads_match_gradient_dtypes():
    params = {"a": jnp.zeros((2,), jnp.bfloat16), "b": jnp.zeros((3,), jnp.float32)}
    z = zero_grads_like(params, None)
    assert z["a"].dtype == jnp.bfloat16 and z["b"].dtype == jnp.float32
    z16 = zero_grads_like(params, "bfloat16")
    assert z16["a"].dtype == jnp.bfloat16 and z16["b"].dtype == jnp.bfloat16


def test_microbatched_step_keeps_bf16_grad_tree():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}

    def loss_fn(p, batch):
        return jnp.sum(p["w"].astype(jnp.float32)) * jnp.sum(batch), {}

    adam = AdamConfig(lr=1e-2, grad_clip=None)
    step = make_train_step(loss_fn, adam, lambda s: 1e-2, microbatches=2,
                           donate=False)
    state, metrics = step(init_train_state(params, adam),
                          jnp.ones((4,), jnp.float32))
    assert state["params"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(metrics["loss"]))
