"""Request-keyed sampling property tests (PR 10).

The keyed sampler's contract is positional purity: a lane's token is a
function of its OWN ``(seed, rid, position, logits)`` and nothing else.
Properties pinned here:

- slot-permutation invariance: shuffling the batch rows permutes the output
  row identically — a request's draw cannot depend on which slot it sits in;
- co-batch invariance: a lane drawn alone equals the same lane drawn inside
  any batch — co-batched traffic cannot perturb a request's stream;
- greedy identity: a temperature-0 lane is ``argmax`` of the raw logits,
  regardless of its filter settings;
- engine equivalence at temperature > 0: for random request sets,
  ``ServeEngine`` with 1 plane == 2 planes == paged planes == the reference
  ``Server`` (the end-to-end face of the same purity).

Runs under real hypothesis when installed, else the seeded-example fallback
from conftest.py.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import LM_ARCHS
from repro.models.lm import model as lm
from repro.serve import ServeConfig, ServeEngine, Server, keyed_sample
from repro.serve.sampling import TOP_K_OFF, TOP_P_OFF


@pytest.fixture(scope="module")
def lm_setup():
    cfg = LM_ARCHS["qwen1.5-4b"].smoke_config()
    params = lm.init(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _rows(rng, n, vocab=37):
    """A random batch of lanes: logits + per-lane sampling rows (filters on
    for roughly half the lanes, so both code paths stay exercised)."""
    logits = rng.standard_normal((n, vocab)).astype(np.float32)
    rids = rng.integers(0, 1000, n).astype(np.int32)
    seeds = rng.integers(0, 2**32, n, dtype=np.uint32)
    positions = rng.integers(1, 64, n).astype(np.int32)
    temps = rng.uniform(0.2, 2.0, n).astype(np.float32)
    tks = np.where(rng.random(n) < 0.5, rng.integers(1, vocab, n),
                   TOP_K_OFF).astype(np.int32)
    tps = np.where(rng.random(n) < 0.5, rng.uniform(0.3, 1.0, n),
                   TOP_P_OFF).astype(np.float32)
    return logits, rids, seeds, positions, temps, tks, tps


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 8))
def test_keyed_sample_slot_permutation_invariant(seed, n):
    rng = np.random.default_rng(seed)
    rows = _rows(rng, n)
    base = np.asarray(keyed_sample(*rows))
    perm = rng.permutation(n)
    shuffled = np.asarray(keyed_sample(*(r[perm] for r in rows)))
    np.testing.assert_array_equal(shuffled, base[perm])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 8))
def test_keyed_sample_co_batch_invariant(seed, n):
    """Each lane drawn ALONE equals the same lane drawn inside the batch."""
    rng = np.random.default_rng(seed)
    rows = _rows(rng, n)
    batched = np.asarray(keyed_sample(*rows))
    for i in range(n):
        alone = np.asarray(keyed_sample(*(r[i:i + 1] for r in rows)))
        assert alone[0] == batched[i], f"lane {i} perturbed by co-batching"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 8))
def test_keyed_sample_greedy_identity(seed, n):
    """temperature == 0 is argmax of the RAW logits — filters and seeds on a
    greedy lane change nothing (retired lanes rely on this)."""
    rng = np.random.default_rng(seed)
    logits, rids, seeds, positions, _temps, tks, tps = _rows(rng, n)
    temps = np.zeros((n,), np.float32)
    got = np.asarray(keyed_sample(logits, rids, seeds, positions, temps,
                                  tks, tps))
    np.testing.assert_array_equal(got, np.argmax(logits, axis=-1))


# explicit example loop instead of @given: the conftest hypothesis fallback
# cannot mix drawn arguments with pytest fixtures, and lm_setup is needed
@pytest.mark.parametrize("example_seed", [0, 7, 23])
def test_engines_match_server_at_temperature(lm_setup, example_seed):
    """End-to-end purity: for a random request set at temperature > 0, every
    engine shape (1 plane, 2 planes, paged) generates exactly what the
    reference Server generates for the same per-request seeds."""
    cfg, params = lm_setup
    sc = ServeConfig(slots=2, max_len=48, max_new_tokens=4)
    rng = np.random.default_rng(example_seed)
    prompts = [rng.integers(0, 120, size=int(rng.integers(2, 8)))
               for _ in range(5)]
    temps = rng.uniform(0.3, 1.5, size=5)
    seeds = rng.integers(0, 2**16, size=5)

    srv = Server(params, cfg, sc)
    for i, p in enumerate(prompts):
        srv.submit(p, temperature=float(temps[i]), seed=int(seeds[i]))
    ref = srv.run()

    paged = ServeConfig(slots=2, max_len=48, max_new_tokens=4, block_size=4)
    for planes, cfg_e in ((1, sc), (2, sc), (1, paged)):
        eng = ServeEngine(params, cfg, cfg_e, planes=planes)
        rids = [eng.submit(p, temperature=float(temps[i]), seed=int(seeds[i]))
                for i, p in enumerate(prompts)]
        got = eng.run()
        for i, rid in enumerate(rids):
            assert got[rid] == ref[i], \
                f"request {i} diverged (planes={planes}, " \
                f"paged={cfg_e.block_size}, example seed={example_seed})"
