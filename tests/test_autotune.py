"""Autotune dispatcher: bucketing, cache robustness, mode semantics.

The tuning cache is an OPTIONAL artifact: a missing, truncated, corrupt or
foreign-backend ``TUNING_<backend>.json`` must never crash dispatch — the
worst legal outcome is the static per-backend default.  These tests torture
exactly that contract: torn files at arbitrary byte offsets, concurrent
writers, stale variant names, caches tuned for another backend.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.kernels.autotune import (autotuning, bucket_key, cache_path,
                                    dispatch, load_cache, pow2_bucket,
                                    reset_autotune, save_cache, set_autotune,
                                    verdict_for)
from repro.kernels.window_gather.ref import window_gather_ref


@pytest.fixture(autouse=True)
def _clean_policy():
    reset_autotune()
    yield
    reset_autotune()


def _wg_args(t=64, c=8, b=4, span=6):
    rng = np.random.default_rng(0)
    series = rng.standard_normal((t, c)).astype(np.float32)
    starts = rng.integers(0, t - span + 1, b).astype(np.int32)
    return series, starts, span


# ------------------------------------------------------------ shape bucketing
def test_pow2_bucket_envelopes():
    assert [pow2_bucket(n) for n in (0, 1, 2, 3, 5, 16, 17, 1000)] == \
        [1, 1, 2, 4, 8, 16, 32, 1024]


def test_bucket_key_is_stable_and_backend_scoped():
    k = bucket_key("window_gather", "cpu", {"t": 512, "c": 64}, np.float32)
    assert k == "window_gather|cpu|t=512,c=64|float32"
    assert bucket_key("window_gather", "tpu", {"t": 512, "c": 64},
                      np.float32) != k


def test_same_bucket_shares_one_verdict(tmp_path):
    """Shapes inside one power-of-two envelope resolve to the same entry."""
    with autotuning(mode="tune", cache_dir=str(tmp_path), warmup=0, iters=1):
        s1, st1, span = _wg_args(t=40, c=8)
        s2, st2, _ = _wg_args(t=60, c=7)
        dispatch("window_gather", s1, st1, span=span)
        n_after_first = len(load_cache(cache_path("cpu", str(tmp_path)),
                                       "cpu"))
        dispatch("window_gather", s2, st2, span=span)
        n_after_second = len(load_cache(cache_path("cpu", str(tmp_path)),
                                        "cpu"))
    assert n_after_first == n_after_second == 1


# --------------------------------------------------------------- persistence
def test_cache_round_trip(tmp_path):
    path = cache_path("cpu", str(tmp_path))
    entries = {"op|cpu|t=64|float32": {"variant": "ref", "params": {},
                                       "us": 1.5}}
    save_cache(path, "cpu", entries)
    assert load_cache(path, "cpu") == entries


def test_save_merges_with_existing_entries(tmp_path):
    path = cache_path("cpu", str(tmp_path))
    save_cache(path, "cpu", {"a|cpu|t=1|f32": {"variant": "x", "params": {}}})
    save_cache(path, "cpu", {"b|cpu|t=2|f32": {"variant": "y", "params": {}}})
    got = load_cache(path, "cpu")
    assert set(got) == {"a|cpu|t=1|f32", "b|cpu|t=2|f32"}


def test_missing_cache_loads_empty(tmp_path):
    assert load_cache(cache_path("cpu", str(tmp_path)), "cpu") == {}


def test_torn_cache_at_any_offset_loads_empty(tmp_path):
    """A write torn at ANY byte offset (or trailing garbage) never raises."""
    path = cache_path("cpu", str(tmp_path))
    save_cache(path, "cpu", {"op|cpu|t=64|float32": {
        "variant": "ref", "params": {"block": 128}, "us": 1.5}})
    blob = open(path, "rb").read()
    full = load_cache(path, "cpu")
    assert full
    for cut in range(0, len(blob), max(1, len(blob) // 40)):
        with open(path, "wb") as f:
            f.write(blob[:cut])
        got = load_cache(path, "cpu")  # must not raise
        assert got == {} or got == full
    for garbage in (b"{not json", b"\x00\xff" * 10, b"[1, 2, 3]",
                    b'{"entries": 7}', blob + b"trailing"):
        with open(path, "wb") as f:
            f.write(garbage)
        assert load_cache(path, "cpu") == {}


def test_foreign_backend_cache_ignored(tmp_path):
    """A cache tuned on one backend must not steer another's dispatch —
    the file-level backend stamp gates the load."""
    path = cache_path("cpu", str(tmp_path))
    save_cache(path, "tpu", {"op|tpu|t=64|float32": {"variant": "pallas",
                                                     "params": {}}})
    assert load_cache(path, "cpu") == {}
    assert load_cache(path, "tpu") != {}


def test_concurrent_writers_never_corrupt(tmp_path):
    """N racing writers: the file must parse as a valid cache after every
    interleaving, and every surviving entry must be exactly what its writer
    wrote (atomic replace — no torn merges)."""
    path = cache_path("cpu", str(tmp_path))
    written = {f"op{i}|cpu|t=64|float32": {"variant": "ref", "params": {},
                                           "us": float(i)}
               for i in range(16)}
    threads = [threading.Thread(
        target=save_cache, args=(path, "cpu", {k: v}))
        for k, v in written.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = load_cache(path, "cpu")
    assert got  # at least the last writer's entry survived
    for key, entry in got.items():
        assert entry == written[key]
    with open(path) as f:
        raw = json.load(f)  # the file itself is intact JSON
    assert raw["backend"] == "cpu"


# ------------------------------------------------------------- mode semantics
def test_mode_off_uses_static_default():
    series, starts, span = _wg_args()
    with autotuning(mode="off"):
        v = verdict_for("window_gather", series, starts, span=span)
    assert v.source == "default"


def test_mode_load_without_cache_falls_back_to_default(tmp_path):
    series, starts, span = _wg_args()
    with autotuning(mode="load", cache_dir=str(tmp_path)):
        v = verdict_for("window_gather", series, starts, span=span)
        out = dispatch("window_gather", series, starts, span=span)
    assert v.source == "default"
    assert np.array_equal(np.asarray(out),
                          np.asarray(window_gather_ref(series, starts,
                                                       span=span)))


def test_tune_persists_and_load_reads_back(tmp_path):
    series, starts, span = _wg_args()
    with autotuning(mode="tune", cache_dir=str(tmp_path), warmup=0, iters=1):
        tuned = verdict_for("window_gather", series, starts, span=span)
    assert tuned.source == "tuned"
    assert os.path.exists(cache_path("cpu", str(tmp_path)))
    # a fresh "load" policy (fresh memos) reads the persisted verdict
    with autotuning(mode="load", cache_dir=str(tmp_path)):
        loaded = verdict_for("window_gather", series, starts, span=span)
    assert loaded.source == "cache"
    assert loaded.variant == tuned.variant
    assert loaded.params == tuned.params


def test_stale_cached_variant_falls_back_cleanly(tmp_path):
    """A cache naming a variant that no longer exists (older registry
    revision) must dispatch the default, not crash."""
    series, starts, span = _wg_args()
    key = bucket_key("window_gather", "cpu",
                     {"t": series.shape[0], "c": series.shape[1],
                      "b": len(starts), "span": span}, series.dtype)
    save_cache(cache_path("cpu", str(tmp_path)), "cpu",
               {key: {"variant": "does_not_exist", "params": {}}})
    with autotuning(mode="load", cache_dir=str(tmp_path)):
        out = dispatch("window_gather", series, starts, span=span)
    assert np.array_equal(np.asarray(out),
                          np.asarray(window_gather_ref(series, starts,
                                                       span=span)))


def test_set_autotune_rejects_unknown_mode():
    with pytest.raises(ValueError):
        set_autotune(mode="sometimes")
