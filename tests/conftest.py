"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only repro.launch.dryrun forces 512 placeholder devices.

Also installs a seeded-example fallback for ``hypothesis`` so the property
suites (`test_windows.py`, `test_sampler.py`, `test_kernels.py`,
`test_flash_attention.py`, `test_index_batching.py`) run on a bare pytest
install: when the real library is absent, ``@given`` draws a fixed number of
deterministic examples (seeded from the test name) from mini-strategies that
cover the subset of the API these tests use.  With hypothesis installed the
real library is used untouched.
"""
import sys
import types
import zlib

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)

# Cap for the fallback: property tests declare up to 200 examples, which the
# real hypothesis shrinks/reuses efficiently; the seeded fallback just replays
# N deterministic draws, so keep N small enough for a fast CI suite.
_FALLBACK_MAX_EXAMPLES = 25


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rng):
            return self._draw_fn(rng)

    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def floats(lo, hi, **_):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])

    def lists(elem, *, min_size=0, max_size=None):
        hi = min_size + 8 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi + 1))
            return [elem.example(rng) for _ in range(n)]

        return _Strategy(draw)

    def composite(fn):
        def build(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.example(rng), *args, **kwargs))

        return build

    def settings(*, max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            declared = getattr(fn, "_fallback_max_examples", 20)
            n_examples = min(declared, _FALLBACK_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())

            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(seed)
                for _ in range(n_examples):
                    drawn = tuple(s.example(rng) for s in arg_strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # NOT functools.wraps: copying __wrapped__ would make pytest
            # resolve the original signature and demand fixtures for the
            # drawn parameters.
            for attr in ("__name__", "__qualname__", "__doc__", "__module__",
                         "pytestmark"):
                if hasattr(fn, attr):
                    setattr(wrapper, attr, getattr(fn, attr))
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.sampled_from = sampled_from
    strategies.lists = lists
    strategies.composite = composite
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
