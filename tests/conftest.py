"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only repro.launch.dryrun forces 512 placeholder devices.

Also installs a seeded-example fallback for ``hypothesis`` so the property
suites (`test_windows.py`, `test_sampler.py`, `test_kernels.py`,
`test_flash_attention.py`, `test_index_batching.py`) run on a bare pytest
install: when the real library is absent, ``@given`` draws a fixed number of
deterministic examples (seeded from the test name) from mini-strategies that
cover the subset of the API these tests use.  With hypothesis installed the
real library is used untouched.
"""
import sys
import types
import zlib

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)

# Cap for the fallback: property tests declare up to 200 examples, which the
# real hypothesis shrinks/reuses efficiently; the seeded fallback just replays
# N deterministic draws, so keep N small enough for a fast CI suite.
_FALLBACK_MAX_EXAMPLES = 25


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rng):
            return self._draw_fn(rng)

    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def floats(lo, hi, **_):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])

    def lists(elem, *, min_size=0, max_size=None):
        hi = min_size + 8 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi + 1))
            return [elem.example(rng) for _ in range(n)]

        return _Strategy(draw)

    def composite(fn):
        def build(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.example(rng), *args, **kwargs))

        return build

    def settings(*, max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            declared = getattr(fn, "_fallback_max_examples", 20)
            n_examples = min(declared, _FALLBACK_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())

            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(seed)
                for _ in range(n_examples):
                    drawn = tuple(s.example(rng) for s in arg_strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # NOT functools.wraps: copying __wrapped__ would make pytest
            # resolve the original signature and demand fixtures for the
            # drawn parameters.
            for attr in ("__name__", "__qualname__", "__doc__", "__module__",
                         "pytestmark"):
                if hasattr(fn, attr):
                    setattr(wrapper, attr, getattr(fn, attr))
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.sampled_from = sampled_from
    strategies.lists = lists
    strategies.composite = composite
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


# --------------------------------------------------- multi-host harness kit
# Fixtures for tests that drive REAL subprocesses (tests/multihost.py): the
# pytest process itself has already initialised a single-CPU jax backend, so
# every jax.distributed participant must be a fresh subprocess with its own
# XLA_FLAGS/coordinator env — these fixtures own that plumbing.

@pytest.fixture
def free_port():
    """Callable returning an OS-assigned free TCP port (coordinator/transport
    addresses for subprocess fleets)."""
    import socket

    def get() -> int:
        with socket.socket() as s:
            s.bind(("127.0.0.1", 0))
            return s.getsockname()[1]

    return get


@pytest.fixture(scope="session")
def repo_root():
    import os
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def results_dir(repo_root):
    """``results/`` at the repo root — where harnesses drop the JSON evidence
    files CI uploads as artifacts."""
    import os
    d = os.path.join(repo_root, "results")
    os.makedirs(d, exist_ok=True)
    return d


@pytest.fixture
def mh_spawn(repo_root):
    """Launch ``tests/multihost.py`` subprocess roles (worker / announce).

    Returns ``spawn(argv, *, devices, log) -> subprocess.Popen``: PYTHONPATH
    points at ``src``, XLA_FLAGS forces ``devices`` CPU devices, and stdout/
    stderr append to the ``log`` file (pipes would deadlock on XLA's crash
    dumps, and the files double as CI artifacts).  Every spawned process is
    terminated at fixture teardown so a failing driver can't leak a fleet.
    """
    import os
    import subprocess
    import sys

    procs: list[subprocess.Popen] = []
    logs: list = []
    script = os.path.join(repo_root, "tests", "multihost.py")

    def spawn(argv, *, devices: int = 1, log: str | None = None):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(repo_root, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        env.setdefault("JAX_PLATFORMS", "cpu")
        if log is not None:
            sink = open(log, "a")
            logs.append(sink)
        else:
            sink = subprocess.DEVNULL
        p = subprocess.Popen([sys.executable, script, *[str(a) for a in argv]],
                             env=env, stdout=sink, stderr=subprocess.STDOUT)
        procs.append(p)
        return p

    yield spawn
    for p in procs:
        if p.poll() is None:
            p.kill()
            p.wait()
    for f in logs:
        f.close()
