"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only repro.launch.dryrun forces 512 placeholder devices."""
import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
