"""Full dry-run campaign runner: one shard of the (arch × shape × mesh) matrix.

Usage: python results/campaign.py <shard_idx> <n_shards> <out.json>
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys

from repro.launch.dryrun import run_cell
from repro.launch.specs import all_cells
from repro.configs import get_arch

shard, n_shards, out = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]

jobs = []
for aid, shape, skip in all_cells():
    if skip:
        jobs.append(("skip", aid, shape, skip, False))
        continue
    for mp in (False, True):
        jobs.append(("run", aid, shape, None, mp))
# ST-GNN extra placements (paper comparison: baseline-DDP vs generalized)
for aid in ("dcrnn-pems", "pgt-dcrnn-pems-all-la"):
    shape = get_arch(aid).shapes[0].name
    for placement in ("partitioned", "ondemand"):
        jobs.append(("run-st", aid, shape, placement, False))

records = []
for i, job in enumerate(jobs):
    if i % n_shards != shard:
        continue
    kind, aid, shape, extra, mp = job
    if kind == "skip":
        records.append({"arch": aid, "shape": shape, "status": "skipped",
                        "reason": extra, "mesh": "-"})
        print(f"[skip] {aid}:{shape}")
        continue
    kw = {}
    if kind == "run-st":
        kw["placement"] = extra
    records.append(run_cell(aid, shape, multi_pod=mp, **kw))

with open(out, "w") as f:
    json.dump(records, f, indent=1)
print(f"shard {shard}: wrote {len(records)} records")
