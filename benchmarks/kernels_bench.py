"""Kernel micro-benchmarks: jitted ref vs Pallas(interpret) vs auto dispatch.

Every arm is JITTED before timing — eager wall time is dominated by per-op
Python dispatch and says nothing about the lowering.  Wall times on CPU are
still NOT kernel performance (interpret mode runs the kernel body in Python;
the roofline analysis covers TPU projections) — this harness exists to

- pin correctness at benchmark shapes (each pallas arm is checked against
  its oracle before it is timed),
- time the lowerings the CPU path actually chooses, and
- record what the measured ``auto`` dispatcher (``repro.kernels.autotune``)
  picks for each op, so a dispatch regression (auto slower than the best
  static arm) shows up in the artifact trend.

With ``--out`` the rows are serialised to ``results/BENCH_kernels.json``
(same record shape as BENCH_smoke.json: ``headline`` + ``rows``), which the
CI bench job trend-gates against the previous artifact.

Usage: PYTHONPATH=src python -m benchmarks.kernels_bench \
           [--out results/BENCH_kernels.json] [--autotune {off,load,tune}]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import recording, row, timed
from repro.kernels import (diffusion_conv, diffusion_conv_ref,
                           flash_attention, linear_scan, linear_scan_ref,
                           verdict_for, window_gather, window_gather_ref)
from repro.pipeline.gathers import GATHERS


def _us(t: float) -> str:
    return f"{1e6 * t:.1f}"


def _suite(smoke: bool) -> None:
    rng = np.random.default_rng(0)

    # window_gather at PeMS-like row width
    t_len, c, b = (512, 64, 16) if smoke else (2048, 256, 32)
    series = jnp.asarray(rng.standard_normal((t_len, c)).astype(np.float32))
    starts = jnp.asarray(
        rng.integers(0, t_len - 48, b).astype(np.int32))
    ref = jax.jit(window_gather_ref, static_argnames=("span",))
    pal = jax.jit(functools.partial(window_gather, use_pallas=True),
                  static_argnames=("span",))
    auto = jax.jit(functools.partial(window_gather, impl="auto"),
                   static_argnames=("span",))
    shape = f"[{t_len},{c}] b={b} span=24"
    ok = np.array_equal(np.asarray(pal(series, starts, span=24)),
                        np.asarray(ref(series, starts, span=24)))
    row("kernels/window_gather_pallas_ok", int(ok), "bool", "interpret mode")
    row("kernels/window_gather_ref_us",
        _us(timed(lambda: ref(series, starts, span=24), iters=5)), "us", shape)
    row("kernels/window_gather_pallas_us",
        _us(timed(lambda: pal(series, starts, span=24))), "us",
        shape + ", interpret")
    t_auto = timed(lambda: auto(series, starts, span=24), iters=5)
    v = verdict_for("window_gather", np.asarray(series), np.asarray(starts),
                    span=24)
    row("kernels/window_gather_auto_us", _us(t_auto), "us",
        f"{shape} -> {v.variant} ({v.source})")
    ok = np.array_equal(np.asarray(auto(series, starts, span=24)),
                        np.asarray(ref(series, starts, span=24)))
    row("kernels/window_gather_auto_ok", int(ok), "bool",
        f"variant={v.variant}")

    # the fused-train-step (x, y) gather: every named pipeline variant
    il, hz = 12, 12
    for name in ("slice", "take", "fused", "pallas", "auto"):
        fn = jax.jit(functools.partial(GATHERS[name], input_len=il,
                                       horizon=hz))
        xs, ys = fn(series, starts)
        rx, ry = GATHERS["slice"](series, starts, input_len=il, horizon=hz)
        ok = (np.array_equal(np.asarray(xs), np.asarray(rx))
              and np.array_equal(np.asarray(ys), np.asarray(ry)))
        detail = f"[{t_len},{c}] b={b} L={il} H={hz}"
        if name == "auto":
            v = verdict_for("gather", np.asarray(series), np.asarray(starts),
                            input_len=il, horizon=hz)
            detail += f" -> {v.variant} ({v.source})"
        t = timed(lambda: fn(series, starts),
                  iters=2 if name == "pallas" else 5)
        row(f"kernels/gather_{name}_us", _us(t), "us", detail)
        row(f"kernels/gather_{name}_ok", int(ok), "bool", "")
        if not ok:
            raise SystemExit(f"gather variant {name!r} diverged from slice")

    # linear_scan at RG-LRU width
    bsz, s, d = (4, 256, 64) if smoke else (8, 1024, 256)
    a = jnp.asarray(rng.uniform(0.9, 1.0, (bsz, s, d)).astype(np.float32))
    bb = jnp.asarray(rng.standard_normal((bsz, s, d)).astype(np.float32))
    h0 = jnp.zeros((bsz, d), jnp.float32)
    ref = jax.jit(linear_scan_ref)
    pal = jax.jit(functools.partial(linear_scan, use_pallas=True, chunk=256))
    auto = jax.jit(functools.partial(linear_scan, impl="auto"))
    shape = f"[{bsz},{s},{d}]"
    ps, _ = pal(a, bb, h0)
    rs, _ = ref(a, bb, h0)
    row("kernels/linear_scan_pallas_maxerr",
        f"{float(jnp.max(jnp.abs(ps - rs))):.2e}", "abs", "interpret mode")
    row("kernels/linear_scan_ref_us",
        _us(timed(lambda: ref(a, bb, h0), iters=5)), "us", shape)
    row("kernels/linear_scan_pallas_us",
        _us(timed(lambda: pal(a, bb, h0))), "us", shape + ", interpret")
    t_auto = timed(lambda: auto(a, bb, h0), iters=5)
    v = verdict_for("linear_scan", np.asarray(a), np.asarray(bb),
                    np.asarray(h0))
    row("kernels/linear_scan_auto_us", _us(t_auto), "us",
        f"{shape} -> {v.variant} ({v.source})")

    # flash attention at a train_4k-like tile (GQA 8:2)
    sq = 256 if smoke else 512
    q = jnp.asarray(rng.standard_normal((1, sq, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, sq, 2, 64)).astype(np.float32))
    v_ = jnp.asarray(rng.standard_normal((1, sq, 2, 64)).astype(np.float32))
    ref = jax.jit(functools.partial(flash_attention, causal=True,
                                    use_pallas=False))
    pal = jax.jit(functools.partial(flash_attention, causal=True,
                                    use_pallas=True, block_q=128,
                                    block_k=128))
    auto = jax.jit(functools.partial(flash_attention, causal=True,
                                     impl="auto"))
    shape = f"[1,{sq},8x64] GQA2"
    err = float(jnp.max(jnp.abs(pal(q, k, v_) - ref(q, k, v_))))
    row("kernels/flash_attention_maxerr", f"{err:.2e}", "abs",
        "interpret mode")
    row("kernels/flash_attention_ref_us",
        _us(timed(lambda: ref(q, k, v_), iters=5)), "us", shape)
    row("kernels/flash_attention_pallas_us",
        _us(timed(lambda: pal(q, k, v_), iters=2)), "us",
        shape + ", interpret")
    t_auto = timed(lambda: auto(q, k, v_), iters=5)
    vd = verdict_for("flash_attention", np.asarray(q), np.asarray(k),
                     np.asarray(v_), causal=True)
    row("kernels/flash_attention_auto_us", _us(t_auto), "us",
        f"{shape} -> {vd.variant} ({vd.source})")

    # diffusion_conv at PeMS-All-LA-ish block
    n, c, h, kh = (128, 8, 16, 2) if smoke else (256, 16, 32, 2)
    adj = rng.uniform(0, 1, (n, n)).astype(np.float32)
    adj[adj < 0.6] = 0
    np.fill_diagonal(adj, 1)
    sup = (jnp.asarray(adj / adj.sum(1, keepdims=True)),
           jnp.asarray(adj.T / adj.T.sum(1, keepdims=True)))
    x = jnp.asarray(rng.standard_normal((4, n, c)).astype(np.float32))
    w = jnp.asarray(
        rng.standard_normal(((1 + 2 * kh) * c, h)).astype(np.float32) * 0.1)
    bias = jnp.zeros((h,), jnp.float32)
    ref = jax.jit(functools.partial(diffusion_conv_ref, k_hops=kh))
    pal = jax.jit(functools.partial(diffusion_conv, k_hops=kh,
                                    use_pallas=True, block_n=128))
    auto = jax.jit(functools.partial(diffusion_conv, k_hops=kh, impl="auto"))
    shape = f"N={n} K={kh}"
    err = float(jnp.max(jnp.abs(pal(x, sup, w, bias) - ref(x, sup, w, bias))))
    row("kernels/diffusion_conv_pallas_maxerr", f"{err:.2e}", "abs",
        "interpret mode")
    row("kernels/diffusion_conv_ref_us",
        _us(timed(lambda: ref(x, sup, w, bias), iters=5)), "us", shape)
    row("kernels/diffusion_conv_pallas_us",
        _us(timed(lambda: pal(x, sup, w, bias), iters=2)), "us",
        shape + ", interpret")
    t_auto = timed(lambda: auto(x, sup, w, bias), iters=5)
    vd = verdict_for("diffusion_conv", np.asarray(x),
                     tuple(np.asarray(s) for s in sup), np.asarray(w),
                     np.asarray(bias), k_hops=kh, n_supports=2)
    row("kernels/diffusion_conv_auto_us", _us(t_auto), "us",
        f"{shape} -> {vd.variant} ({vd.source})")


def _pick(records: list[dict], name: str) -> float:
    vals = [float(r["value"]) for r in records if r["name"] == name]
    if not vals:
        raise SystemExit(f"kernels-bench produced no '{name}' record")
    return vals[0]


def main(smoke: bool = False, out: str | None = None,
         autotune: str | None = None, tuning_dir: str = "results") -> None:
    if autotune is not None:
        from repro.kernels import set_autotune
        set_autotune(mode=autotune, cache_dir=tuning_dir)
    if out is None:
        _suite(smoke)
        return
    t0 = time.perf_counter()
    with recording() as records:
        _suite(smoke)
    wall = time.perf_counter() - t0
    payload = {
        "schema": 1,
        "kind": "bench-kernels",
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "autotune": autotune or "load",
        "smoke": smoke,
        "wall_s": round(wall, 2),
        "headline": {
            "gather_auto_us": _pick(records, "kernels/gather_auto_us"),
            "gather_slice_us": _pick(records, "kernels/gather_slice_us"),
            "window_gather_auto_us": _pick(
                records, "kernels/window_gather_auto_us"),
            "linear_scan_auto_us": _pick(
                records, "kernels/linear_scan_auto_us"),
            "flash_attention_auto_us": _pick(
                records, "kernels/flash_attention_auto_us"),
            "diffusion_conv_auto_us": _pick(
                records, "kernels/diffusion_conv_auto_us"),
        },
        "rows": records,
    }
    out_dir = os.path.dirname(out) or "."
    os.makedirs(out_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".bench-", dir=out_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, out)
    print(f"# kernels-bench done in {wall:.1f}s -> {out}")
    print(json.dumps(payload["headline"], indent=1))


def _cli(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write a BENCH_kernels.json record here "
                         "(default: rows to stdout only)")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller shapes (the CI bench leg)")
    ap.add_argument("--autotune", choices=("off", "load", "tune"),
                    default="load",
                    help="kernel autotune policy for the 'auto' arms")
    ap.add_argument("--tuning-dir", default="results",
                    help="directory holding TUNING_<backend>.json")
    args = ap.parse_args(argv)
    print("name,value,unit,detail")
    main(smoke=args.smoke, out=args.out, autotune=args.autotune,
         tuning_dir=args.tuning_dir)


if __name__ == "__main__":
    _cli()
