"""Kernel micro-benchmarks: oracle vs Pallas(interpret) correctness timing.

Wall times on CPU are NOT kernel performance (interpret mode runs the kernel
body in Python) — the roofline analysis covers TPU projections.  This harness
exists to pin correctness at benchmark shapes and to time the pure-jnp
fallbacks that the CPU path actually uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import (diffusion_conv, diffusion_conv_ref, gather_xy,
                           linear_scan, linear_scan_ref, window_gather,
                           window_gather_ref)


def main() -> None:
    rng = np.random.default_rng(0)

    # window_gather at PeMS-like row width
    series = jnp.asarray(rng.standard_normal((2048, 256)).astype(np.float32))
    starts = jnp.asarray(rng.integers(0, 2000, 32).astype(np.int32))
    t = timed(lambda: window_gather_ref(series, starts, span=24))
    row("kernels/window_gather_ref", f"{1e6 * t:.0f}", "us", "[2048,256] b=32")
    pal = window_gather(series, starts, span=24, use_pallas=True)
    ok = np.array_equal(np.asarray(pal),
                        np.asarray(window_gather_ref(series, starts, span=24)))
    row("kernels/window_gather_pallas_ok", int(ok), "bool", "interpret mode")

    # linear_scan at RG-LRU width
    a = jnp.asarray(rng.uniform(0.9, 1.0, (8, 1024, 256)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((8, 1024, 256)).astype(np.float32))
    t = timed(lambda: linear_scan_ref(a, b, jnp.zeros((8, 256))))
    row("kernels/linear_scan_ref", f"{1e3 * t:.2f}", "ms", "[8,1024,256]")
    ps, pl = linear_scan(a, b, None, use_pallas=True, chunk=256)
    rs, rl = linear_scan_ref(a, b, jnp.zeros((8, 256)))
    row("kernels/linear_scan_pallas_maxerr",
        f"{float(jnp.max(jnp.abs(ps - rs))):.2e}", "abs", "")

    # flash attention at a train_4k-like tile
    from repro.kernels import flash_attention
    from repro.models.lm.attention import full_attention

    q = jnp.asarray(rng.standard_normal((1, 512, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)).astype(np.float32))
    t = timed(lambda: full_attention(q, k, v, causal=True))
    row("kernels/full_attention_ref", f"{1e3 * t:.2f}", "ms", "[1,512,8x64] GQA2")
    pal = flash_attention(q, k, v, causal=True, use_pallas=True,
                          block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(pal - full_attention(q, k, v, causal=True))))
    row("kernels/flash_attention_maxerr", f"{err:.2e}", "abs", "interpret mode")

    # diffusion_conv at PeMS-All-LA-ish block
    n, c, h, k = 256, 16, 32, 2
    adj = rng.uniform(0, 1, (n, n)).astype(np.float32)
    adj[adj < 0.6] = 0
    np.fill_diagonal(adj, 1)
    sup = (jnp.asarray(adj / adj.sum(1, keepdims=True)),
           jnp.asarray(adj.T / adj.T.sum(1, keepdims=True)))
    x = jnp.asarray(rng.standard_normal((4, n, c)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(((1 + 2 * k) * c, h)).astype(np.float32) * 0.1)
    bias = jnp.zeros((h,))
    t = timed(lambda: diffusion_conv_ref(x, sup, w, bias, k_hops=k))
    row("kernels/diffusion_conv_ref", f"{1e3 * t:.2f}", "ms", f"N={n} K={k}")
    pal = diffusion_conv(x, sup, w, bias, k_hops=k, use_pallas=True, block_n=128)
    ref = diffusion_conv_ref(x, sup, w, bias, k_hops=k)
    row("kernels/diffusion_conv_pallas_maxerr",
        f"{float(jnp.max(jnp.abs(pal - ref))):.2e}", "abs", "")


if __name__ == "__main__":
    main()
