"""Paper Fig 9 / §5.4: generalized-distributed-index-batching vs baseline DDP
for larger-than-memory series — data volume moved per epoch.

The decisive quantity is bytes communicated to assemble batches: the
generalized variant gathers only from the LOCAL time shard (0 inter-worker
bytes; halo windows cost one boundary exchange), while baseline DDP ships
every window from whichever shard owns it.  We count both exactly from the
sampler + placement math, and time the local-gather step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import IndexDataset, WindowSpec, gather_batch
from repro.core.distributed import local_time_range, local_window_ids
from repro.data import make_traffic_series

N, ENTRIES, B_PER, WORLD = 32, 2_048, 16, 8


def main() -> None:
    spec = WindowSpec(horizon=6, input_len=6)
    series = make_traffic_series(ENTRIES, N)
    window_bytes = spec.span * N * 2 * 4

    # generalized: per-rank local windows (interior) — zero communication
    total_local = 0
    for r in range(WORLD):
        ids = local_window_ids(ENTRIES, spec, r, WORLD, halo=False)
        total_local += len(ids)
    row("fig9/generalized_windows", total_local, "windows",
        f"interior windows across {WORLD} ranks; inter-worker bytes = 0")
    lost = (ENTRIES - spec.span + 1) - total_local
    row("fig9/generalized_halo_loss", lost, "windows",
        f"{100 * lost / (ENTRIES - spec.span + 1):.2f}% of samples skipped "
        "(or one halo exchange of span-1 rows per boundary)")

    # baseline DDP: every sampled window crosses the network with prob (w-1)/w
    steps = total_local // (B_PER * WORLD)
    ddp_bytes = steps * B_PER * WORLD * window_bytes * (WORLD - 1) / WORLD
    row("fig9/ddp_epoch_bytes", f"{ddp_bytes / 2**20:.1f}", "MiB/epoch",
        "expected on-demand shipping volume")
    row("fig9/generalized_epoch_bytes", "0.0", "MiB/epoch", "local gathers only")

    # time one local-shard gather step (the generalized inner loop)
    r0 = local_time_range(ENTRIES, 0, WORLD)
    shard = jnp.asarray(series[r0[0]:r0[1] + spec.span - 1])
    ids0 = jnp.asarray(
        local_window_ids(ENTRIES, spec, 0, WORLD, halo=False)[:B_PER])

    def step():
        return gather_batch(shard, ids0 - r0[0], input_len=6, horizon=6)

    row("fig9/local_gather_step", f"{1e6 * timed(step):.0f}", "us", "")


if __name__ == "__main__":
    main()
