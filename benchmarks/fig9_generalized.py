"""Paper Fig 9 / §5.4: generalized-distributed-index-batching vs baseline DDP
for larger-than-memory series — data volume moved per epoch.

The decisive quantity is bytes communicated to assemble batches: the
generalized variant gathers only from the LOCAL time shard (0 inter-worker
bytes; halo windows cost one boundary exchange), while baseline DDP ships
every window from whichever shard owns it.  We count both exactly from the
placement math in `core/distributed.py`, and time the PARTITIONED
`repro.pipeline` step (local-shard gather fused with grad+Adam).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import IndexDataset, Placement, WindowSpec
from repro.core.distributed import local_window_ids
from repro.data import make_traffic_series
from repro.launch.mesh import make_host_mesh
from repro.pipeline import PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig
from repro.train.loop import init_train_state

N, ENTRIES, B_PER, WORLD = 32, 2_048, 16, 8


def main() -> None:
    spec = WindowSpec(horizon=6, input_len=6)
    series = make_traffic_series(ENTRIES, N)
    window_bytes = spec.span * N * 2 * 4

    # generalized: per-rank local windows (interior) — zero communication
    total_local = 0
    for r in range(WORLD):
        ids = local_window_ids(ENTRIES, spec, r, WORLD, halo=False)
        total_local += len(ids)
    row("fig9/generalized_windows", total_local, "windows",
        f"interior windows across {WORLD} ranks; inter-worker bytes = 0")
    lost = (ENTRIES - spec.span + 1) - total_local
    row("fig9/generalized_halo_loss", lost, "windows",
        f"{100 * lost / (ENTRIES - spec.span + 1):.2f}% of samples skipped "
        "(or one halo exchange of span-1 rows per boundary)")

    # baseline DDP: every sampled window crosses the network with prob (w-1)/w
    steps = total_local // (B_PER * WORLD)
    ddp_bytes = steps * B_PER * WORLD * window_bytes * (WORLD - 1) / WORLD
    row("fig9/ddp_epoch_bytes", f"{ddp_bytes / 2**20:.1f}", "MiB/epoch",
        "expected on-demand shipping volume")
    row("fig9/generalized_epoch_bytes", "0.0", "MiB/epoch", "local gathers only")

    # time one PARTITIONED pipeline step (the generalized inner loop): the
    # shard-aligned sampler draws rank-local batches, so the gather reads
    # only the local series shard.  The train split is widened so every
    # rank's shard holds at least one batch of train windows (a 70/10/20
    # tail would leave the last ranks empty and force the count-split
    # fallback, whose gathers may cross shards).
    def loss_fn(p, x, y):
        err = jnp.mean((x[:, -1] * p["w"] - y[:, 0]) ** 2)
        return err, {}

    ds = IndexDataset.from_raw(series, spec, train=0.9, val=0.05)
    pipe = build_pipeline(
        series, spec, make_host_mesh(), loss_fn, {"w": jnp.ones(())},
        PipelineConfig(batch_per_rank=B_PER, placement=Placement.PARTITIONED,
                       world=WORLD, loop=TrainLoopConfig(donate=False)),
        dataset=ds)
    assert pipe.describe()["sampler"] == "ShardAlignedBatchSampler"
    rank0 = pipe.sampler.epoch(0)[0]
    starts0 = pipe.batch_of_starts(rank0)
    state = init_train_state({"w": jnp.ones(())}, pipe.config.adam)
    t = timed(lambda: pipe.train_step(state, starts0)[1]["loss"])
    row("fig9/local_step", f"{1e6 * t:.0f}", "us",
        "rank-0 local-batch fused gather+step")


if __name__ == "__main__":
    main()
