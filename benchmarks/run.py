"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig7,table3] [--smoke]
Emits ``name,value,unit,detail`` CSV rows; §Dry-run/§Roofline numbers come
from results/dryrun_full.json (produced by repro.launch.dryrun --all).

``--smoke`` shrinks the suites that support it (fig7, table3) to tiny
synthetic sizes — the CI bench-smoke leg runs them through
``benchmarks/smoke.py``, which also serialises the rows to BENCH_smoke.json.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from benchmarks import (fig7_scaling, fig9_generalized, kernels_bench,
                        serve_bench, table1_memory, table2_case_study,
                        table3_index_vs_base, table4_gpu_index,
                        table5_shuffling, table6_a3tgcn)

SUITES = {
    "table1": table1_memory.main,
    "table2": table2_case_study.main,
    "table3": table3_index_vs_base.main,
    "table4": table4_gpu_index.main,
    "table5": table5_shuffling.main,
    "fig7": fig7_scaling.main,
    "fig9": fig9_generalized.main,
    "table6": table6_a3tgcn.main,
    "kernels": kernels_bench.main,
    "serve": serve_bench.main,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic sizes on suites that support it")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    failed = []
    print("name,value,unit,detail")
    for name in names:
        t0 = time.perf_counter()
        print(f"# --- {name} ---")
        fn = SUITES[name]
        kw = ({"smoke": True} if args.smoke
              and "smoke" in inspect.signature(fn).parameters else {})
        try:
            fn(**kw)
        except Exception:  # noqa: BLE001 — keep the harness going
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
