"""Paper Table 4: index-batching vs GPU-index-batching.

The contrast is WHERE batches are assembled: host (numpy slice + per-step
device_put — the paper's CPU index-batching) vs device (resident series +
on-device gather — GPU-index-batching, our default).  The measured gap is the
per-step H2D transfer the paper eliminates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import IndexDataset, WindowSpec, gather_batch
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn

N, ENTRIES, B = 48, 800, 32


def main() -> None:
    spec = WindowSpec(horizon=6, input_len=6)
    ds = IndexDataset.from_raw(make_traffic_series(ENTRIES, N), spec)
    adj = gaussian_adjacency(random_sensor_coords(N))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=16, input_len=6, horizon=6)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)
    grad = jax.jit(jax.grad(lambda p, x, y: pgt_dcrnn.loss_fn(p, cfg, sup, x, y)))

    host_series = np.asarray(ds.series)
    ids = ds.starts[:B]

    def host_batched_step():
        # CPU index-batching: slice on host, ship the BATCH each step
        x = np.stack([host_series[s:s + 6] for s in ids])
        y = np.stack([host_series[s + 6:s + 12] for s in ids])
        return grad(params, jnp.asarray(x), jnp.asarray(y))

    dev_series = jnp.asarray(ds.series)  # ONE transfer, then resident
    dev_starts = jnp.asarray(ids)

    def device_step():
        x, y = gather_batch(dev_series, dev_starts, input_len=6, horizon=6)
        return grad(params, x, y)

    t_host = timed(host_batched_step)
    t_dev = timed(device_step)
    row("table4/host_index_step", f"{1e3 * t_host:.2f}", "ms",
        "batch assembled on host + H2D per step")
    row("table4/gpu_index_step", f"{1e3 * t_dev:.2f}", "ms",
        "resident series, on-device gather")
    row("table4/speedup", f"{t_host / t_dev:.2f}", "x",
        "paper reports 12.87% end-to-end at PeMS scale")


if __name__ == "__main__":
    main()
