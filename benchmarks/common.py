"""Shared benchmark utilities: timing, CSV emission, reduced-scale knobs.

Every benchmark mirrors one paper table/figure at CPU-container scale (the
full-scale numbers come from the dry-run roofline, results/dryrun_full.json).
Output convention: ``name,value,unit,detail`` CSV rows on stdout.

``recording()`` additionally captures every row as a dict — the bench-smoke
harness (``benchmarks/smoke.py``) runs the suites under it and serialises
the records to ``BENCH_smoke.json`` for the CI perf trajectory.
"""
from __future__ import annotations

import contextlib
import sys
import time

import jax

#: When set (by ``recording()``), every ``row()`` call also appends a dict
#: here — the machine-readable mirror of the CSV stream.
RECORDS: list | None = None


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, value, unit: str, detail: str = "") -> None:
    print(f"{name},{value},{unit},{detail}")
    if RECORDS is not None:
        RECORDS.append({"name": name, "value": value, "unit": unit,
                        "detail": detail})


@contextlib.contextmanager
def recording():
    """Capture every ``row()`` emitted in the block as dicts (and still
    print the CSV).  Yields the record list."""
    global RECORDS
    prev, RECORDS = RECORDS, []
    try:
        yield RECORDS
    finally:
        RECORDS = prev


def peak_rss_bytes() -> int:
    """Peak resident set size of THIS process, in bytes (ru_maxrss is KiB on
    Linux, bytes on macOS)."""
    import resource
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(rss if sys.platform == "darwin" else rss * 1024)
