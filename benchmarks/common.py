"""Shared benchmark utilities: timing, CSV emission, reduced-scale knobs.

Every benchmark mirrors one paper table/figure at CPU-container scale (the
full-scale numbers come from the dry-run roofline, results/dryrun_full.json).
Output convention: ``name,value,unit,detail`` CSV rows on stdout.
"""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, value, unit: str, detail: str = "") -> None:
    print(f"{name},{value},{unit},{detail}")
