"""Serving load benchmark: replay arrival traces through the ServeEngine.

Replays a Poisson trace (independent arrivals at ``--rate`` req/s) and a
bursty trace (whole bursts at once, the tail-latency stressor) through
``repro.serve.ServeEngine`` on a reduced config, recording what a serving
fleet is judged on:

- ``serve_p50_ms`` / ``serve_p99_ms`` — request latency (admission→finish,
  INCLUDING queueing; that is what a client sees) over the Poisson trace,
- ``serve_tokens_s`` — generated-token throughput over the Poisson replay,
- ``serve_sampled_tokens_s`` — sampled-decode (temperature > 0) throughput;
  the same arm ASSERTS request-keyed determinism (same per-request seeds →
  identical tokens from 1-plane, 2-plane and paged engines) on every run,
- slot occupancy and backpressure rejects per trace (rows only — occupancy
  is a utilization diagnostic, not a regression gate).

Wall times on CPU CI are noisy; the trend gate's warn band absorbs that —
the fail band catches real regressions (an accidental per-lane sync in the
decode loop roughly doubles p50 at smoke scale, far outside jitter).

Every (group-size × prompt-length) prefill bucket and the decode step are
compiled during warmup so the replayed percentiles measure serving, not XLA.

Usage: PYTHONPATH=src python -m benchmarks.serve_bench --smoke \
           [--out results/BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import recording, row
from repro.configs import LM_ARCHS
from repro.models.lm import model as lm
from repro.serve import Backpressure, ServeConfig, ServeEngine

#: prompt-length buckets — few distinct lengths keep the batched-prefill
#: groups large and the warmup compile set small
PLENS = (4, 8)


def _traces(kind: str, n: int, rate: float, burst: int, rng) -> np.ndarray:
    """Arrival offsets (seconds from replay start), sorted ascending."""
    if kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, n))
    # bursty: whole bursts land at once, burst gap keeps the MEAN rate equal
    # to the Poisson trace so the two replays differ only in variance
    n_bursts = (n + burst - 1) // burst
    starts = np.arange(n_bursts) * (burst / rate)
    return np.repeat(starts, burst)[:n]


def _warmup(engine: ServeEngine, slots: int) -> None:
    """Compile every (k, plen) prefill bucket + the decode step."""
    for plen in PLENS:
        for k in range(1, slots + 1):
            for _ in range(k):
                engine.submit(np.ones((plen,), np.int32), max_new_tokens=2)
            engine.run()


def _replay(engine: ServeEngine, arrivals: np.ndarray, prompts: list,
            budget: int) -> dict:
    n = len(arrivals)
    submitted: list[int] = []
    rejects = 0
    occ: list[float] = []
    i = 0
    t0 = time.perf_counter()
    while i < n or engine.active_lanes() or len(engine.router.queue):
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            try:
                submitted.append(engine.submit(prompts[i],
                                               max_new_tokens=budget))
                i += 1
            except Backpressure:
                rejects += 1  # shed; the client retries on the next tick
                break
        if engine.active_lanes() or len(engine.router.queue):
            engine.step()
            occ.append(engine.occupancy())
        elif i < n:
            time.sleep(max(0.0, min(arrivals[i] - (time.perf_counter() - t0),
                                    0.005)))
    wall = time.perf_counter() - t0
    done = [engine.router.done[rid] for rid in submitted]
    lat_ms = np.array([r.latency_s * 1e3 for r in done if r.status == "ok"])
    toks = sum(len(r.out) for r in done)
    return {
        "requests": n,
        "completed": int((np.array([r.status for r in done]) == "ok").sum()),
        "rejected_submits": rejects,
        "wall_s": round(wall, 3),
        "p50_ms": float(np.percentile(lat_ms, 50)) if lat_ms.size else None,
        "p99_ms": float(np.percentile(lat_ms, 99)) if lat_ms.size else None,
        "tokens": toks,
        "tokens_s": round(toks / wall, 1) if wall > 0 else None,
        "occupancy_pct": round(100.0 * float(np.mean(occ)), 1) if occ else 0.0,
        "steps": len(occ),
    }


def _suite(*, smoke: bool, arch: str, rate: float, seed: int) -> dict:
    cfg = LM_ARCHS[arch].smoke_config()
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    slots, budget, n = (4, 8, 24) if smoke else (8, 16, 96)
    burst = 2 * slots
    serve = ServeConfig(slots=slots, max_len=64, max_new_tokens=budget)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.choice(PLENS)))
               for _ in range(n)]

    engine = ServeEngine(params, cfg, serve,
                         queue_limit=4 * slots, seed=seed)
    _warmup(engine, slots)

    stats = {}
    for kind in ("poisson", "bursty"):
        arrivals = _traces(kind, n, rate, burst, rng)
        s = _replay(engine, arrivals, prompts, budget)
        stats[kind] = s
        detail = f"{arch} slots={slots} rate={rate}/s n={n}"
        row(f"serve/{kind}_p50_ms", f"{s['p50_ms']:.1f}", "ms", detail)
        row(f"serve/{kind}_p99_ms", f"{s['p99_ms']:.1f}", "ms", detail)
        row(f"serve/{kind}_tokens_s", s["tokens_s"], "tok/s", detail)
        row(f"serve/{kind}_occupancy_pct", s["occupancy_pct"], "%", detail)
        row(f"serve/{kind}_rejected", s["rejected_submits"], "count",
            f"queue_limit={4 * slots}")
    stats["paged"] = _paged_arm(params, cfg, arch=arch, slots=slots,
                                budget=budget, rate=rate, rng=rng,
                                contiguous_bytes=engine.planes[0].cache_bytes())
    stats["sampled"] = _sampled_arm(params, cfg, arch=arch, budget=budget,
                                    rng=rng)
    stats["config"] = {"arch": arch, "slots": slots, "max_len": 64,
                       "max_new_tokens": budget, "requests": n, "rate": rate,
                       "burst": burst, "queue_limit": 4 * slots,
                       "plens": list(PLENS)}
    return stats


def _paged_arm(params, cfg, *, arch: str, slots: int, budget: int,
               rate: float, rng, contiguous_bytes: int) -> dict:
    """The PR 9 paged-KV memory headlines — deterministic arithmetic at
    fixed config, measured on REAL planes (cache_bytes sums the actual
    device buffers; saturation counts actual live lanes after admission):

    - ``serve_cache_bytes``: resident KV bytes with a pool sized to the
      workload's LIVE tokens (prompt+budget per request x slots) instead of
      ``slots x max_len`` — the memory the paging refactor saves at the
      same load (lower = better; gated against the contiguous baseline in
      CI's bench leg).
    - ``serve_admitted_at_saturation``: how many requests decode
      CONCURRENTLY inside the contiguous layout's byte budget.  Contiguous
      admits exactly ``slots``; paged repacks the same bytes into
      ``pool_blocks // blocks_per_request`` lanes (higher = better).
    """
    bs = 8
    live = max(PLENS) + budget  # lifetime tokens of the longest request
    blocks_per_req = -(-live // bs)

    # live-token pool: the memory-win configuration at the SAME load
    sc_live = ServeConfig(slots=slots, max_len=64, max_new_tokens=budget,
                          block_size=bs, pool_blocks=slots * blocks_per_req)
    eng = ServeEngine(params, cfg, sc_live, queue_limit=4 * slots, seed=0)
    _warmup(eng, slots)
    paged_bytes = eng.planes[0].cache_bytes()
    arrivals = _traces("poisson", 2 * slots, rate, slots, rng)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.choice(PLENS)))
               for _ in range(2 * slots)]
    replay = _replay(eng, arrivals, prompts, budget)
    detail = f"{arch} bs={bs} pool={slots * blocks_per_req} blocks"
    row("serve/paged_cache_bytes", paged_bytes, "bytes", detail)
    row("serve/contiguous_cache_bytes", contiguous_bytes, "bytes",
        f"{arch} slots={slots} max_len=64")
    row("serve/paged_p50_ms", f"{replay['p50_ms']:.1f}", "ms", detail)

    # saturation: same bytes as contiguous => slots*ceil(max_len/bs) usable
    # blocks; admit far more requests than contiguous slots and count how
    # many actually hold a decode lane after admission settles
    sat_pool = slots * (64 // bs)
    sat_lanes = sat_pool // blocks_per_req
    sc_sat = ServeConfig(slots=sat_lanes, max_len=64, max_new_tokens=budget,
                         block_size=bs, pool_blocks=sat_pool)
    eng_sat = ServeEngine(params, cfg, sc_sat, queue_limit=4 * sat_lanes,
                          seed=0)
    for _ in range(2 * sat_lanes):
        eng_sat.submit(rng.integers(0, cfg.vocab, size=max(PLENS)),
                       max_new_tokens=budget)
    eng_sat.step()
    admitted = eng_sat.active_lanes()
    eng_sat.run()
    row("serve/admitted_at_saturation", admitted, "requests",
        f"paged bs={bs} pool={sat_pool} blocks vs {slots} contiguous slots")
    return {
        "block_size": bs, "blocks_per_request": blocks_per_req,
        "live_pool_blocks": slots * blocks_per_req,
        "cache_bytes": int(paged_bytes),
        "contiguous_cache_bytes": int(contiguous_bytes),
        "bytes_ratio": round(paged_bytes / contiguous_bytes, 3),
        "admitted_at_saturation": int(admitted),
        "contiguous_slots": slots,
        "replay": replay,
    }


def _sampled_arm(params, cfg, *, arch: str, budget: int, rng) -> dict:
    """The PR 10 sampled-decode arm: temperature > 0 with request-keyed
    draws.  Every run REPLAYS the same request set (same per-request seeds)
    through a 1-plane engine, a 2-plane engine and a paged engine and
    asserts the outputs are identical — the determinism contract
    (same seeds → same tokens, independent of plane count and cache layout)
    fails the bench, and therefore the CI job, the moment it breaks.
    ``serve_sampled_tokens_s`` (batch replay on the 1-plane engine, sampling
    inside the jit) is the trend-gated throughput headline.
    """
    slots, temp, n = 2, 0.8, 6
    serve = ServeConfig(slots=slots, max_len=64, max_new_tokens=budget)
    paged = ServeConfig(slots=slots, max_len=64, max_new_tokens=budget,
                        block_size=8)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.choice(PLENS)))
               for _ in range(n)]
    seeds = [1000 + i for i in range(n)]

    outs: dict[str, list] = {}
    tokens_s = None
    for name, planes, sc in (("planes1", 1, serve), ("planes2", 2, serve),
                             ("paged", 1, paged)):
        eng = ServeEngine(params, cfg, sc, planes=planes, queue_limit=4 * n)
        _warmup(eng, slots)  # greedy warmup covers sampled: one shared jit
        t0 = time.perf_counter()
        rids = [eng.submit(p, max_new_tokens=budget, temperature=temp,
                           seed=seeds[i]) for i, p in enumerate(prompts)]
        res = eng.run()
        wall = time.perf_counter() - t0
        outs[name] = [res[r] for r in rids]
        if name == "planes1":
            tokens_s = round(sum(len(o) for o in outs[name]) / wall, 1)
    if not (outs["planes1"] == outs["planes2"] == outs["paged"]):
        raise RuntimeError(
            "sampled-decode determinism violated: same per-request seeds "
            f"produced different tokens across engine shapes — "
            f"planes1={outs['planes1']} planes2={outs['planes2']} "
            f"paged={outs['paged']}")
    detail = f"{arch} slots={slots} temp={temp} n={n}"
    row("serve/sampled_tokens_s", tokens_s, "tok/s", detail)
    row("serve/sampled_deterministic", 1, "bool",
        "1-plane == 2-plane == paged for the same per-request seeds")
    return {
        "temperature": temp, "requests": n, "slots": slots,
        "per_request_seeds": seeds,
        "tokens_s": tokens_s,
        "deterministic_across_planes": True,
    }


def main(*, smoke: bool = False, out: str | None = None,
         arch: str = "qwen1.5-4b", rate: float = 30.0, seed: int = 0) -> None:
    t0 = time.perf_counter()
    with recording() as records:
        stats = _suite(smoke=smoke, arch=arch, rate=rate, seed=seed)
    wall = time.perf_counter() - t0
    if out is None:
        print(f"# serve-bench done in {wall:.1f}s (no --out)")
        return
    po, bu = stats["poisson"], stats["bursty"]
    payload = {
        "schema": 1,
        "kind": "bench-serve",
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "smoke": smoke,
        "wall_s": round(wall, 2),
        "headline": {
            # Poisson replay: the client-visible latency numbers.  The
            # throughput headline comes from the BURSTY replay — Poisson
            # tokens/s is arrival-rate-bound (a decode slowdown would hide
            # in idle time), the saturating burst is what a decode
            # regression actually moves.
            "serve_p50_ms": po["p50_ms"],
            "serve_p99_ms": po["p99_ms"],
            "serve_tokens_s": bu["tokens_s"],
            "serve_occupancy_pct": bu["occupancy_pct"],
            # paged KV (PR 9): resident cache bytes with a live-token pool
            # (must stay measurably below the contiguous baseline — CI's
            # bench leg asserts it) and concurrent requests inside the
            # contiguous byte budget
            "serve_cache_bytes": stats["paged"]["cache_bytes"],
            "serve_admitted_at_saturation":
                stats["paged"]["admitted_at_saturation"],
            # sampled decode (PR 10): request-keyed draws inside the jit;
            # the arm raises (failing the job) unless 1-plane == 2-plane ==
            # paged for the same per-request seeds
            "serve_sampled_tokens_s": stats["sampled"]["tokens_s"],
        },
        "traces": stats,
        "rows": records,
    }
    out_dir = os.path.dirname(out) or "."
    os.makedirs(out_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".bench-", dir=out_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, out)
    print(f"# serve-bench done in {wall:.1f}s -> {out}")
    print(json.dumps(payload["headline"], indent=1))


def _cli(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write a BENCH_serve.json record here")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller trace (the CI bench leg)")
    ap.add_argument("--arch", default="qwen1.5-4b", choices=sorted(LM_ARCHS))
    ap.add_argument("--rate", type=float, default=30.0,
                    help="mean arrival rate, requests/second")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    print("name,value,unit,detail")
    main(smoke=args.smoke, out=args.out, arch=args.arch, rate=args.rate,
         seed=args.seed)


if __name__ == "__main__":
    _cli()
