"""Paper Table 3 + Fig 5: index-batching vs standard batching — runtime,
memory, and accuracy parity, at reduced scale.

Accuracy parity is proven exactly (identical batches => identical training
trajectory when fed the same window ids); we demonstrate it by training both
paths for a few epochs and comparing losses bit-for-bit, then timing each
batching path separately.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch, materialize_windows)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig
from repro.train.loop import init_train_state, make_train_step

N, ENTRIES, B = 32, 600, 16


def main(smoke: bool = False) -> None:
    """``smoke=True``: tiny synthetic sizes + 1 parity epoch, for the CI
    bench-smoke leg (same code path, seconds of wall time)."""
    n, entries, b = (8, 150, 8) if smoke else (N, ENTRIES, B)
    epochs = 1 if smoke else 3
    spec = WindowSpec(horizon=6, input_len=6)
    raw = make_traffic_series(entries, n)
    ds = IndexDataset.from_raw(raw, spec)
    adj = gaussian_adjacency(random_sensor_coords(n))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=n, hidden=16, input_len=6, horizon=6)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)
    adam = AdamConfig(lr=5e-3)

    # ---- materialised (baseline) path
    xs, ys = materialize_windows(np.asarray(ds.series), ds.starts, 6, 6)
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    row("table3/mem_base", f"{(xs.nbytes + ys.nbytes) / 2**20:.2f}", "MiB", "")
    row("table3/mem_index", f"{ds.nbytes_index() / 2**20:.2f}", "MiB",
        f"reduction={100 * (1 - ds.nbytes_index() / (xs.nbytes + ys.nbytes)):.1f}%")

    def loss_base(p, ids):
        return pgt_dcrnn.loss_fn(p, cfg, sup, xs_d[ids], ys_d[ids]), {}

    series_dev = jnp.asarray(ds.series)

    def loss_index(p, ids):
        x, y = gather_batch(series_dev, jnp.asarray(ds.starts)[ids],
                            input_len=6, horizon=6)
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    sampler = GlobalShuffleSampler(ds.train_windows, b, ShardInfo(0, 1), seed=1)
    step_b = make_train_step(loss_base, adam, lambda s: 5e-3, donate=False)
    step_i = make_train_step(loss_index, adam, lambda s: 5e-3, donate=False)

    sb = init_train_state(params, adam)
    si = init_train_state(params, adam)
    losses_b, losses_i = [], []
    for epoch in range(epochs):
        for ids in sampler.epoch_global(epoch):
            ids = jnp.asarray(ids)
            sb, mb = step_b(sb, ids)
            si, mi = step_i(si, ids)
            losses_b.append(float(mb["loss"]))
            losses_i.append(float(mi["loss"]))
    max_dl = max(abs(a - b) for a, b in zip(losses_b, losses_i))
    row("table3/loss_final_base", f"{losses_b[-1]:.5f}", "mae", "")
    row("table3/loss_final_index", f"{losses_i[-1]:.5f}", "mae",
        f"max|Δloss| over {len(losses_b)} steps = {max_dl:.2e}")

    ids0 = jnp.asarray(sampler.epoch_global(0)[0])
    t_b = timed(lambda: step_b(init_train_state(params, adam), ids0))
    t_i = timed(lambda: step_i(init_train_state(params, adam), ids0))
    row("table3/step_base", f"{1e3 * t_b:.2f}", "ms", "")
    row("table3/step_index", f"{1e3 * t_i:.2f}", "ms",
        f"overhead={100 * (t_i / t_b - 1):+.1f}% (paper: <1%)")


if __name__ == "__main__":
    main()
