"""Perf-trend gate over successive bench artifacts.

``benchmarks/smoke.py`` (BENCH_smoke.json) and ``benchmarks/kernels_bench.py``
(BENCH_kernels.json) each record one perf point per push; this module closes
the ROADMAP loop by COMPARING two points: CI downloads the previous run's
artifact and gates the current one against it —

    python -m benchmarks.trend --prev prev/BENCH_smoke.json \
                               --cur results/BENCH_smoke.json

Per headline field the comparator computes a REGRESSION fraction in the
field's bad direction (throughput falling, latencies/memory rising):

- ratio fields (tokens/s, gather µs, peak RSS) compare relatively —
  ``0.30`` means 30% worse than the previous point;
- the table3 overhead is already a percentage, so it compares in absolute
  percentage POINTS (a +12-point jump = 0.12) — a relative ratio on a
  near-zero (or negative!) overhead baseline would be meaningless.

Verdicts: regression > ``--fail`` (default 25%) fails the job, >
``--warn`` (default 10%) prints a warning, improvements and small noise
pass.  Missing fields (schema drift) are reported but never fail — a NEW
headline metric must not brick CI until the artifact history catches up.

CPU CI wall times are noisy; the warn band is where noise lives, the fail
band is reserved for real regressions (a 25% slide in the gather/step hot
path is far outside runner jitter).
"""
from __future__ import annotations

import argparse
import json
import sys

#: headline field -> (better direction, comparison kind).  The table covers
#: BOTH artifact kinds (bench-smoke and bench-kernels); fields absent from a
#: record compare as "missing", which never fails, so one table gates both.
HEADLINE_FIELDS: dict[str, tuple[str, str]] = {
    "tokens_per_s": ("higher", "ratio"),
    "gather_dense_us": ("lower", "ratio"),
    "gather_pallas_interpret_us": ("lower", "ratio"),
    # Measured kernel autotuning (ISSUE 7): the dispatcher's pick must stay
    # competitive — gather_auto_us drifting up means either the tuner started
    # picking losers or the dispatch path grew overhead.
    "gather_auto_us": ("lower", "ratio"),
    "step_overhead_vs_base_pct": ("lower", "points"),
    # Async feed pipeline (ISSUE 6): the measured overlap win.  Losing it —
    # overlap points falling, pipelined step time rising — is a regression
    # the gate must catch, same bands as the other hot-path numbers.
    "step_overlap_pct": ("higher", "points"),
    "prefetch_step_us": ("lower", "ratio"),
    "peak_rss_bytes": ("lower", "ratio"),
    # bench-serve (BENCH_serve.json, ISSUE 8): the serving engine under
    # replayed load.  Direction-aware: latency falling is GOOD (a lower
    # p50/p99 passes), throughput falling is the regression.  Occupancy is
    # recorded but not gated (it is a utilization diagnostic, and moves
    # with runner speed in either direction).
    "serve_p50_ms": ("lower", "ratio"),
    "serve_p99_ms": ("lower", "ratio"),
    "serve_tokens_s": ("higher", "ratio"),
    # Paged KV (PR 9): resident cache bytes with a pool sized to live tokens
    # (growing = the paging win eroding), and how many requests fit
    # CONCURRENTLY inside the contiguous layout's byte budget (falling =
    # block accounting or pool sizing regressed).  Both are deterministic
    # arithmetic at fixed config, so any drift is a real change.
    "serve_cache_bytes": ("lower", "ratio"),
    "serve_admitted_at_saturation": ("higher", "ratio"),
    # Request-keyed sampling (PR 10): sampled-decode throughput — the keyed
    # draws run inside the jitted decode/prefill programs, so a slowdown
    # here means the sampler path grew a sync or lost program sharing.  The
    # determinism assertion itself lives in the bench (it raises).
    "serve_sampled_tokens_s": ("higher", "ratio"),
    # bench-kernels (BENCH_kernels.json) headline: what the auto dispatcher
    # actually runs per op, jitted steady state.
    "gather_slice_us": ("lower", "ratio"),
    "window_gather_auto_us": ("lower", "ratio"),
    "linear_scan_auto_us": ("lower", "ratio"),
    "flash_attention_auto_us": ("lower", "ratio"),
    "diffusion_conv_auto_us": ("lower", "ratio"),
}


def compare_headlines(prev: dict, cur: dict, *, warn: float = 0.10,
                      fail: float = 0.25) -> list[dict]:
    """Compare two ``headline`` dicts field by field.

    Returns one row per known field:
    ``{field, prev, cur, regression, verdict}`` with verdict in
    ``ok | warn | fail | missing`` — ``regression`` is the fraction worse
    (negative = improvement), None when incomparable.
    """
    rows = []
    for field, (direction, kind) in HEADLINE_FIELDS.items():
        p, c = prev.get(field), cur.get(field)
        if p is None and c is None:
            # the field belongs to the OTHER artifact kind (one table gates
            # both bench-smoke and bench-kernels records) — no row at all
            continue
        if p is None or c is None:
            rows.append({"field": field, "prev": p, "cur": c,
                         "regression": None, "verdict": "missing"})
            continue
        p, c = float(p), float(c)
        if kind == "points":
            # already percentages: compare absolute points on the 0-1 scale
            reg = (c - p) / 100.0 if direction == "lower" else (p - c) / 100.0
        elif p <= 0:
            # a non-positive ratio baseline can't anchor a relative change
            rows.append({"field": field, "prev": p, "cur": c,
                         "regression": None, "verdict": "missing"})
            continue
        elif direction == "lower":
            reg = c / p - 1.0
        else:
            reg = 1.0 - c / p
        verdict = "fail" if reg > fail else "warn" if reg > warn else "ok"
        rows.append({"field": field, "prev": p, "cur": c,
                     "regression": reg, "verdict": verdict})
    return rows


def _load_headline(path: str) -> dict:
    with open(path) as f:
        record = json.load(f)
    headline = record.get("headline")
    if not isinstance(headline, dict):
        raise SystemExit(f"{path}: no 'headline' object — not a bench "
                         f"record?")
    return headline


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prev", required=True,
                    help="previous BENCH_smoke.json (older artifact)")
    ap.add_argument("--cur", required=True,
                    help="current BENCH_smoke.json (this run)")
    ap.add_argument("--warn", type=float, default=0.10,
                    help="warn above this regression fraction")
    ap.add_argument("--fail", type=float, default=0.25,
                    help="fail above this regression fraction")
    args = ap.parse_args(argv)
    rows = compare_headlines(_load_headline(args.prev),
                             _load_headline(args.cur),
                             warn=args.warn, fail=args.fail)
    print(f"{'field':32} {'prev':>14} {'cur':>14} {'regression':>11} verdict")
    for r in rows:
        reg = "n/a" if r["regression"] is None else f"{r['regression']:+.1%}"
        print(f"{r['field']:32} {r['prev'] if r['prev'] is not None else '-':>14} "
              f"{r['cur'] if r['cur'] is not None else '-':>14} {reg:>11} "
              f"{r['verdict']}")
    warns = [r for r in rows if r["verdict"] == "warn"]
    fails = [r for r in rows if r["verdict"] == "fail"]
    for r in warns:
        print(f"::warning::bench-smoke {r['field']} regressed "
              f"{r['regression']:+.1%} vs the previous artifact")
    if fails:
        for r in fails:
            print(f"::error::bench-smoke {r['field']} regressed "
                  f"{r['regression']:+.1%} (> {args.fail:.0%}) vs the "
                  f"previous artifact")
        sys.exit(1)


if __name__ == "__main__":
    main()
