"""Paper Table 6 / §5.5: index-batching generalises to A3T-GCN (and ST-LLM).

Trains A3T-GCN with base vs index batching (identical window ids) and
reports runtime + memory + final MSE parity; runs one ST-LLM step with
index-batched windows to cover the Fig-10 model family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch, materialize_windows)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, sym_norm_adjacency)
from repro.models import a3tgcn, stllm
from repro.optim import AdamConfig
from repro.train.loop import init_train_state, make_train_step

N, ENTRIES, B = 24, 500, 8


def main() -> None:
    spec = WindowSpec(horizon=4, input_len=4)
    ds = IndexDataset.from_raw(make_traffic_series(ENTRIES, N, seed=5), spec)
    a_hat = jnp.asarray(sym_norm_adjacency(
        gaussian_adjacency(random_sensor_coords(N, seed=5))))
    cfg = a3tgcn.A3TGCNConfig(num_nodes=N, hidden=16, input_len=4, horizon=4)
    params = a3tgcn.init(jax.random.PRNGKey(0), cfg)
    adam = AdamConfig(lr=5e-3)
    series = jnp.asarray(ds.series)
    starts = jnp.asarray(ds.starts)

    xs, ys = materialize_windows(np.asarray(ds.series), ds.starts, 4, 4)
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    row("table6/mem_base", f"{(xs.nbytes + ys.nbytes) / 2**20:.2f}", "MiB", "")
    row("table6/mem_index", f"{ds.nbytes_index() / 2**20:.2f}", "MiB",
        f"reduction={100 * (1 - ds.nbytes_index() / (xs.nbytes + ys.nbytes)):.1f}%"
        " (paper: 49.2%)")

    def loss_base(p, ids):
        return a3tgcn.loss_fn(p, cfg, a_hat, xs_d[ids], ys_d[ids]), {}

    def loss_index(p, ids):
        x, y = gather_batch(series, starts[ids], input_len=4, horizon=4)
        return a3tgcn.loss_fn(p, cfg, a_hat, x, y), {}

    sampler = GlobalShuffleSampler(ds.train_windows, B, ShardInfo(0, 1), seed=2)
    results = {}
    for name, lf in (("base", loss_base), ("index", loss_index)):
        step = make_train_step(lf, adam, lambda s: 5e-3, donate=False)
        state = init_train_state(params, adam)
        for epoch in range(4):
            for ids in sampler.epoch_global(epoch):
                state, m = step(state, jnp.asarray(ids))
        tval, _ = lf(state["params"], jnp.asarray(ds.test_windows[:64]))
        results[name] = float(tval)
        t = timed(lambda: step(init_train_state(params, adam),
                               jnp.asarray(sampler.epoch_global(0)[0])))
        row(f"table6/{name}_step", f"{1e3 * t:.2f}", "ms", "")
        row(f"table6/{name}_test_mse", f"{float(tval):.5f}", "mse", "")
    row("table6/mse_delta", f"{abs(results['base'] - results['index']):.2e}",
        "mse", "identical batches -> identical trajectory")

    # ---- ST-LLM (Fig 10 family): one index-batched train step
    scfg = stllm.STLLMConfig(num_nodes=N, input_len=4, horizon=4, d_model=32,
                             layers=2, n_heads=4, d_ff=64)
    sparams = stllm.init(jax.random.PRNGKey(1), scfg)

    def loss_stllm(p, ids):
        x, y = gather_batch(series, starts[ids], input_len=4, horizon=4)
        return stllm.loss_fn(p, scfg, x, y), {}

    step = make_train_step(loss_stllm, adam, lambda s: 1e-3, donate=False)
    t = timed(lambda: step(init_train_state(sparams, adam),
                           jnp.asarray(sampler.epoch_global(0)[0])))
    row("fig10/stllm_index_step", f"{1e3 * t:.2f}", "ms",
        "ST-LLM over index-batched windows")


if __name__ == "__main__":
    main()
