"""CI bench-smoke harness — the perf trajectory's recorded points.

Runs the fig7 (distributed-index scaling) and table3 (index vs standard
batching) benchmarks in ``--smoke`` mode (tiny synthetic data, same code
paths) plus a window-gather microbench (dense jnp vs Pallas interpret), and
serialises everything to ``BENCH_smoke.json``:

- ``headline``: the few numbers a trend line wants — tokens/s through the
  fused gather/step, gather microseconds for the ``dense`` and
  ``pallas``-interpret lowerings, peak RSS of the whole run;
- ``rows``: every ``name,value,unit,detail`` record the suites printed, so
  nothing the CSV stream shows is lost from the artifact.

CPU wall times are NOT accelerator performance (Pallas runs interpret mode
on CPU) — the point of this harness is (a) the benchmarks EXECUTE, end to
end, on every push, and (b) successive artifacts give the hot paths a
recorded history, so a regression in the gather/step machinery shows up as
a trend break instead of going unnoticed (MSPipe's untracked-stage lesson).

Usage: PYTHONPATH=src python -m benchmarks.smoke [--out results/BENCH_smoke.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import fig7_scaling, table3_index_vs_base
from benchmarks.common import peak_rss_bytes, recording, row, timed
from repro.kernels import window_gather, window_gather_ref


def _gather_microbench() -> None:
    """Window gather at a reduced PeMS-like shape: the hot path of
    index-batching, timed for the dense lowering and checked+timed for the
    Pallas kernel in interpret mode."""
    rng = np.random.default_rng(0)
    series = jnp.asarray(rng.standard_normal((512, 64)).astype(np.float32))
    starts = jnp.asarray(rng.integers(0, 480, 16).astype(np.int32))
    t_dense = timed(lambda: window_gather_ref(series, starts, span=24))
    row("smoke/gather_dense_us", f"{1e6 * t_dense:.0f}", "us",
        "[512,64] b=16 span=24, jnp dense lowering")
    t_pallas = timed(
        lambda: window_gather(series, starts, span=24, use_pallas=True),
        iters=1)
    row("smoke/gather_pallas_interpret_us", f"{1e6 * t_pallas:.0f}", "us",
        "same shape, Pallas kernel in interpret mode (CPU; not TPU perf)")
    ok = np.array_equal(
        np.asarray(window_gather(series, starts, span=24, use_pallas=True)),
        np.asarray(window_gather_ref(series, starts, span=24)))
    row("smoke/gather_pallas_matches_dense", int(ok), "bool", "")
    if not ok:
        raise SystemExit("pallas gather diverged from the dense lowering")


def _pick(records: list[dict], name: str) -> float:
    vals = [float(r["value"]) for r in records if r["name"] == name]
    if not vals:
        raise SystemExit(f"bench-smoke produced no '{name}' record")
    return vals[0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_smoke.json")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    print("name,value,unit,detail")
    with recording() as records:
        fig7_scaling.main(smoke=True)
        table3_index_vs_base.main(smoke=True)
        _gather_microbench()
    wall = time.perf_counter() - t0

    tokens = max(float(r["value"]) for r in records
                 if r["name"].startswith("fig7/tokens_per_s_"))
    payload = {
        "schema": 1,
        "kind": "bench-smoke",
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "wall_s": round(wall, 2),
        "headline": {
            "tokens_per_s": tokens,
            "gather_dense_us": _pick(records, "smoke/gather_dense_us"),
            "gather_pallas_interpret_us": _pick(
                records, "smoke/gather_pallas_interpret_us"),
            "step_overhead_vs_base_pct": round(
                100 * (_pick(records, "table3/step_index")
                       / _pick(records, "table3/step_base") - 1), 1),
            "peak_rss_bytes": peak_rss_bytes(),
        },
        "rows": records,
    }
    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".bench-", dir=out_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, args.out)
    print(f"# bench-smoke done in {wall:.1f}s -> {args.out}")
    print(json.dumps(payload["headline"], indent=1))


if __name__ == "__main__":
    main()
