"""CI bench-smoke harness — the perf trajectory's recorded points.

Runs the fig7 (distributed-index scaling) and table3 (index vs standard
batching) benchmarks in ``--smoke`` mode (tiny synthetic data, same code
paths) plus a window-gather microbench (jitted dense jnp vs Pallas interpret
vs the measured ``auto`` dispatch), and serialises everything to
``BENCH_smoke.json``:

- ``headline``: the few numbers a trend line wants — tokens/s through the
  fused gather/step, gather microseconds for the ``dense``,
  ``pallas``-interpret and autotuned ``auto`` lowerings, the
  async-feed-pipeline overlap
  (``step_overlap_pct`` / ``prefetch_step_us``, with the staleness-0
  bit-identity asserted on every run), peak RSS of the whole run;
- ``rows``: every ``name,value,unit,detail`` record the suites printed, so
  nothing the CSV stream shows is lost from the artifact.

CPU wall times are NOT accelerator performance (Pallas runs interpret mode
on CPU) — the point of this harness is (a) the benchmarks EXECUTE, end to
end, on every push, and (b) successive artifacts give the hot paths a
recorded history, so a regression in the gather/step machinery shows up as
a trend break instead of going unnoticed (MSPipe's untracked-stage lesson).

Usage: PYTHONPATH=src python -m benchmarks.smoke [--out results/BENCH_smoke.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import fig7_scaling, table3_index_vs_base
from benchmarks.common import peak_rss_bytes, recording, row, timed
from repro.kernels import window_gather, window_gather_ref


def _gather_microbench() -> None:
    """Window gather at a reduced PeMS-like shape: the hot path of
    index-batching.  All three arms are JITTED before timing — eager wall
    time is dominated by per-op Python dispatch and says nothing about the
    lowering (the pallas arm used to be timed eagerly, which buried the
    comparison under interpreter overhead):

    - ``dense``  — jit of the pure-jnp reference;
    - ``pallas`` — jit of the scalar-prefetch kernel (interpret mode on
      CPU; not TPU perf);
    - ``auto``   — jit of the measured dispatcher (kernels/autotune):
      dispatch fires at TRACE time exactly like the fused train step, so
      the steady state runs the tuned winner with zero dispatch overhead.
    """
    import functools
    import statistics

    from repro.kernels import verdict_for

    rng = np.random.default_rng(0)
    series = jnp.asarray(rng.standard_normal((512, 64)).astype(np.float32))
    starts = jnp.asarray(rng.integers(0, 480, 16).astype(np.int32))
    dense = jax.jit(window_gather_ref, static_argnames=("span",))
    pallas = jax.jit(functools.partial(window_gather, use_pallas=True),
                     static_argnames=("span",))
    auto = jax.jit(functools.partial(window_gather, impl="auto"),
                   static_argnames=("span",))
    # dense and auto often lower to the SAME graph (the tuner picks ref);
    # at the ~10µs scale an A-then-B comparison is pure scheduler jitter,
    # so the two arms are interleaved and compared by round medians.
    rounds, dense_ts, auto_ts = 5, [], []
    for _ in range(rounds):
        dense_ts.append(timed(lambda: dense(series, starts, span=24),
                              iters=5))
        auto_ts.append(timed(lambda: auto(series, starts, span=24), iters=5))
    t_dense = statistics.median(dense_ts)
    t_auto = statistics.median(auto_ts)
    row("smoke/gather_dense_us", f"{1e6 * t_dense:.1f}", "us",
        "[512,64] b=16 span=24, jit of the jnp dense lowering, median of "
        f"{rounds} interleaved rounds")
    t_pallas = timed(lambda: pallas(series, starts, span=24))
    row("smoke/gather_pallas_interpret_us", f"{1e6 * t_pallas:.1f}", "us",
        "same shape, jit of the Pallas kernel in interpret mode (CPU; "
        "not TPU perf)")
    v = verdict_for("window_gather", np.asarray(series), np.asarray(starts),
                    span=24)
    row("smoke/gather_auto_us", f"{1e6 * t_auto:.1f}", "us",
        f"same shape, autotuned dispatch -> {v.variant} ({v.source}), "
        f"median of {rounds} interleaved rounds")
    ok_pallas = np.array_equal(np.asarray(pallas(series, starts, span=24)),
                               np.asarray(dense(series, starts, span=24)))
    ok_auto = np.array_equal(np.asarray(auto(series, starts, span=24)),
                             np.asarray(dense(series, starts, span=24)))
    row("smoke/gather_pallas_matches_dense", int(ok_pallas), "bool", "")
    row("smoke/gather_auto_matches_dense", int(ok_auto), "bool",
        f"variant={v.variant}")
    if not ok_pallas:
        raise SystemExit("pallas gather diverged from the dense lowering")
    if not ok_auto:
        raise SystemExit("autotuned gather diverged from the dense lowering")


def _prefetch_bench(staleness: int) -> None:
    """Measured overlap of the async feed pipeline (ISSUE 6) — three arms of
    the same smoke-scale pgt_dcrnn fit:

    1. synchronous (prefetch_depth=0): the baseline step time AND the
       reference loss trajectory;
    2. pipelined at staleness 0: must be BIT-IDENTICAL to (1) — the
       refactor's correctness evidence, asserted here on every bench run;
    3. pipelined at ``staleness``: the timed arm — host feed assembly and
       the host→device transfer move off the step thread, so the step-time
       delta vs (1) is the measured overlap (not asserted into existence).

    The shape is deliberately host-bound (tiny model, modest batch): the
    caller-thread feed path — host row assembly + the Python-side
    ``device_put`` — is the overhead the pipeline hides, and this is where
    it is visible.  Arms are INTERLEAVED (sync/stale alternating rounds)
    and compared by median so machine noise hits both the same way; a
    single-shot A-then-B comparison on a shared CI core is pure jitter.
    """
    import statistics

    from repro.core import Placement, WindowSpec
    from repro.data import (gaussian_adjacency, make_traffic_series,
                            random_sensor_coords, transition_matrices)
    from repro.launch.mesh import make_host_mesh
    from repro.models import pgt_dcrnn
    from repro.pipeline import PipelineConfig, build_pipeline
    from repro.train import TrainLoopConfig

    n, entries = 8, 900
    spec = WindowSpec(horizon=2, input_len=2)
    series = make_traffic_series(entries, n)
    adj = gaussian_adjacency(random_sensor_coords(n))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=n, hidden=8, input_len=2,
                                   horizon=2)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, x, y):
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    mesh = make_host_mesh()

    def run(depth: int, stale: int, *, log_every: int):
        """(loss rows, steady-state step µs): a fresh 2-epoch fit; epoch 0
        absorbs the jit compile, epoch 1 is the timed steady state."""
        loop = TrainLoopConfig(epochs=2, log_every=log_every, eval_every=0,
                               prefetch_depth=depth, staleness=stale)
        pipe = build_pipeline(
            series, spec, mesh, loss_fn, params,
            PipelineConfig(batch_per_rank=16, placement=Placement.REPLICATED,
                           world=1, seed=0, loop=loop))
        _, hist = pipe.fit(eval_fn=None)
        losses = [h["loss"] for h in hist if "epoch_time_s" not in h]
        steady = [h["epoch_time_s"] for h in hist
                  if "epoch_time_s" in h and h["epoch"] == 1][0]
        return losses, 1e6 * steady / pipe.steps_per_epoch

    # Correctness arms: full per-step loss trajectories, compared exactly.
    sync_losses, _ = run(0, 0, log_every=1)
    id_losses, _ = run(2, 0, log_every=1)
    bit_identical = sync_losses == id_losses
    stale_losses = (run(2, staleness, log_every=1)[0] if staleness >= 1
                    else id_losses)
    # Timing arms: per-step logging off (each logged row is a host sync
    # that would mask the overlap), interleaved rounds, medians.
    rounds, sync_ts, stale_ts = 3, [], []
    for _ in range(rounds):
        sync_ts.append(run(0, 0, log_every=0)[1])
        stale_ts.append(run(2, staleness, log_every=0)[1])
    sync_us = statistics.median(sync_ts)
    stale_us = statistics.median(stale_ts)
    overlap_pct = 100.0 * (1.0 - stale_us / sync_us)
    steps = len(sync_losses)
    row("prefetch/sync_step_us", f"{sync_us:.1f}", "us",
        f"synchronous pull-per-step baseline, median of {rounds} "
        f"interleaved rounds")
    row("prefetch/prefetch_step_us", f"{stale_us:.1f}", "us",
        f"pipelined, depth=2 staleness={staleness}")
    row("prefetch/step_overlap_pct", f"{overlap_pct:.1f}", "%",
        "100*(1 - pipelined/sync) median steady-state step time")
    row("prefetch/bit_identical_at_0", int(bit_identical), "bool",
        f"staleness-0 loss trajectory ({steps} steps) vs synchronous")
    row("prefetch/final_loss_sync", f"{sync_losses[-1]:.10g}", "loss", "")
    row("prefetch/final_loss_stale", f"{stale_losses[-1]:.10g}", "loss",
        f"staleness={staleness}")
    if not bit_identical:
        raise SystemExit("staleness-0 pipelined losses diverged from the "
                         "synchronous path — the prefetch identity is broken")


def _pick(records: list[dict], name: str) -> float:
    vals = [float(r["value"]) for r in records if r["name"] == name]
    if not vals:
        raise SystemExit(f"bench-smoke produced no '{name}' record")
    return vals[0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_smoke.json")
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness of the TIMED prefetch arm (the "
                         "staleness-0 bit-identity arm always runs)")
    ap.add_argument("--autotune", choices=("off", "load", "tune"),
                    default="load",
                    help="kernel autotune policy for the 'auto' arms: off = "
                         "static defaults, load = use the committed "
                         "TUNING_<backend>.json, tune = measure and persist "
                         "fresh verdicts")
    ap.add_argument("--tuning-dir", default="results",
                    help="directory holding TUNING_<backend>.json")
    args = ap.parse_args(argv)

    from repro.kernels import set_autotune
    set_autotune(mode=args.autotune, cache_dir=args.tuning_dir)

    t0 = time.perf_counter()
    print("name,value,unit,detail")
    with recording() as records:
        fig7_scaling.main(smoke=True)
        table3_index_vs_base.main(smoke=True)
        _gather_microbench()
        _prefetch_bench(args.staleness)
    wall = time.perf_counter() - t0

    tokens = max(float(r["value"]) for r in records
                 if r["name"].startswith("fig7/tokens_per_s_"))
    payload = {
        "schema": 1,
        "kind": "bench-smoke",
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "autotune": args.autotune,
        "wall_s": round(wall, 2),
        "headline": {
            "tokens_per_s": tokens,
            "gather_dense_us": _pick(records, "smoke/gather_dense_us"),
            "gather_pallas_interpret_us": _pick(
                records, "smoke/gather_pallas_interpret_us"),
            "gather_auto_us": _pick(records, "smoke/gather_auto_us"),
            "step_overhead_vs_base_pct": round(
                100 * (_pick(records, "table3/step_index")
                       / _pick(records, "table3/step_base") - 1), 1),
            "step_overlap_pct": _pick(records, "prefetch/step_overlap_pct"),
            "prefetch_step_us": _pick(records, "prefetch/prefetch_step_us"),
            "peak_rss_bytes": peak_rss_bytes(),
        },
        "prefetch": {
            "staleness": args.staleness,
            "bit_identical_at_0": bool(
                _pick(records, "prefetch/bit_identical_at_0")),
            "sync_step_us": _pick(records, "prefetch/sync_step_us"),
            "prefetch_step_us": _pick(records, "prefetch/prefetch_step_us"),
            "step_overlap_pct": _pick(records, "prefetch/step_overlap_pct"),
            "final_loss_sync": _pick(records, "prefetch/final_loss_sync"),
            "final_loss_stale": _pick(records, "prefetch/final_loss_stale"),
        },
        "rows": records,
    }
    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".bench-", dir=out_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, args.out)
    print(f"# bench-smoke done in {wall:.1f}s -> {args.out}")
    print(json.dumps(payload["headline"], indent=1))


if __name__ == "__main__":
    main()
