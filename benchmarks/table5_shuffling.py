"""Paper Table 5: global shuffling vs local batch shuffling — validation MAE.

Trains the same model under both placements' samplers at several simulated
worker counts and reports the optimal validation MAE of each (paper finds
parity).  Both arms run through `repro.pipeline`: REPLICATED selects the
global shuffle, PARTITIONED the fixed-partition local batch shuffle; the
lock-step SPMD simulation is the pipeline's own epoch_global assembly
(every rank's batch concatenated into one jitted step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import Placement, WindowSpec
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.launch.mesh import make_host_mesh
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig
from repro.pipeline import PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig

N, ENTRIES, B = 24, 500, 8
EPOCHS = 6

ARMS = (("global", Placement.REPLICATED),
        ("local-batch", Placement.PARTITIONED))


def main() -> None:
    spec = WindowSpec(horizon=4, input_len=4)
    series = make_traffic_series(ENTRIES, N, seed=3)
    adj = gaussian_adjacency(random_sensor_coords(N, seed=3))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=16, input_len=4, horizon=4)
    params0 = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh()

    def loss_fn(p, x, y):
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    for world in (2, 4):
        for name, placement in ARMS:
            # partition="count": the paper's Table-5 local-batch arm uses
            # EQUAL per-rank partitions (same training budget as the global
            # arm) — the comparison is about shuffling granularity, not the
            # uneven time-shard ownership of the aligned partitioner.
            pipe = build_pipeline(
                series, spec, mesh, loss_fn, params0,
                PipelineConfig(batch_per_rank=B, placement=placement,
                               world=world, seed=7, partition="count",
                               adam=AdamConfig(lr=5e-3),
                               loop=TrainLoopConfig(epochs=EPOCHS, log_every=0)))
            _, history = pipe.fit()
            best = min(h["val_mae"] for h in history if "val_mae" in h)
            row(f"table5/{name}_w{world}", f"{best:.4f}", "val-mae", "")


if __name__ == "__main__":
    main()
