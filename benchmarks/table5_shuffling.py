"""Paper Table 5: global shuffling vs local batch shuffling — validation MAE.

Trains the same model under both samplers at several simulated worker counts
and reports the optimal validation MAE of each (paper finds parity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import (GlobalShuffleSampler, IndexDataset,
                        LocalBatchShuffleSampler, ShardInfo, WindowSpec,
                        gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig
from repro.train.loop import init_train_state, make_train_step

N, ENTRIES, B = 24, 500, 8
EPOCHS = 6


def main() -> None:
    spec = WindowSpec(horizon=4, input_len=4)
    ds = IndexDataset.from_raw(make_traffic_series(ENTRIES, N, seed=3), spec)
    adj = gaussian_adjacency(random_sensor_coords(N, seed=3))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=16, input_len=4, horizon=4)
    params0 = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)
    adam = AdamConfig(lr=5e-3)
    series = jnp.asarray(ds.series)
    starts_all = jnp.asarray(ds.starts)

    def loss_fn(p, ids):
        x, y = gather_batch(series, starts_all[ids], input_len=4, horizon=4)
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    val_ids = jnp.asarray(ds.val_windows[:64])

    def val_mae(state):
        l, _ = loss_fn(state["params"], val_ids)
        return float(l)

    for world in (2, 4):
        for name, cls in (("global", GlobalShuffleSampler),
                          ("local-batch", LocalBatchShuffleSampler)):
            step = make_train_step(loss_fn, adam, lambda s: 5e-3, donate=False)
            state = init_train_state(params0, adam)
            best = np.inf
            for epoch in range(EPOCHS):
                # lock-step simulation: run every rank's batch each step
                rank_grids = [cls(ds.train_windows, B, ShardInfo(r, world),
                                  seed=7).epoch(epoch) for r in range(world)]
                for s_i in range(rank_grids[0].shape[0]):
                    ids = jnp.asarray(np.concatenate(
                        [g[s_i] for g in rank_grids]))
                    state, _ = step(state, ids)
                best = min(best, val_mae(state))
            row(f"table5/{name}_w{world}", f"{best:.4f}", "val-mae", "")


if __name__ == "__main__":
    main()
