"""Paper Table 1: dataset sizes before/after preprocessing (analytic, exact).

Validates the eq.-1 memory model against every Table-1 dataset and reports
index-batching's eq.-2 footprint + reduction next to it.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.core import windows as W
from repro.data.registry import TABLE1


def main() -> None:
    for name, d in TABLE1.items():
        spec = W.WindowSpec(horizon=d.horizon)
        post = W.materialized_bytes(d.entries, d.nodes, d.features, spec,
                                    dtype_bytes=8, counting="table")
        idx = W.index_batching_bytes(d.entries, d.nodes, d.features, spec,
                                     dtype_bytes=8, counting="table")
        red = 1.0 - idx / post if post else 0.0
        row(f"table1/{name}/post_gib", f"{post / 2**30:.2f}", "GiB",
            f"paper={d.table1_post_bytes / 2**30:.2f}")
        if d.table1_post_bytes:
            err = abs(post - d.table1_post_bytes) / d.table1_post_bytes
            row(f"table1/{name}/vs_paper", f"{100 * err:.2f}", "%err", "")
        row(f"table1/{name}/index_gib", f"{idx / 2**30:.3f}", "GiB",
            f"reduction={100 * red:.1f}%")


if __name__ == "__main__":
    main()
