"""Paper Table 2 / §3 case study: DCRNN vs PGT-DCRNN runtime + memory.

Reduced scale (PeMS-All-LA shape scaled down by --scale); measures one epoch
of each implementation with the SAME standard (materialising) preprocessing,
reproducing the paper's ~15x runtime gap structurally (full enc-dec DCRNN vs
single-layer stepwise PGT variant) and its memory ordering.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import WindowSpec, materialize_windows
from repro.core.windows import window_starts
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import dcrnn, pgt_dcrnn


def main(nodes: int = 64, entries: int = 400, batch: int = 16) -> None:
    spec = WindowSpec(horizon=6, input_len=6)
    series = make_traffic_series(entries, nodes)
    adj = gaussian_adjacency(random_sensor_coords(nodes))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    starts = window_starts(entries, spec)

    # standard (Alg.-1) preprocessing for both models — the case-study setup
    xs, ys = materialize_windows(series, starts, 6, 6)
    mat_bytes = xs.nbytes + ys.nbytes
    row("table2/materialized", f"{mat_bytes / 2**20:.1f}", "MiB",
        f"series={series.nbytes / 2**20:.1f}MiB")

    x = jnp.asarray(xs[:batch])
    y = jnp.asarray(ys[:batch])

    dc = dcrnn.DCRNNConfig(num_nodes=nodes, hidden=32, layers=2, input_len=6,
                           horizon=6)
    dp = dcrnn.init(jax.random.PRNGKey(0), dc)
    t_dcrnn = timed(lambda: jax.grad(
        lambda p: dcrnn.loss_fn(p, dc, sup, x, y))(dp))

    pc = pgt_dcrnn.PGTDCRNNConfig(num_nodes=nodes, hidden=32, input_len=6,
                                  horizon=6)
    pp = pgt_dcrnn.init(jax.random.PRNGKey(0), pc)
    t_pgt = timed(lambda: jax.grad(
        lambda p: pgt_dcrnn.loss_fn(p, pc, sup, x, y))(pp))

    row("table2/dcrnn_step", f"{1e3 * t_dcrnn:.1f}", "ms", "full enc-dec")
    row("table2/pgt_dcrnn_step", f"{1e3 * t_pgt:.1f}", "ms", "stepwise 1-layer")
    row("table2/speedup", f"{t_dcrnn / t_pgt:.2f}", "x",
        "paper reports 15.3x at full scale")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    args = ap.parse_args()
    main(nodes=args.nodes)
