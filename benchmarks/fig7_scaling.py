"""Paper Fig 7 + §5.3: distributed-index-batching vs baseline DDP scaling.

Two views:
1. HOST-SIMULATED strong scaling: fixed dataset, growing worker count; each
   "worker"'s step runs sequentially on this CPU (lock-step SPMD semantics),
   so reported speedup = T(1)/T(w) with perfect overlap — an upper bound that
   isolates ALGORITHMIC communication cost (which we account analytically
   from batch bytes moved).
2. DRY-RUN collective bytes at production scale, read from
   results/dryrun_full.json when present: replicated vs partitioned vs
   ondemand — the Fig-7/Fig-9 contrast measured from compiled HLO.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn

N, ENTRIES, B_PER = 32, 600, 8


def main() -> None:
    spec = WindowSpec(horizon=6, input_len=6)
    ds = IndexDataset.from_raw(make_traffic_series(ENTRIES, N), spec)
    adj = gaussian_adjacency(random_sensor_coords(N))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=16, input_len=6, horizon=6)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)
    series = jnp.asarray(ds.series)
    grad = jax.jit(jax.grad(lambda p, x, y: pgt_dcrnn.loss_fn(p, cfg, sup, x, y)))

    def worker_step(starts):
        x, y = gather_batch(series, starts, input_len=6, horizon=6)
        return grad(params, x, y)

    window_bytes = 12 * N * 2 * 4  # one (x,y) span in f32

    for w in (1, 2, 4, 8):
        sampler = GlobalShuffleSampler(ds.train_windows, B_PER, ShardInfo(0, w),
                                       seed=0)
        starts0 = jnp.asarray(ds.starts[sampler.epoch(0)[0]])
        t = timed(lambda: worker_step(starts0))
        # distributed-index: zero data bytes; DDP ships every window to its worker
        ddp_bytes = B_PER * w * window_bytes
        row(f"fig7/steps_per_epoch_w{w}", sampler.steps_per_epoch, "steps", "")
        row(f"fig7/index_step_w{w}", f"{1e3 * t:.2f}", "ms",
            "per-worker compute; data comms = 0 B")
        row(f"fig7/ddp_data_bytes_w{w}", ddp_bytes, "B",
            "on-demand batch shipping per step")

    # production-scale collective contrast from the dry-run
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_full.json")
    if os.path.exists(path):
        with open(path) as f:
            recs = json.load(f)
        for r in recs:
            if r.get("arch") == "dcrnn-pems" and r.get("status") == "ok" \
                    and not r.get("multi_pod"):
                pl = r["meta"].get("placement", "replicated")
                row(f"fig7/dryrun_coll_{pl}",
                    f"{r['collectives']['total'] / 2**20:.1f}", "MiB/step",
                    f"peak={r['memory']['peak_bytes'] / 2**30:.2f}GiB")


if __name__ == "__main__":
    main()
