"""Paper Fig 7 + §5.3: distributed-index-batching vs baseline DDP scaling.

Two views:
1. HOST-SIMULATED strong scaling: fixed dataset, growing worker count; each
   "worker"'s step runs sequentially on this CPU (lock-step SPMD semantics),
   so reported speedup = T(1)/T(w) with perfect overlap — an upper bound that
   isolates ALGORITHMIC communication cost (which we account analytically
   from batch bytes moved).  The per-worker step is the `repro.pipeline`
   fused gather+grad+Adam program under the REPLICATED placement.
2. DRY-RUN collective bytes at production scale, read from
   results/dryrun_full.json when present: replicated vs partitioned vs
   ondemand — the Fig-7/Fig-9 contrast measured from compiled HLO.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import Placement, WindowSpec
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.launch.mesh import make_host_mesh
from repro.models import pgt_dcrnn
from repro.pipeline import PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig
from repro.train.loop import init_train_state

N, ENTRIES, B_PER = 32, 600, 8


def main(smoke: bool = False) -> None:
    """``smoke=True``: tiny synthetic sizes + fewer worker points, for the CI
    bench-smoke leg (seconds, not minutes; same code path)."""
    n, entries, b_per = (8, 150, 4) if smoke else (N, ENTRIES, B_PER)
    worlds = (1, 2) if smoke else (1, 2, 4, 8)
    spec = WindowSpec(horizon=6, input_len=6)
    series = make_traffic_series(entries, n)
    adj = gaussian_adjacency(random_sensor_coords(n))
    sup = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=n, hidden=16, input_len=6, horizon=6)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, x, y):
        return pgt_dcrnn.loss_fn(p, cfg, sup, x, y), {}

    span = spec.in_len + spec.horizon
    window_bytes = span * n * 2 * 4  # one (x,y) span in f32
    mesh = make_host_mesh()

    for w in worlds:
        pipe = build_pipeline(
            series, spec, mesh, loss_fn, params,
            PipelineConfig(batch_per_rank=b_per, placement=Placement.REPLICATED,
                           world=w, seed=0,
                           loop=TrainLoopConfig(donate=False)))
        # one worker's slice of the first global batch (lock-step semantics)
        rank0 = pipe.sampler.epoch(0)[0]
        starts0 = pipe.batch_of_starts(rank0)
        state = init_train_state(jax.tree.map(jnp.copy, params),
                                 pipe.config.adam)
        t = timed(lambda: pipe.train_step(state, starts0)[1]["loss"],
                  iters=1 if smoke else 3)
        # distributed-index: zero data bytes; DDP ships every window to its worker
        ddp_bytes = b_per * w * window_bytes
        glob = b_per * w
        row(f"fig7/steps_per_epoch_w{w}", pipe.steps_per_epoch, "steps", "")
        row(f"fig7/index_step_w{w}", f"{1e3 * t:.2f}", "ms",
            "per-worker fused step; data comms = 0 B")
        # throughput with perfect lock-step overlap of the w workers — the
        # same upper-bound semantics as the speedup view above; "tokens" are
        # window ELEMENTS (batch x span x nodes x features) through the step
        row(f"fig7/windows_per_s_w{w}", f"{glob / t:.1f}", "windows/s",
            "global batch / per-worker step, simulated w-worker overlap")
        row(f"fig7/tokens_per_s_w{w}", f"{glob * span * n * 2 / t:.0f}",
            "tok/s", "window elements through the fused gather/step")
        row(f"fig7/ddp_data_bytes_w{w}", ddp_bytes, "B",
            "on-demand batch shipping per step")

    # production-scale collective contrast from the dry-run
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_full.json")
    if os.path.exists(path):
        with open(path) as f:
            recs = json.load(f)
        for r in recs:
            if r.get("arch") == "dcrnn-pems" and r.get("status") == "ok" \
                    and not r.get("multi_pod"):
                pl = r["meta"].get("placement", "replicated")
                row(f"fig7/dryrun_coll_{pl}",
                    f"{r['collectives']['total'] / 2**20:.1f}", "MiB/step",
                    f"peak={r['memory']['peak_bytes'] / 2**30:.2f}GiB")


if __name__ == "__main__":
    main()
