"""Docs checker: keep README/docs commands and links from rotting.

Two failure modes this tool turns into CI failures (PR 9 satellite):

1. **Dead intra-repo links/paths.**  Every markdown link whose target is not
   an external URL or a pure anchor must resolve to a real file/directory,
   relative to the linking file (falling back to the repo root).  Renaming
   ``docs/ARCHITECTURE.md`` or a module without updating its references
   breaks this check, not a future reader.

2. **Rotten command/code blocks.**  Fenced ``python`` blocks must at least
   COMPILE (a snippet referencing syntax that never existed is worse than no
   snippet).  Fenced ``bash`` blocks are syntax-checked with ``bash -n``;
   blocks annotated with an HTML comment **directly above the fence**::

       <!-- docs-check: run -->
       ```bash
       PYTHONPATH=src python -m repro.launch.serve --requests 2 ...
       ```

   are additionally EXECUTED under ``--run`` (the CI docs-check job) with
   the repo root as cwd — so the exact commands the README advertises are
   the commands that work.  ``<!-- docs-check: skip -->`` exempts a block
   from all checking (deliberately schematic pseudo-code).

Usage:
    python tools/docs_check.py              # links + compile/syntax checks
    python tools/docs_check.py --run        # also execute annotated blocks
    python tools/docs_check.py README.md docs/FOO.md   # explicit file set
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — target captured up to the closing paren (no nesting in
#: our docs); images (![...]) match too, which is what we want
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^(```+)\s*(\w*)\s*$")
_ANNOT = re.compile(r"<!--\s*docs-check:\s*(run|skip)\s*-->")


def _default_files() -> list[str]:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for root, _dirs, names in os.walk(docs):
            files += [os.path.join(root, n) for n in sorted(names)
                      if n.endswith(".md")]
    return [f for f in files if os.path.isfile(f)]


def _is_external(target: str) -> bool:
    return target.startswith(("http://", "https://", "mailto:", "#"))


def check_links(path: str, text: str) -> list[str]:
    """Every intra-repo link target must exist (anchors stripped)."""
    errors = []
    base = os.path.dirname(path)
    for m in _LINK.finditer(text):
        target = m.group(1).split("#")[0]
        if not target or _is_external(m.group(1)):
            continue
        cand = [os.path.normpath(os.path.join(base, target)),
                os.path.normpath(os.path.join(REPO, target))]
        if not any(os.path.exists(c) for c in cand):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"-> {target}")
    return errors


def extract_blocks(text: str) -> list[dict]:
    """Fenced code blocks with language + the docs-check annotation (an HTML
    comment on the non-empty line directly above the fence)."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not (m and m.group(2)):  # opening fence with a language tag
            i += 1
            continue
        fence, lang = m.group(1), m.group(2).lower()
        annot = None
        for j in range(i - 1, -1, -1):
            if not lines[j].strip():
                continue
            am = _ANNOT.search(lines[j])
            annot = am.group(1) if am else None
            break
        body, i = [], i + 1
        while i < len(lines) and not lines[i].startswith(fence):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        blocks.append({"lang": lang, "code": "\n".join(body),
                       "annot": annot, "line": i - len(body)})
    return blocks


def check_blocks(path: str, text: str, *, run: bool) -> list[str]:
    errors = []
    rel = os.path.relpath(path, REPO)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    for b in extract_blocks(text):
        where = f"{rel}:{b['line']}"
        if b["annot"] == "skip":
            continue
        if b["lang"] in ("python", "py"):
            try:
                compile(b["code"], where, "exec")
            except SyntaxError as e:
                errors.append(f"{where}: python block does not compile: {e}")
        elif b["lang"] in ("bash", "sh", "shell"):
            p = subprocess.run(["bash", "-n"], input=b["code"],
                               capture_output=True, text=True)
            if p.returncode != 0:
                errors.append(f"{where}: bash block does not parse: "
                              f"{p.stderr.strip()}")
            elif run and b["annot"] == "run":
                p = subprocess.run(["bash", "-e"], input=b["code"],
                                   capture_output=True, text=True,
                                   cwd=REPO, env=env, timeout=900)
                if p.returncode != 0:
                    tail = (p.stderr or p.stdout).strip().splitlines()[-8:]
                    errors.append(f"{where}: annotated bash block FAILED "
                                  f"(exit {p.returncode}):\n    "
                                  + "\n    ".join(tail))
                else:
                    print(f"  ran {where}: ok")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: README.md + docs/**.md)")
    ap.add_argument("--run", action="store_true",
                    help="execute bash blocks annotated "
                         "'<!-- docs-check: run -->' (the CI docs-check job)")
    args = ap.parse_args(argv)
    files = ([os.path.abspath(f) for f in args.files] if args.files
             else _default_files())
    errors: list[str] = []
    for path in files:
        with open(path) as f:
            text = f.read()
        errors += check_links(path, text)
        errors += check_blocks(path, text, run=args.run)
        print(f"checked {os.path.relpath(path, REPO)}")
    if errors:
        print(f"\n{len(errors)} docs problem(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"docs OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
