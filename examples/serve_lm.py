"""Serve a small LM with batched requests (continuous batching).

Any of the 10 assigned architectures is selectable (reduced config on CPU):

  PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import LM_ARCHS
from repro.models.lm import model as lm
from repro.train import ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b", choices=sorted(LM_ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = LM_ARCHS[args.arch].smoke_config()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    srv = Server(params, cfg, ServeConfig(slots=args.slots, max_len=96,
                                          max_new_tokens=args.max_new_tokens))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        srv.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(3, 20))))
    out = srv.run()
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"[{args.arch}] {len(out)} requests, {toks} tokens, "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s, {args.slots} slots)")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
