"""The paper's scaling study (Fig 7/8), lock-step simulated on one host.

Simulates W workers: each step runs every worker's batch sequentially
(SPMD lock-step semantics), gradients are averaged (the AllReduce), and the
global batch grows with W — reproducing the accuracy-vs-workers trend of
Fig 8 and the runtime decomposition of Fig 7 at reduced scale.

  PYTHONPATH=src python examples/scaling_study.py --workers 1,2,4,8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig, linear_scaled_lr
from repro.train.loop import init_train_state, make_train_step

N, ENTRIES, B_PER, EPOCHS = 32, 800, 8, 4


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", default="1,2,4,8")
    ap.add_argument("--lr-scaling", action="store_true",
                    help="linear LR scaling (the paper's Fig-8 mitigation)")
    args = ap.parse_args()

    ds = IndexDataset.from_raw(make_traffic_series(ENTRIES, N, seed=4),
                               WindowSpec(horizon=6)).to_device()
    adj = gaussian_adjacency(random_sensor_coords(N, seed=4))
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=N, hidden=16, input_len=6, horizon=6)
    params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, starts):
        x, y = gather_batch(ds.series, starts, input_len=6, horizon=6)
        return pgt_dcrnn.loss_fn(p, cfg, supports, x, y), {}

    val_ids = jnp.asarray(ds.starts[ds.val_windows[:64]])
    print("workers,global_batch,epoch_s(sim),steps/epoch,val_mae,lr")
    for w in [int(x) for x in args.workers.split(",")]:
        base_lr = 5e-3
        lr = (linear_scaled_lr(base_lr, B_PER * w, B_PER)
              if args.lr_scaling else base_lr)
        adam = AdamConfig(lr=lr)
        step = make_train_step(loss_fn, adam, lambda s, _lr=lr: _lr, donate=False)
        state = init_train_state(params, adam)
        sampler = GlobalShuffleSampler(ds.train_windows, B_PER, ShardInfo(0, w),
                                       seed=0)
        t0 = time.perf_counter()
        for epoch in range(EPOCHS):
            # one jitted step consumes the whole global batch (SPMD semantics);
            # per-worker wall time = measured / w (perfect DP overlap)
            for ids in sampler.epoch_global(epoch):
                state, _ = step(state, jnp.asarray(ds.starts[ids]))
        wall = (time.perf_counter() - t0) / EPOCHS / w
        vl, _ = loss_fn(state["params"], val_ids)
        print(f"{w},{B_PER * w},{wall:.2f},{sampler.steps_per_epoch},"
              f"{float(vl):.4f},{lr:.2e}")


if __name__ == "__main__":
    main()
