"""Quickstart: the paper's workflow in ~40 lines.

1. Build a PeMS-shaped synthetic series + sensor graph.
2. Index-batching preprocessing: ONE standardized series + int32 starts.
3. GPU-index-batching: place the series on device once.
4. Train PGT-DCRNN with global-shuffle sampling; batches are reconstructed
   on-device from indices — no snapshot array ever exists.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig
from repro.train import TrainLoopConfig, make_train_step, run_training
from repro.train.loop import init_train_state

NODES, ENTRIES, HORIZON, BATCH = 48, 1_000, 6, 16

# 1. data + graph
series = make_traffic_series(ENTRIES, NODES)
adj = gaussian_adjacency(random_sensor_coords(NODES))
supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))

# 2.+3. index-batching preprocessing, then one host->device transfer
ds = IndexDataset.from_raw(series, WindowSpec(horizon=HORIZON)).to_device()
print(f"windows={ds.n_windows}  compact={ds.nbytes_index() / 2**20:.2f} MiB  "
      f"materialized-would-be={ds.nbytes_materialized() / 2**20:.2f} MiB")

# 4. model + index-batched train step
cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=NODES, hidden=16,
                               input_len=HORIZON, horizon=HORIZON)
params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)


def loss_fn(p, starts):
    x, y = gather_batch(ds.series, starts, input_len=HORIZON, horizon=HORIZON)
    return pgt_dcrnn.loss_fn(p, cfg, supports, x, y), {}


adam = AdamConfig(lr=5e-3)
state, history = run_training(
    state=init_train_state(params, adam),
    train_step=make_train_step(loss_fn, adam, lambda s: 5e-3),
    sampler=GlobalShuffleSampler(ds.train_windows, BATCH, ShardInfo(0, 1)),
    batch_of_starts=lambda ids: jnp.asarray(ds.starts[ids]),
    loop=TrainLoopConfig(epochs=3, log_every=10),
)
logs = [h for h in history if "loss" in h]
print(f"loss {logs[0]['loss']:.4f} -> {logs[-1]['loss']:.4f} over {len(logs)} logs")
