"""Quickstart: the paper's workflow in ~30 lines via `repro.pipeline`.

1. Build a PeMS-shaped synthetic series + sensor graph.
2. `build_pipeline` does the rest — index-batching preprocessing (ONE
   standardized series + int32 starts), device placement for the chosen
   `Placement`, the matching sampler, and a jitted train step with the
   window gather fused in.  Batches are reconstructed on-device from
   indices; no snapshot array ever exists.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import WindowSpec
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.launch.mesh import make_host_mesh
from repro.models import pgt_dcrnn
from repro.optim import AdamConfig
from repro.pipeline import PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig

NODES, ENTRIES, HORIZON, BATCH = 48, 1_000, 6, 16

# 1. data + graph
series = make_traffic_series(ENTRIES, NODES)
adj = gaussian_adjacency(random_sensor_coords(NODES))
supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))

# 2. model loss on gathered (x, y) windows — the only model-specific piece
cfg = pgt_dcrnn.PGTDCRNNConfig(num_nodes=NODES, hidden=16,
                               input_len=HORIZON, horizon=HORIZON)
params = pgt_dcrnn.init(jax.random.PRNGKey(0), cfg)


def loss_fn(p, x, y):
    return pgt_dcrnn.loss_fn(p, cfg, supports, x, y), {}


# 3. the pipeline: placement + sampler + fused gather/step in one call
pipe = build_pipeline(
    series, WindowSpec(horizon=HORIZON), make_host_mesh(), loss_fn, params,
    PipelineConfig(batch_per_rank=BATCH, adam=AdamConfig(lr=5e-3),
                   loop=TrainLoopConfig(epochs=3, log_every=10)))
ds = pipe.dataset
print(f"windows={ds.n_windows}  compact={ds.nbytes_index() / 2**20:.2f} MiB  "
      f"materialized-would-be={ds.nbytes_materialized() / 2**20:.2f} MiB")

state, history = pipe.fit()
logs = [h for h in history if "loss" in h and "epoch_time_s" not in h]
print(f"loss {logs[0]['loss']:.4f} -> {logs[-1]['loss']:.4f} over {len(logs)} logs")
