"""End-to-end driver: train a ~100M-weight DCRNN on a PeMS-scaled synthetic
graph for a few hundred steps, with checkpoints, restart, and validation.

This is the full production path (the same code `repro.launch.train` wraps):
index-batching + device-resident series + global shuffling + async atomic
checkpoints + deterministic mid-epoch resume.

Run:  PYTHONPATH=src python examples/train_dcrnn_pems.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GlobalShuffleSampler, IndexDataset, ShardInfo,
                        WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import Checkpointer, latest_step, restore
from repro.models import dcrnn
from repro.optim import AdamConfig, warmup_cosine
from repro.train import TrainLoopConfig, make_train_step, run_training
from repro.train.loop import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--hidden", type=int, default=96)
    ap.add_argument("--entries", type=int, default=4_000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dcrnn_ckpt")
    args = ap.parse_args()

    cfg = dcrnn.DCRNNConfig(num_nodes=args.nodes, hidden=args.hidden, layers=2,
                            max_diffusion_step=2, input_len=12, horizon=12,
                            remat=True)
    # weight count scales with hidden^2; report it like a real driver would
    params = dcrnn.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"DCRNN params: {n_params / 1e6:.2f}M  nodes={args.nodes}")

    adj = gaussian_adjacency(random_sensor_coords(args.nodes))
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    series = make_traffic_series(args.entries, args.nodes, adjacency=adj)
    ds = IndexDataset.from_raw(series, WindowSpec(horizon=12)).to_device()
    print(f"series resident: {ds.nbytes_index() / 2**20:.1f} MiB "
          f"(materialized would be {ds.nbytes_materialized() / 2**30:.2f} GiB)")

    def loss_fn(p, starts):
        x, y = gather_batch(ds.series, starts, input_len=12, horizon=12)
        return dcrnn.loss_fn(p, cfg, supports, x, y), {}

    adam = AdamConfig(lr=1e-2)
    sched = lambda s: warmup_cosine(s, base_lr=1e-2, warmup_steps=20,
                                    total_steps=args.steps)
    step = make_train_step(loss_fn, adam, sched)
    sampler = GlobalShuffleSampler(ds.train_windows, args.batch, ShardInfo(0, 1))
    epochs = max(1, -(-args.steps // sampler.steps_per_epoch))

    state = init_train_state(params, adam)
    ck = Checkpointer(args.ckpt_dir, keep=2)
    start_step = 0
    if latest_step(args.ckpt_dir) is not None:
        state, start_step = restore(args.ckpt_dir, state)
        print(f"resumed from step {start_step}")

    def eval_fn(st):
        ids = ds.starts[ds.val_windows[: 4 * args.batch]]
        l, _ = loss_fn(st["params"], jnp.asarray(ids))
        return {"val_mae": float(l)}

    t0 = time.perf_counter()
    state, history = run_training(
        state=state, train_step=step, sampler=sampler,
        batch_of_starts=lambda ids: jnp.asarray(ds.starts[ids]),
        loop=TrainLoopConfig(epochs=epochs, log_every=20, ckpt_every=50,
                             ckpt_dir=args.ckpt_dir),
        eval_fn=eval_fn, checkpointer=ck,
        start_epoch=start_step // sampler.steps_per_epoch,
        start_step=start_step)
    logs = [h for h in history if "loss" in h]
    vals = [h for h in history if "val_mae" in h]
    print(f"wall {time.perf_counter() - t0:.1f}s  "
          f"train {logs[0]['loss']:.4f}->{logs[-1]['loss']:.4f}  "
          f"val {vals[-1]['val_mae']:.4f}  ckpts={ck.steps()}")


if __name__ == "__main__":
    main()
