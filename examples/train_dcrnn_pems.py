"""End-to-end driver: train a ~100M-weight DCRNN on a PeMS-scaled synthetic
graph for a few hundred steps, with checkpoints, restart, and validation.

This is the full production path through `repro.pipeline`: index-batching +
device-resident series + global shuffling + async atomic checkpoints +
deterministic mid-epoch resume — the pipeline owns the sampler/placement/step
wiring the old driver glued by hand.

Run:  PYTHONPATH=src python examples/train_dcrnn_pems.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import WindowSpec
from repro.data import (gaussian_adjacency, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import latest_step
from repro.launch.mesh import make_host_mesh
from repro.models import dcrnn
from repro.optim import AdamConfig, warmup_cosine
from repro.pipeline import PipelineConfig, build_pipeline
from repro.train import TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--hidden", type=int, default=96)
    ap.add_argument("--entries", type=int, default=4_000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--gather", default="slice",
                    choices=["slice", "take", "fused", "pallas"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dcrnn_ckpt")
    args = ap.parse_args()

    cfg = dcrnn.DCRNNConfig(num_nodes=args.nodes, hidden=args.hidden, layers=2,
                            max_diffusion_step=2, input_len=12, horizon=12,
                            remat=True)
    # weight count scales with hidden^2; report it like a real driver would
    params = dcrnn.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"DCRNN params: {n_params / 1e6:.2f}M  nodes={args.nodes}")

    adj = gaussian_adjacency(random_sensor_coords(args.nodes))
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    series = make_traffic_series(args.entries, args.nodes, adjacency=adj)

    def loss_fn(p, x, y):
        return dcrnn.loss_fn(p, cfg, supports, x, y), {}

    pipe = build_pipeline(
        series, WindowSpec(horizon=12), make_host_mesh(), loss_fn, params,
        PipelineConfig(
            batch_per_rank=args.batch, gather=args.gather,
            adam=AdamConfig(lr=1e-2),
            schedule=lambda s: warmup_cosine(s, base_lr=1e-2, warmup_steps=20,
                                             total_steps=args.steps),
            loop=TrainLoopConfig(log_every=20, ckpt_every=50,
                                 ckpt_dir=args.ckpt_dir)))
    ds = pipe.dataset
    print(f"series resident: {ds.nbytes_index() / 2**20:.1f} MiB "
          f"(materialized would be {ds.nbytes_materialized() / 2**30:.2f} GiB)")
    resumed = latest_step(args.ckpt_dir)
    if resumed is not None:
        print(f"resuming from step {resumed}")

    t0 = time.perf_counter()
    epochs = max(1, -(-args.steps // pipe.steps_per_epoch))
    state, history = pipe.fit(epochs=epochs)
    # step logs when log_every fired, else fall back to epoch summaries
    logs = ([h for h in history if "loss" in h and "epoch_time_s" not in h]
            or [h for h in history if "loss" in h])
    vals = [h for h in history if "val_mae" in h]
    if not logs:  # history empty: resume already covered every step
        print(f"nothing to train: checkpoint already at step {resumed}")
        return
    print(f"wall {time.perf_counter() - t0:.1f}s  "
          f"train {logs[0]['loss']:.4f}->{logs[-1]['loss']:.4f}  "
          f"val {vals[-1]['val_mae']:.4f}")


if __name__ == "__main__":
    main()
