"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The ``os.environ`` line below MUST run before any other import: jax locks the
device count on first init, and the production meshes need 512 placeholder
host devices.  (Set here, in the module, NOT globally — smoke tests and
benches see 1 device.)

Per cell this proves the distribution config is coherent with no hardware:
``jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs).compile()``
must succeed; ``memory_analysis()`` proves the per-device footprint and
``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun  # full matrix
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback

import jax

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_LINE_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)([\w\-.]*)\(")


def _shapes_bytes(shape_str: str) -> int:
    """Total bytes of all HLO shapes in a string like '(f32[8,128]{1,0}, u32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    The compiled module is the per-device program, so these are bytes moved
    per device.  Async pairs count the -start only; -done is skipped.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if m is None:
            continue
        shape_str, op, suffix = m.groups()
        if "done" in suffix:
            continue  # async pair: bytes were counted at the -start
        out[op] += _shapes_bytes(shape_str)
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, **build_kw) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "x".join(str(s) for s in mesh.devices.shape),
                 "chips": mesh_chips(mesh), "multi_pod": multi_pod,
                 "options": {k: str(v) for k, v in build_kw.items()}}
    t0 = time.time()
    try:
        prog = build_cell(arch_id, shape_name, mesh, **build_kw)
        donate = prog.meta.get("donate", ())
        with mesh:
            jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                             out_shardings=prog.out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*prog.args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            }
            ca = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            from repro.launch.costs import analyze_hlo

            hc = analyze_hlo(hlo_text)
            rec["cost"] = {
                # loop-aware (while bodies × trip count) — the roofline inputs
                "flops": hc.flops,
                "bytes_accessed": hc.bytes,
                # raw XLA numbers (loop bodies counted once) for reference
                "xla_flops": float(ca.get("flops", 0.0)),
                "xla_bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            }
            rec["collectives"] = {**{k: v for k, v in hc.coll_by_op.items()},
                                  "total": hc.coll_bytes}
            rec["meta"] = {k: (float(v) if isinstance(v, (int, float)) else v)
                           for k, v in prog.meta.items()}
            rec["kind"] = prog.kind
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        if rec["status"] == "ok":
            mem = rec["memory"]
            print(f"[ok] {arch_id}:{shape_name} mesh={rec['mesh']} "
                  f"compile={rec['compile_s']}s "
                  f"peak/device={mem['peak_bytes']/2**30:.2f}GiB "
                  f"flops/device={rec['cost']['flops']:.3e} "
                  f"coll/device={rec['collectives']['total']/2**20:.1f}MiB")
        else:
            print(f"[ERR] {arch_id}:{shape_name} mesh={rec['mesh']}: {rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="full 40-cell matrix")
    ap.add_argument("--placement", default="replicated",
                    choices=["replicated", "partitioned", "ondemand"],
                    help="ST-GNN series placement")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args()

    from repro.launch.specs import all_cells

    records = []
    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, None)]

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for aid, shape, skip in cells:
        if skip:
            records.append({"arch": aid, "shape": shape, "status": "skipped",
                            "reason": skip})
            print(f"[skip] {aid}:{shape} — {skip[:80]}")
            continue
        for mp in meshes:
            kw = {}
            from repro.configs import get_arch
            if get_arch(aid).family == "stgnn":
                kw["placement"] = args.placement
            records.append(run_cell(aid, shape, multi_pod=mp, **kw))

    if args.out:
        import os as _os
        _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")
    n_err = sum(1 for r in records if r.get("status") == "error")
    if n_err:
        raise SystemExit(f"{n_err} cells failed")


if __name__ == "__main__":
    main()
