"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

``main()`` forces 512 placeholder host devices FIRST THING — jax locks the
device count on first backend init, and the production meshes need them.
The override lives in main(), not at module scope: this module is also
imported as a library (``collective_bytes``, ``partitioned_halo_evidence``)
by tests and notebooks, which must keep their own device count.

Per cell this proves the distribution config is coherent with no hardware:
``jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs).compile()``
must succeed; ``memory_analysis()`` proves the per-device footprint and
``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun  # full matrix
"""
import os

import argparse
import json
import re
import time
import traceback

import jax

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_LINE_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)([\w\-.]*)\(")


def _shapes_bytes(shape_str: str) -> int:
    """Total bytes of all HLO shapes in a string like '(f32[8,128]{1,0}, u32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    The compiled module is the per-device program, so these are bytes moved
    per device.  Async pairs count the -start only; -done is skipped.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if m is None:
            continue
        shape_str, op, suffix = m.groups()
        if "done" in suffix:
            continue  # async pair: bytes were counted at the -start
        out[op] += _shapes_bytes(shape_str)
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def partitioned_halo_evidence(mesh=None, *, entries: int = 256, nodes: int = 4,
                              features: int = 2, global_batch: int = 16,
                              input_len: int = 3, horizon: int = 3) -> dict:
    """Collective-bytes evidence for the PARTITIONED ``halo`` knob.

    ``halo=False`` (``PipelineConfig(halo=False)``) confines every sampled
    window to the series shard its rank's device owns, so the step lowers as
    a shard_map whose gathers are provably local — the compiled program's
    ONLY collective is the gradient all-reduce.  ``halo=True`` windows may
    spill ``span−1`` steps into the next shard, which forces the global-index
    lowering and materialises an all-gather of the resident series.

    Compiles both lowerings on ``mesh`` (default: the host mesh) against
    abstract shapes and returns their per-device collective-byte tables plus
    ``data_bytes`` = everything except the gradient all-reduce.
    """
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.batching import gather_batch_fused
    from repro.launch.mesh import make_host_mesh, shrink_mesh

    if mesh is None:
        # Cap the default at 8 data slots: the dryrun CLI forces 512 host
        # devices, which the small evidence shapes cannot divide.
        mesh = shrink_mesh(make_host_mesh(), 8)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    all_axes = tuple(mesh.axis_names)
    series_sh = NamedSharding(mesh, P(dp))
    batch_sh = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())

    def loss(w, series, starts):
        x, y = gather_batch_fused(series, starts, input_len=input_len,
                                  horizon=horizon)
        return jnp.mean((x * w).sum(-1) ** 2) + jnp.mean(y)

    def step_global(w, series, starts):
        return jax.value_and_grad(loss)(w, series, starts)

    # Mirrors engine._shard_local_gather: inside the shard, global starts
    # become shard-local offsets (start − shard origin) before gathering.
    dp_total = 1
    for a in dp:
        dp_total *= int(mesh.shape[a])
    shard_len = entries // max(dp_total, 1)

    def body(w, series_shard, starts_shard):
        lo = jax.lax.axis_index(dp[0]) * shard_len
        l, g = jax.value_and_grad(loss)(w, series_shard, starts_shard - lo)
        return jax.lax.pmean(l, all_axes), jax.lax.pmean(g, all_axes)

    step_local = shard_map(body, mesh=mesh,
                           in_specs=(P(), P(dp), P(dp)),
                           out_specs=(P(), P()), check_rep=False)

    sds = jax.ShapeDtypeStruct
    args = (sds((features,), jnp.float32),
            sds((entries, nodes, features), jnp.float32),
            sds((global_batch,), jnp.int32))

    def compile_and_count(fn):
        compiled = jax.jit(fn, in_shardings=(rep, series_sh, batch_sh),
                           out_shardings=(rep, rep)).lower(*args).compile()
        coll = collective_bytes(compiled.as_text())
        coll["data_bytes"] = coll["total"] - coll["all-reduce"]
        return coll

    return {
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "dims": {"entries": entries, "nodes": nodes, "features": features,
                 "global_batch": global_batch, "input_len": input_len,
                 "horizon": horizon},
        # halo=False contract: shard-local gathers (shard_map lowering)
        "halo_false": compile_and_count(step_local),
        # halo=True upper bound: global-index gathers over the sharded series
        "halo_true": compile_and_count(step_global),
    }


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, **build_kw) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "x".join(str(s) for s in mesh.devices.shape),
                 "chips": mesh_chips(mesh), "multi_pod": multi_pod,
                 "options": {k: str(v) for k, v in build_kw.items()}}
    t0 = time.time()
    try:
        prog = build_cell(arch_id, shape_name, mesh, **build_kw)
        donate = prog.meta.get("donate", ())
        with mesh:
            jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                             out_shardings=prog.out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*prog.args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            }
            ca = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            from repro.launch.costs import analyze_hlo

            hc = analyze_hlo(hlo_text)
            rec["cost"] = {
                # loop-aware (while bodies × trip count) — the roofline inputs
                "flops": hc.flops,
                "bytes_accessed": hc.bytes,
                # raw XLA numbers (loop bodies counted once) for reference
                "xla_flops": float(ca.get("flops", 0.0)),
                "xla_bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            }
            rec["collectives"] = {**{k: v for k, v in hc.coll_by_op.items()},
                                  "total": hc.coll_bytes}
            rec["meta"] = {k: (float(v) if isinstance(v, (int, float)) else v)
                           for k, v in prog.meta.items()}
            rec["kind"] = prog.kind
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        if rec["status"] == "ok":
            mem = rec["memory"]
            print(f"[ok] {arch_id}:{shape_name} mesh={rec['mesh']} "
                  f"compile={rec['compile_s']}s "
                  f"peak/device={mem['peak_bytes']/2**30:.2f}GiB "
                  f"flops/device={rec['cost']['flops']:.3e} "
                  f"coll/device={rec['collectives']['total']/2**20:.1f}MiB")
        else:
            print(f"[ERR] {arch_id}:{shape_name} mesh={rec['mesh']}: {rec['error']}")
    return rec


def main() -> None:
    # Must precede the first backend init (jax.devices()/device_put/...);
    # imports above only bind the jax module and do not lock the count.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="full 40-cell matrix")
    ap.add_argument("--placement", default="replicated",
                    choices=["replicated", "partitioned", "ondemand"],
                    help="ST-GNN series placement")
    ap.add_argument("--halo-evidence", action="store_true",
                    help="compile the PARTITIONED step with shard-local "
                         "(halo=False) vs global-index (halo=True) gathers "
                         "and report per-device collective bytes")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args()

    if args.halo_evidence:
        rec = partitioned_halo_evidence()
        print(json.dumps(rec, indent=1))
        if args.out:
            import os as _os
            _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(rec, f, indent=1)
        df, dt = rec["halo_false"]["data_bytes"], rec["halo_true"]["data_bytes"]
        print(f"halo=False data-collective bytes/device: {df} "
              f"(communication-free: {df == 0}); halo=True: {dt}")
        return

    from repro.launch.specs import all_cells

    records = []
    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, None)]

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for aid, shape, skip in cells:
        if skip:
            records.append({"arch": aid, "shape": shape, "status": "skipped",
                            "reason": skip})
            print(f"[skip] {aid}:{shape} — {skip[:80]}")
            continue
        for mp in meshes:
            kw = {}
            from repro.configs import get_arch
            if get_arch(aid).family == "stgnn":
                kw["placement"] = args.placement
            records.append(run_cell(aid, shape, multi_pod=mp, **kw))

    if args.out:
        import os as _os
        _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")
    n_err = sum(1 for r in records if r.get("status") == "error")
    if n_err:
        raise SystemExit(f"{n_err} cells failed")


if __name__ == "__main__":
    main()
