"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` visits each while-loop body ONCE (measured:
a scan of 10 matmuls reports 1/10th the FLOPs), which silently undercounts
every scanned layer stack, attention chunk loop and recurrence in this
codebase.  This module re-derives costs from the post-optimization HLO text
with loop trip counts rolled up:

- FLOPs: every ``dot`` (2·M·N·K from the dimension numbers) and
  ``convolution``, including dots inside fusion computations.
- bytes: the *fusion-boundary traffic model* — operands + results of fusions,
  dots, gathers/scatters/dynamic-slices and other unfused data movers.  Ops
  fused together contribute only their boundary — matching what actually
  moves through HBM.
- collective bytes: result shapes of all-gather / all-reduce / reduce-scatter
  / all-to-all / collective-permute (async -start counted once).
- while loops: body + condition costs × trip count (extracted from the
  condition's comparison against a constant; conservative 1 if unknown).

Shapes in the compiled module are per-device (post-SPMD), so all returned
costs are per-device per step.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "token": 0, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = <shape-or-tuple> opcode(...)" — shape may be a tuple
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALLED_RE = re.compile(r"(?:calls|to_apply|condition|body|branch_computations|true_computation|false_computation)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes_all(s: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(s))


@dataclasses.dataclass
class _Op:
    name: str
    shape_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list
    by_name: dict

    _params: list | None = None
    _sliced: dict | None = None

    def parameters(self) -> list:
        """Parameter ops in positional order."""
        if self._params is None:
            ps = [op for op in self.ops if op.opcode == "parameter"]
            def idx(op):
                m = re.search(r"parameter\((\d+)\)", op.line)
                return int(m.group(1)) if m else 0
            self._params = sorted(ps, key=idx)
        return self._params

    def sliced_param_bytes(self) -> dict:
        """param name -> touched bytes, for params whose ONLY consumers are
        dynamic-slice/gather (the fused-slice pattern: the fusion operand is
        the full stack but only a slice's worth of HBM moves)."""
        if self._sliced is not None:
            return self._sliced
        consumers: dict[str, list] = {}
        for op in self.ops:
            for nm in _operand_names(op.line):
                consumers.setdefault(nm, []).append(op)
        out = {}
        for p in self.parameters():
            cons = consumers.get(p.name, [])
            if cons and all(c.opcode in ("dynamic-slice", "gather", "slice")
                            and _operand_names(c.line)[:1] == [p.name]
                            for c in cons):
                out[p.name] = sum(_shapes_first_bytes(c.shape_str) for c in cons)
        self._sliced = out
        return out


def _parse_computations(text: str) -> dict[str, "_Computation"]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("#"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and (line.startswith("%") or line.startswith("ENTRY")) and s.endswith("{"):
            cur = _Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            op = _Op(m.group(1), m.group(2), m.group(3), s)
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 × result_elems × contracted_elems for one dot."""
    res = _SHAPE_RE.findall(op.shape_str)
    if not res:
        return 0.0
    out_elems = _shape_elems(res[0][1])
    # contracting dims come from lhs shape + lhs_contracting_dims; the HLO
    # printer may type operands inline ("dot(f32[...] %lhs, ...)"), so pull
    # the %-prefixed operand names rather than the first token after "dot(".
    operands = _operand_names(op.line)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not operands:
        return 2.0 * out_elems
    lhs = comp.by_name.get(operands[0])
    if lhs is None:
        return 2.0 * out_elems  # parameter operand — be conservative
    lhs_dims = _SHAPE_RE.findall(lhs.shape_str)
    if not lhs_dims:
        return 2.0 * out_elems
    dims = [int(d) for d in lhs_dims[0][1].split(",") if d]
    if mc is not None and mc.group(1):
        k = 1
        for ci in mc.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    else:
        k = dims[-1] if dims else 1
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, comp: _Computation) -> float:
    """2 × result_elems × (kernel spatial × in-channels) — rough but fair."""
    res = _SHAPE_RE.findall(op.shape_str)
    if not res:
        return 0.0
    out_elems = _shape_elems(res[0][1])
    operands = _operand_names(op.line)
    if len(operands) < 2:
        return 2.0 * out_elems
    ker = comp.by_name.get(operands[1])
    if ker is None:
        return 2.0 * out_elems
    kd = _SHAPE_RE.findall(ker.shape_str)
    kelems = _shape_elems(kd[0][1]) if kd else 1
    od = _SHAPE_RE.findall(op.shape_str)
    oc = 1
    if od:
        dims = [int(d) for d in od[0][1].split(",") if d]
        oc = dims[-1] if dims else 1
    return 2.0 * out_elems * max(kelems // max(oc, 1), 1)


def _operand_names(line: str) -> list[str]:
    # The operand list is the first parenthesised group that references
    # %-named values; earlier paren groups can be layout tile annotations
    # in the result shape (e.g. "{1,0:T(8,128)}" on TPU HLO), which must
    # be skipped or every op on such a line would appear operand-less.
    for m in re.finditer(r"\w[\w\-.]*\(([^)]*)\)", line):
        names = re.findall(r"%([\w.\-]+)", m.group(1))
        if names:
            return names
    return []


_MOVER_OPS = {"fusion", "dot", "convolution", "gather", "scatter",
              "dynamic-slice", "dynamic-update-slice", "copy", "transpose",
              "reduce", "sort", "concatenate", "pad",
              "slice", "convert", "reduce-window", "select-and-scatter"}
# reshape/bitcast/broadcast are layout-level; parameters etc. are free
_FREE_OPS = {"bitcast", "reshape", "parameter", "constant", "tuple",
             "get-tuple-element", "iota", "broadcast"}


def _op_bytes(op: _Op, comp: _Computation, all_comps: dict | None = None) -> float:
    if op.opcode in _FREE_OPS or op.opcode not in _MOVER_OPS:
        return 0.0
    result = _shape_bytes_all(op.shape_str)
    # indexed movers touch only the selected region, not the whole operand
    if op.opcode in ("dynamic-slice", "slice"):
        return 2.0 * result  # read region + write result
    if op.opcode == "gather":
        return 2.0 * result  # touched rows ≈ result size
    if op.opcode in ("dynamic-update-slice", "scatter"):
        # update region read+write; the big buffer aliases in place
        upd = 0.0
        names = _operand_names(op.line)
        if len(names) >= 2:
            o = comp.by_name.get(names[1])
            if o is not None:
                upd = _shapes_first_bytes(o.shape_str)
        return 2.0 * (upd or result)
    total = float(result)
    sliced = None
    if op.opcode == "fusion" and all_comps is not None:
        mc = _CALLED_RE.search(op.line)
        if mc:
            callee = all_comps.get(mc.group(1).split(",")[0].strip().lstrip("%"))
            if callee is not None:
                sliced = callee.sliced_param_bytes()
                callee_params = callee.parameters()
    names = _operand_names(op.line)
    for i, nm in enumerate(names):
        o = comp.by_name.get(nm)
        if o is None:
            continue
        full = _shapes_first_bytes(o.shape_str)
        if sliced is not None and i < len(callee_params):
            pname = callee_params[i].name
            if pname in sliced:
                full = min(full, sliced[pname])
        total += full
    return total


def _shapes_first_bytes(shape_str: str) -> int:
    """Bytes of the first (result) shape only — operands are single shapes."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    return _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)


def _trip_count(while_line: str, cond: _Computation | None) -> int:
    """Trip count: XLA's ``known_trip_count`` backend_config when present,
    else the largest integer constant in the condition computation."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_line)
    if m:
        return max(int(m.group(1)), 1)
    best = 1
    if cond is not None:
        for op in cond.ops:
            if op.opcode == "constant":
                mc = re.search(r"constant\((\d+)\)", op.line)
                if mc:
                    best = max(best, int(mc.group(1)))
    return best


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                       {o: b * k for o, b in self.coll_by_op.items()})

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for o, b in other.coll_by_op.items():
            self.coll_by_op[o] = self.coll_by_op.get(o, 0.0) + b


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    memo: dict[str, HloCost] = {}

    def cost_of(name: str, stack=(), *, flops_only: bool = False) -> HloCost:
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return HloCost()
        comp = comps[name]
        total = HloCost()
        for op in comp.ops:
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                cond = comps.get(mc.group(1)) if mc else None
                trips = _trip_count(op.line, cond)
                if mb and mb.group(1) in comps:
                    total.add(cost_of(mb.group(1), stack + (name,),
                                      flops_only=flops_only).scaled(trips))
                continue
            if op.opcode in ("call", "conditional", "map", "async-start"):
                for m in _CALLED_RE.finditer(op.line):
                    for sub in m.group(1).split(","):
                        total.add(cost_of(sub.strip().lstrip("%"),
                                          stack + (name,), flops_only=flops_only))
            elif op.opcode == "fusion":
                # fusion interior: flops only — HBM traffic is the boundary,
                # which _op_bytes charges on the fusion op itself
                for m in _CALLED_RE.finditer(op.line):
                    for sub in m.group(1).split(","):
                        total.add(cost_of(sub.strip().lstrip("%"),
                                          stack + (name,), flops_only=True))
            coll = next((c for c in _COLLECTIVES if op.opcode.startswith(c)), None)
            if coll is not None:
                if "done" in op.opcode[len(coll):]:
                    continue
                b = _shape_bytes_all(op.shape_str)
                total.coll_bytes += b
                total.coll_by_op[coll] = total.coll_by_op.get(coll, 0.0) + b
                continue
            if op.opcode == "dot":
                total.flops += _dot_flops(op, comp)
            elif op.opcode == "convolution":
                total.flops += _conv_flops(op, comp)
            if not flops_only:
                total.bytes += _op_bytes(op, comp, comps)
        memo[key] = total
        return total

    entry = None
    # ENTRY computation: the one declared with "ENTRY" or falls back to last
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None and comps:
        entry = next(iter(comps))
    return cost_of(entry) if entry else HloCost()
