"""Production mesh definitions.

Single pod = 16×16 = 256 chips (v5e pod), axes (data, model); multi-pod adds a
leading "pod" axis (2×16×16 = 512 chips).  The pod axis rides the slow DCN/ICI
link, so shardings keep it pure-DP: the only cross-pod collective is the
gradient all-reduce.

Functions (not module constants) so importing never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(*, model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / single-host training)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def shrink_mesh(mesh: Mesh, new_dp: int) -> Mesh:
    """Largest sub-mesh with ``new_dp`` data-parallel slots, model axis whole.

    The elastic engine calls this after ``plan_remesh`` shrinks the data
    axis.  When the physical device pool is already at or below the target
    (simulated worlds on a small host mesh), the mesh is returned unchanged —
    the *logical* world still shrinks in the sampler/config.
    """
    model = int(mesh.shape.get("model", 1))
    devs = np.asarray(mesh.devices).reshape(-1, model)
    if new_dp >= devs.shape[0]:
        return mesh
    return Mesh(devs[:new_dp], ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))
