"""Sharding rules: param-tree path → PartitionSpec for every arch family.

Scheme (Megatron-style TP over "model", FSDP over "data", pure DP over "pod"):

- attention: wq/wk/wv shard the head output dim over model (iff the head
  count divides TP so the post-matmul reshape stays shard-aligned); wo shards
  its input dim.  MLA shards the latent-expansion weights per-head.
- MLP: wi/wg shard d_ff (column parallel); wo shards d_ff (row parallel) —
  one all-reduce per block, the classic pattern.
- MoE: experts shard over model (EP) when n_experts % tp == 0, else TP
  inside each expert over d_expert.
- embeddings / lm_head: vocab-sharded over model when divisible.
- FSDP: every leaf additionally shards its largest remaining dim over "data"
  when divisible — params, grads and Adam state all follow the same spec.
- anything that fails divisibility falls back to replication on that axis
  (correct, just less sharded) — this is what makes ALL 10 archs lower on the
  fixed production mesh without per-arch hand-tuning.

``pure_dp=True`` reproduces the paper's DDP exactly: params fully replicated,
batch sharded over every axis; used for the paper-faithful ST-GNN baseline.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


# --------------------------------------------------------------------- helpers
def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _with_fsdp(spec: list, shape: tuple, mesh: Mesh, fsdp_axes: tuple[str, ...],
               min_size: int = 2**16) -> list:
    """Add FSDP sharding on the largest unsharded dim (params >= min_size)."""
    if not fsdp_axes or int(np.prod(shape)) < min_size:
        return spec
    fsdp_n = int(np.prod([mesh.shape[a] for a in fsdp_axes]))
    # largest dim not already sharded, divisible by the fsdp extent
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and _div(shape[i], fsdp_n):
            spec[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            break
    return spec


# ------------------------------------------------------------------- LM params
def lm_param_spec(path: str, shape: tuple, cfg, mesh: Mesh, *,
                  fsdp: tuple[str, ...] = ("data",), tp_rules: bool = True) -> P:
    """PartitionSpec for one LM param leaf.

    ``shape`` includes the stage-stacking leading ``repeats`` dim for leaves
    under stages/ — rules index dims from the END so they hold for both.
    ``tp_rules=False`` disables tensor parallelism entirely (the 2D/ZeRO-3
    scheme: params fully FSDP-sharded, batch over every axis).
    """
    # tp=0 disables every TP rule branch (_div(n, 0) is False)
    tp = int(mesh.shape.get("model", 1)) if tp_rules else 0
    nd = len(shape)
    spec: list = [None] * nd

    def last(i):  # index from the end
        return nd - i

    name = path.rsplit("/", 1)[-1]
    parent = path.rsplit("/", 2)[-2] if "/" in path else ""

    if "lm_head" in path:
        # [d, V]: vocab (last dim) sharded — column-parallel logits, so the
        # [B,S,V] logits stay vocab-sharded with no collective in the matmul
        if _div(shape[-1], tp):
            spec[-1] = "model"
    elif "embed" in path or path == "pos":
        # [V, d] / [S, d]: vocab/position-sharded over model when divisible
        if _div(shape[0], tp):
            spec[0] = "model"
    elif "/attn/" in path and name == "w":
        hd = cfg.hd
        if parent in ("wq", "wo"):
            heads_ok = _div(cfg.n_heads, tp)
            if parent == "wq" and heads_ok:
                spec[last(1)] = "model"  # column: [*, d, H*hd]
            elif parent == "wo" and heads_ok:
                spec[last(2)] = "model"  # row: [*, H*hd, d]
        elif parent in ("wk", "wv") and _div(cfg.n_kv_heads, tp):
            spec[last(1)] = "model"
        elif parent == "wq" and cfg.mla is not None and _div(cfg.n_heads, tp):
            spec[last(1)] = "model"
        elif parent in ("wukv",) and _div(cfg.n_heads, tp):
            spec[last(1)] = "model"
        # wdkv (latent down-proj) stays TP-replicated: its output is the cache
    elif "/attn/" in path and name == "b":
        if parent == "wq" and _div(cfg.n_heads, tp):
            spec[last(1)] = "model"
        elif parent in ("wk", "wv") and _div(cfg.n_kv_heads, tp):
            spec[last(1)] = "model"
    elif "/mlp/" in path and name == "w":
        dff = shape[last(1)] if parent in ("wi", "wg") else shape[last(2)]
        if parent in ("wi", "wg") and _div(dff, tp):
            spec[last(1)] = "model"
        elif parent == "wo" and _div(dff, tp):
            spec[last(2)] = "model"
    elif "/moe/" in path:
        if name == "w" and parent == "router":
            pass  # router stays replicated (tiny, f32)
        elif name in ("wi", "wg", "wo"):
            e = cfg.moe.n_experts
            de = cfg.moe.d_expert or cfg.d_ff
            if _div(e, tp):
                spec[last(3)] = "model"  # EP: [*, E, d, de]
            elif name in ("wi", "wg") and _div(de, tp):
                spec[last(1)] = "model"
            elif name == "wo" and _div(de, tp):
                spec[last(2)] = "model"
        elif "/shared/" in path and name == "w":
            dff = shape[last(1)] if parent in ("wi", "wg") else shape[last(2)]
            if parent in ("wi", "wg") and _div(dff, tp):
                spec[last(1)] = "model"
            elif parent == "wo" and _div(dff, tp):
                spec[last(2)] = "model"
    elif "/rec/" in path and name == "w":
        w_lru = cfg.lru_width or cfg.d_model
        if parent in ("in_x", "in_gate", "wa", "wx") and _div(w_lru, tp):
            spec[last(1)] = "model"
        elif parent == "out" and _div(w_lru, tp):
            spec[last(2)] = "model"
    elif "/rwkv/" in path and name == "w":
        if parent in ("wr", "wk", "wv", "wg", "cm_k", "cm_r") and _div(shape[last(1)], tp):
            spec[last(1)] = "model"
        elif parent in ("wo", "cm_v") and _div(shape[last(2)], tp):
            spec[last(2)] = "model"

    spec = _with_fsdp(spec, shape, mesh, fsdp)
    return P(*spec)


def lm_param_shardings(params_shape: Any, cfg, mesh: Mesh, *,
                       fsdp: tuple[str, ...] = ("data",), pure_dp: bool = False,
                       tp_rules: bool = True):
    """NamedSharding pytree congruent with the params pytree."""
    def one(path, leaf):
        if pure_dp:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, lm_param_spec(_path_str(path), leaf.shape, cfg, mesh,
                                fsdp=fsdp, tp_rules=tp_rules))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_shardings(param_shardings: Any, mesh: Mesh):
    """Adam m/v follow the param shardings; step is replicated."""
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }


def state_shardings(param_shardings: Any, mesh: Mesh):
    return {"params": param_shardings,
            "opt": opt_state_shardings(param_shardings, mesh)}


# ---------------------------------------------------------------- activations
def batch_spec(mesh: Mesh, *, pure_dp: bool = False) -> P:
    axes = tuple(mesh.axis_names) if pure_dp else dp_axes(mesh)
    return P(axes)


def batch_sharding(mesh: Mesh, *, pure_dp: bool = False) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, pure_dp=pure_dp))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# -------------------------------------------------------------------- caches
def cache_shardings(cache_shape: Any, cfg, mesh: Mesh):
    """Decode caches: batch over data axes, long/state dim over model.

    kv caches  [R, B, S, Hkv, hd] -> P(None, dp, "model", None, None) (S-sharded:
    the sequence axis is the only one guaranteed divisible at 32k; attention
    over an S-sharded cache reduces partial softmax stats over model).
    MLA latent [R, B, S, r]       -> S over model.
    RG-LRU / RWKV state           -> feature/head dim over model when divisible.
    """
    dp = dp_axes(mesh)
    tp = int(mesh.shape.get("model", 1))

    def one(path, leaf):
        nd = len(leaf.shape)
        spec: list = [None] * nd
        # batch axis: axis 1 for stage-stacked caches, 0 otherwise
        b_ax = 1 if nd >= 2 else 0
        dp_n = int(np.prod([mesh.shape[a] for a in dp]))
        if _div(leaf.shape[b_ax], dp_n):
            spec[b_ax] = dp if len(dp) > 1 else dp[0]
        name = _path_str(path)
        if nd >= 4 and ("/k" in name or "/v" in name or "ckv" in name or "kpe" in name):
            if _div(leaf.shape[b_ax + 1], tp):
                spec[b_ax + 1] = "model"  # sequence axis
        elif nd >= 3 and ("ckv" in name or "kpe" in name):
            if _div(leaf.shape[b_ax + 1], tp):
                spec[b_ax + 1] = "model"
        else:  # recurrent state: shard trailing feature dim when divisible
            if nd >= 2 and _div(leaf.shape[-1], tp) and leaf.shape[-1] >= 1024:
                spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def paged_cache_shardings(cache_shape: Any, cfg, mesh: Mesh, mask):
    """Shardings for a paged pool (``lm.init_paged_cache``).

    Paged leaves ``[R, num_blocks, block_size, ...]`` shard the BLOCKS axis
    over the data axes when divisible (the plane pads the pool to a dp
    multiple) — block-table gathers across a blocks-sharded pool lower to a
    collective gather, which is correct under any table contents; the
    null-block row replicates with its shard.  Per-lane (unpaged) leaves keep
    the ``cache_shardings`` rules.  ``mask``: ``lm.paged_cache_mask(cfg)``.
    """
    dp = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    contiguous = cache_shardings(cache_shape, cfg, mesh)

    def one(is_paged, leaf, fallback):
        if not is_paged:
            return fallback
        spec: list = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2 and _div(leaf.shape[1], dp_n):
            spec[1] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, mask, cache_shape, contiguous)


# -------------------------------------------------------------------- ST-GNN
def stgnn_param_shardings(params_shape: Any, mesh: Mesh):
    """DCRNN-family params are tiny (hidden 64) — replicate (the paper's DDP)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), params_shape)


def series_sharding(mesh: Mesh, *, partitioned: bool) -> NamedSharding:
    """Resident series [T, N, F]: replicated (distributed-index-batching) or
    time-sharded over the data axes (generalized / baseline-DDP)."""
    if not partitioned:
        return NamedSharding(mesh, P())
    dp = dp_axes(mesh)
    return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
