"""Serving launcher: replay an arrival trace through the ServeEngine.

Drives the sharded serving engine (``repro.serve``) on a reduced config —
a plane fleet over the host mesh, batched prefill, per-request deadlines —
and prints what it served.  ``--trace batch`` submits everything up front
(the PR-4 demo behaviour); ``--trace poisson`` replays independent arrivals
at ``--rate`` req/s against the wall clock, so backpressure and deadline
expiry actually fire.

  python -m repro.launch.serve --arch qwen1.5-4b --requests 8 --slots 4
  python -m repro.launch.serve --trace poisson --rate 30 --deadline 2.0
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.lm import model as lm
from repro.serve import Backpressure, ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode lanes per plane")
    ap.add_argument("--planes", type=int, default=1,
                    help="inference planes (each owns a slot pool)")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--trace", choices=("batch", "poisson"), default="batch",
                    help="batch: submit all up front; poisson: timed arrivals")
    ap.add_argument("--rate", type=float, default=30.0,
                    help="poisson arrival rate, requests/second")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (default: none)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.lm is None:
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = arch.smoke_config()
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg,
                         ServeConfig(slots=args.slots, max_len=args.max_len,
                                     max_new_tokens=args.max_new_tokens,
                                     temperature=args.temperature),
                         planes=args.planes, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 17)))
               for _ in range(args.requests)]

    rejects = 0
    t0 = time.perf_counter()
    if args.trace == "batch":
        for p in prompts:
            engine.submit(p, deadline_s=args.deadline)
        out = engine.run()
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
        i = 0
        while i < len(arrivals) or engine.active_lanes() or len(engine.router.queue):
            now = time.perf_counter() - t0
            while i < len(arrivals) and arrivals[i] <= now:
                try:
                    engine.submit(prompts[i], deadline_s=args.deadline)
                    i += 1
                except Backpressure:
                    rejects += 1  # shed; retried on the next tick
                    break
            if not engine.step() and i < len(arrivals):
                time.sleep(0.001)
        out = engine.router.results()
    wall = time.perf_counter() - t0

    done = engine.router.done
    ok = [r for r in done.values() if r.status == "ok"]
    timed_out = len(done) - len(ok)
    toks = sum(len(r.out) for r in ok)
    mesh = engine.planes[0].mesh
    print(f"served {len(ok)}/{len(done)} requests "
          f"({timed_out} timeout, {rejects} backpressure-shed), "
          f"{toks} tokens in {wall:.2f}s ({toks / wall:.1f} tok/s, "
          f"planes={args.planes} slots={args.slots} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))})")
    for rid in sorted(out):
        tag = "" if done[rid].status == "ok" else f" [{done[rid].status}]"
        print(f"  req {rid}{tag}: {out[rid][:8]}"
              f"{'...' if len(out[rid]) > 8 else ''}")


if __name__ == "__main__":
    main()
