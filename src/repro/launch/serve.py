"""Serving launcher: replay an arrival trace through the serving stack.

Drives the sharded serving engine (``repro.serve``) on a reduced config —
a plane fleet over the host mesh, batched prefill, per-request deadlines —
and prints what it served.  ``--trace batch`` submits everything up front
(the PR-4 demo behaviour); ``--trace poisson`` replays independent arrivals
at ``--rate`` req/s against the wall clock, so backpressure and deadline
expiry actually fire.

``--block-size`` switches the KV cache to PAGED mode: cache lines come from
a shared pool of fixed-size blocks (``--pool-blocks`` usable blocks; default
= contiguous capacity at block granularity, so size it DOWN to expected live
tokens to realise the memory win) and admission accounts blocks, raising
clean backpressure instead of OOM-ing when the pool is exhausted.

``--role`` picks the process's job in an ELASTIC FLEET (PR 9):

- ``engine`` (default) — everything in one process, as before;
- ``fleet``  — coordinator: spawns ``--planes`` per-host worker processes
  (re-invoking this module with ``--role worker``), assigns requests over
  file mailboxes, tracks liveness via heartbeats, and re-prefills a dead
  worker's in-flight requests on survivors;
- ``worker`` — one serving host: a single-plane engine pumping the file
  mailboxes under ``--fleet-dir`` and beating ``hb_<id>.json``.

  python -m repro.launch.serve --arch qwen1.5-4b --requests 8 --slots 4
  python -m repro.launch.serve --trace poisson --rate 30 --deadline 2.0
  python -m repro.launch.serve --block-size 16 --pool-blocks 24
  python -m repro.launch.serve --role fleet --planes 2 --requests 8
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.lm import model as lm
from repro.serve import (Backpressure, FileMailbox, FleetEngine, ServeConfig,
                         ServeEngine, ServeWorker)


def _serve_config(args: argparse.Namespace) -> ServeConfig:
    # 0 is the argv-safe "off" sentinel for the filters (workers are
    # re-spawned with string argv, so None can't ride through)
    return ServeConfig(slots=args.slots, max_len=args.max_len,
                       max_new_tokens=args.max_new_tokens,
                       temperature=args.temperature,
                       sample_seed=args.sample_seed,
                       top_k=args.top_k or None,
                       top_p=args.top_p or None,
                       block_size=args.block_size or None,
                       pool_blocks=args.pool_blocks or None)


def _prompts(args: argparse.Namespace, vocab: int) -> list:
    rng = np.random.default_rng(args.seed)
    return [rng.integers(0, vocab, size=int(rng.integers(4, 17)))
            for _ in range(args.requests)]


def _report(done: dict, out: dict, wall: float, rejects: int, extra: str) -> None:
    ok = [r for r in done.values() if r.status == "ok"]
    timed_out = sum(1 for r in done.values() if r.status == "timeout")
    truncated = sum(1 for r in done.values() if r.status == "truncated")
    toks = sum(len(r.out) for r in done.values() if r.status != "timeout")
    print(f"served {len(ok)}/{len(done)} requests "
          f"({timed_out} timeout, {truncated} truncated, "
          f"{rejects} backpressure-shed), "
          f"{toks} tokens in {wall:.2f}s ({toks / wall:.1f} tok/s, {extra})")
    for rid in sorted(out):
        tag = "" if done[rid].status == "ok" else f" [{done[rid].status}]"
        print(f"  req {rid}{tag}: {out[rid][:8]}"
              f"{'...' if len(out[rid]) > 8 else ''}")


# ------------------------------------------------------------ single process
def _run_engine(args: argparse.Namespace) -> None:
    arch = get_arch(args.arch)
    if arch.lm is None:
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = arch.smoke_config()
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg, _serve_config(args),
                         planes=args.planes, seed=args.seed)
    prompts = _prompts(args, cfg.vocab)

    rejects = 0
    t0 = time.perf_counter()
    if args.trace == "batch":
        for p in prompts:
            engine.submit(p, deadline_s=args.deadline)
        out = engine.run()
    else:
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
        i = 0
        while i < len(arrivals) or engine.active_lanes() or len(engine.router.queue):
            now = time.perf_counter() - t0
            while i < len(arrivals) and arrivals[i] <= now:
                try:
                    engine.submit(prompts[i], deadline_s=args.deadline)
                    i += 1
                except Backpressure:
                    rejects += 1  # shed; retried on the next tick
                    break
            if not engine.step() and i < len(arrivals):
                time.sleep(0.001)
        out = engine.router.results()
    wall = time.perf_counter() - t0

    mesh = engine.planes[0].mesh
    extra = (f"planes={args.planes} slots={args.slots} "
             f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if args.block_size:
        pool = engine.planes[0].pool
        extra += (f" paged[bs={args.block_size} blocks={pool.num_blocks} "
                  f"cache={engine.planes[0].cache_bytes() / 1e6:.1f}MB]")
    _report(engine.router.done, out, wall, rejects, extra)


# ------------------------------------------------------------------- worker
def _run_worker(args: argparse.Namespace) -> None:
    """One serving host of an elastic fleet (see ``ServeWorker``)."""
    from repro.distributed.transport import FileHeartbeatTransport

    arch = get_arch(args.arch)
    cfg = arch.smoke_config()
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    spool = os.path.join(args.fleet_dir, f"w{args.worker_id}_a{args.attempt}")
    worker = ServeWorker(
        params, cfg, _serve_config(args),
        worker_id=args.worker_id, attempt=args.attempt,
        inbox=FileMailbox(os.path.join(spool, "in")),
        outbox=FileMailbox(os.path.join(spool, "out")),
        heartbeat=FileHeartbeatTransport(os.path.join(args.fleet_dir, "hb")))
    worker.run()


# -------------------------------------------------------------- coordinator
def _run_fleet(args: argparse.Namespace) -> None:
    """Coordinator: spawn per-host workers, drive the fleet, shut it down."""
    from repro.distributed.transport import FileHeartbeatTransport

    arch = get_arch(args.arch)
    if arch.lm is None:
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = arch.smoke_config()
    fleet_dir = args.fleet_dir or tempfile.mkdtemp(prefix="serve-fleet-")
    hb = FileHeartbeatTransport(os.path.join(fleet_dir, "hb"))
    fleet = FleetEngine(_serve_config(args), world=args.planes,
                        hb_timeout=args.hb_timeout,
                        step_feed=lambda: hb.step_feed(0, args.planes))

    procs = []
    for wid in range(args.planes):
        spool = os.path.join(fleet_dir, f"w{wid}_a0")
        fleet.attach(wid, attempt=0,
                     send=FileMailbox(os.path.join(spool, "in")),
                     recv=FileMailbox(os.path.join(spool, "out")))
        argv = [sys.executable, "-m", "repro.launch.serve", "--role", "worker",
                "--fleet-dir", fleet_dir, "--worker-id", str(wid),
                "--arch", args.arch, "--slots", str(args.slots),
                "--max-len", str(args.max_len),
                "--max-new-tokens", str(args.max_new_tokens),
                "--temperature", str(args.temperature),
                "--sample-seed", str(args.sample_seed),
                "--top-k", str(args.top_k),
                "--top-p", str(args.top_p),
                "--block-size", str(args.block_size),
                "--pool-blocks", str(args.pool_blocks),
                "--seed", str(args.seed)]
        procs.append(subprocess.Popen(argv))
    print(f"# fleet: {args.planes} workers, mailboxes under {fleet_dir}")

    prompts = _prompts(args, cfg.vocab)
    t0 = time.perf_counter()
    for p in prompts:
        fleet.submit(p, deadline_s=args.deadline)
    try:
        while fleet.pending():
            fleet.tick()
            time.sleep(0.02)
    finally:
        fleet.stop_workers()
        for p in procs:
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                p.kill()
    wall = time.perf_counter() - t0
    served = {wid: w.served for wid, w in fleet.workers.items()}
    _report(fleet.router.done, fleet.results(), wall, 0,
            f"workers={args.planes} slots/worker={args.slots} "
            f"served-per-worker={served}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--role", choices=("engine", "fleet", "worker"),
                    default="engine",
                    help="engine: in-process fleet (default); fleet: spawn "
                         "per-host worker processes and coordinate them; "
                         "worker: one serving host (spawned by --role fleet)")
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode lanes per plane")
    ap.add_argument("--planes", type=int, default=1,
                    help="inference planes (engine: in-process slot pools; "
                         "fleet: worker PROCESSES, one plane each)")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="default sampling temperature (0 = greedy); draws "
                         "are request-keyed, so output is identical across "
                         "--planes counts for the same seeds")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="default per-request base sampling seed")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k largest logits before sampling "
                         "(0 = off)")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling mass in (0, 1] (0 = off)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged-KV block size in tokens (0 = contiguous "
                         "per-slot cache lines)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="usable blocks in the paged pool (0 = contiguous "
                         "capacity, slots*ceil(max_len/block_size); size it "
                         "to expected LIVE tokens for the memory win)")
    ap.add_argument("--trace", choices=("batch", "poisson"), default="batch",
                    help="batch: submit all up front; poisson: timed arrivals")
    ap.add_argument("--rate", type=float, default=30.0,
                    help="poisson arrival rate, requests/second")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (default: none)")
    ap.add_argument("--fleet-dir", default=None,
                    help="shared mailbox/heartbeat dir for --role "
                         "fleet/worker (fleet default: a fresh tempdir)")
    ap.add_argument("--worker-id", type=int, default=0)
    ap.add_argument("--attempt", type=int, default=0,
                    help="worker mailbox incarnation (bumped on relaunch)")
    ap.add_argument("--hb-timeout", type=float, default=10.0,
                    help="seconds of beat silence before a worker is "
                         "declared dead and its work re-prefilled")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.role == "worker":
        if args.fleet_dir is None:
            raise SystemExit("--role worker requires --fleet-dir")
        _run_worker(args)
    elif args.role == "fleet":
        _run_fleet(args)
    else:
        _run_engine(args)


if __name__ == "__main__":
    main()
