"""Serving launcher: batched continuous-batching demo on a reduced config.

  python -m repro.launch.serve --arch qwen1.5-4b --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.lm import model as lm
from repro.train.serve import ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.lm is None:
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = arch.smoke_config()
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    srv = Server(params, cfg,
                 ServeConfig(slots=args.slots, max_len=args.max_len,
                             max_new_tokens=args.max_new_tokens,
                             temperature=args.temperature),
                 seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 17))
        srv.submit(rng.integers(0, cfg.vocab, size=plen))
    out = srv.run()
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, slots={args.slots})")
    for rid in sorted(out):
        print(f"  req {rid}: {out[rid][:8]}{'...' if len(out[rid]) > 8 else ''}")


if __name__ == "__main__":
    main()
