"""End-to-end training launcher (real compute, host-scale).

Runs the paper's full workflow — synthetic data gen → index-batching
preprocessing → GPU(accelerator)-index-batching placement → distributed-index-
batching training with global shuffling — on whatever devices exist.  On the
CPU container this trains the reduced configs for real; on a TPU slice the
same entry point trains the full ones.

Every arch runs through `repro.pipeline` (placement-aware: the sampler,
series sharding and fused gather/step come from one definition).  LM archs
use the pipeline's `lm` gather (token-stream windows, y = shift(x)).

Multi-host: call with `--init-distributed` under a jax.distributed-capable
launcher (env-configured coordinator) and each process trains from its own
per-rank index feed (`DataPlane.feed(jax.process_index(), epoch)`) — no host
ever materialises the global index grid.  `--elastic` attaches the
heartbeat/re-mesh policy so worker loss shrinks the data axis and resumes
from the latest checkpoint instead of killing the run.

Examples:
  python -m repro.launch.train --arch pgt-dcrnn-pems-all-la --nodes 200 \
      --entries 2000 --epochs 3 --batch 32
  python -m repro.launch.train --arch qwen1.5-4b --smoke --steps 100
  python -m repro.launch.train --arch dcrnn-pems --placement partitioned \
      --elastic --ckpt-dir /tmp/ck ...
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import IndexDataset, Placement, WindowSpec
from repro.data import (gaussian_adjacency, make_token_stream, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import latest_step
from repro.launch.mesh import make_host_mesh
from repro.models import dcrnn, pgt_dcrnn
from repro.models.lm import model as lm
from repro.optim import AdamConfig, warmup_cosine
from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
from repro.train.loop import TrainLoopConfig


def _train_stgnn(arch, args, adam, sched, loop: TrainLoopConfig):
    """Full pipeline path: placement-aware sampler/sharding/fused step."""
    mcfg = arch.model
    if args.nodes:
        mcfg = dataclasses.replace(mcfg, num_nodes=args.nodes)
    coords = random_sensor_coords(mcfg.num_nodes, seed=args.seed)
    adj = gaussian_adjacency(coords)
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    series = make_traffic_series(args.entries, mcfg.num_nodes,
                                 mcfg.in_features, seed=args.seed, adjacency=adj)
    spec = WindowSpec(horizon=mcfg.horizon, input_len=mcfg.input_len)

    mod = dcrnn if isinstance(mcfg, dcrnn.DCRNNConfig) else pgt_dcrnn
    params = mod.init(jax.random.PRNGKey(args.seed), mcfg)

    def loss_fn(p, x, y):
        return mod.loss_fn(p, mcfg, supports, x, y), {}

    mesh = make_host_mesh()
    # --batch is the GLOBAL batch; the pipeline takes a per-rank size
    from repro.core.distributed import dp_size
    dp = max(dp_size(mesh), 1)
    if args.batch % dp:
        raise SystemExit(f"--batch {args.batch} not divisible by "
                         f"data-parallel size {dp}")
    pipe = build_pipeline(
        series, spec, mesh, loss_fn, params,
        PipelineConfig(batch_per_rank=args.batch // dp,
                       placement=Placement(args.placement),
                       gather=args.gather, halo=not args.no_halo,
                       seed=args.seed, adam=adam,
                       schedule=sched, loop=loop),
        elastic=_elastic_config(args))
    if args.resume and loop.ckpt_dir:
        step = latest_step(loop.ckpt_dir)
        if step is not None:
            print(f"resuming from step {step}")
    return pipe.fit(resume=args.resume)


def _train_lm(arch, args, adam, sched, loop: TrainLoopConfig):
    """Token-stream windows (nodes==1 case) through the same pipeline: the
    ``lm`` gather entry reconstructs (tokens, shifted labels) on-device."""
    cfg = arch.smoke_config() if args.smoke else arch.lm
    stream = np.asarray(make_token_stream(args.entries, cfg.vocab, seed=args.seed))
    spec = WindowSpec(horizon=1, input_len=args.seq_len)
    ds = IndexDataset.from_raw(stream, spec, scale_feature=None)
    ds = dataclasses.replace(ds, series=stream)  # tokens: no standardisation
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)

    def loss_fn(p, toks, labels):
        return lm.loss_fn(p, cfg, toks, labels)

    mesh = make_host_mesh()
    from repro.core.distributed import dp_size
    dp = max(dp_size(mesh), 1)
    if args.batch % dp:
        raise SystemExit(f"--batch {args.batch} not divisible by "
                         f"data-parallel size {dp}")
    # --shuffle selects the sampler through the placement contract: global
    # draws over a replicated stream, or the fixed count-split partitions
    # (local batch shuffling) over a time-sharded stream.
    placement = (Placement.REPLICATED if args.shuffle == "global"
                 else Placement.PARTITIONED)
    pipe = build_pipeline(
        stream, spec, mesh, loss_fn, params,
        PipelineConfig(batch_per_rank=args.batch // dp, placement=placement,
                       partition="count", gather="lm", seed=args.seed,
                       adam=adam, schedule=sched, loop=loop),
        dataset=ds, elastic=_elastic_config(args))
    if args.resume and loop.ckpt_dir:
        step = latest_step(loop.ckpt_dir)
        if step is not None:
            print(f"resuming from step {step}")
    return pipe.fit(resume=args.resume, eval_fn=None)


def _elastic_config(args) -> ElasticConfig | None:
    return ElasticConfig() if args.elastic else None


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--entries", type=int, default=2_000)
    ap.add_argument("--nodes", type=int, default=0, help="override graph nodes")
    ap.add_argument("--seq-len", type=int, default=128, help="LM window")
    ap.add_argument("--batch", type=int, default=32, help="global batch")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0, help="cap steps (0 = epochs)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="reduced LM config")
    ap.add_argument("--placement", default="replicated",
                    choices=[p.value for p in Placement],
                    help="ST-GNN dataset placement (pipeline)")
    ap.add_argument("--gather", default="slice",
                    choices=["slice", "take", "fused", "pallas"])
    ap.add_argument("--shuffle", default="global", choices=["global", "local-batch"],
                    help="LM sampler (ST-GNN samplers follow --placement)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-halo", action="store_true",
                    help="PARTITIONED: keep windows strictly interior to each "
                         "rank's series shard (communication-free; see "
                         "launch/dryrun.py --halo-evidence)")
    ap.add_argument("--elastic", action="store_true",
                    help="attach the heartbeat->plan_remesh->shrink-and-"
                         "resume policy (needs --ckpt-dir).  NOTE: the "
                         "default heartbeat transport simulates an "
                         "all-healthy fleet; detecting real worker loss "
                         "needs a collector wired to ElasticConfig."
                         "step_feed (see tests/test_elastic_engine.py)")
    ap.add_argument("--init-distributed", action="store_true",
                    help="call jax.distributed.initialize() (env-configured "
                         "coordinator); each process then trains from its "
                         "own per-rank feed via jax.process_index()")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()
    if args.init_distributed and args.elastic:
        # The elastic shrink path re-materialises the series on the host
        # (DataPlane.remesh), which needs every shard addressable — true on
        # one process, not on a real fleet.  See ROADMAP (multi-host elastic).
        raise SystemExit("--elastic with --init-distributed is not supported "
                         "yet: the shrink path restores on a single host")
    if args.init_distributed:
        jax.distributed.initialize()
        print(f"jax.distributed: process {jax.process_index()} of "
              f"{jax.process_count()} (per-rank feed selection active)")

    arch = get_arch(args.arch)
    adam = AdamConfig(lr=args.lr)
    total = max(args.steps, 100)
    sched = lambda s: warmup_cosine(s, base_lr=args.lr, warmup_steps=total // 10,
                                    total_steps=total)
    loop = TrainLoopConfig(epochs=args.epochs, log_every=10,
                           ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)

    t0 = time.perf_counter()
    if arch.family == "stgnn":
        state, history = _train_stgnn(arch, args, adam, sched, loop)
    else:
        state, history = _train_lm(arch, args, adam, sched, loop)
    wall = time.perf_counter() - t0
    final = [h for h in history if "loss" in h]
    if final:
        print(f"done: {len(final)} logs, wall {wall:.1f}s, "
              f"loss {final[0]['loss']:.4f} -> {final[-1]['loss']:.4f}")
    else:
        print(f"done: nothing to train (resumed past requested epochs), "
              f"wall {wall:.1f}s")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
