"""End-to-end training launcher (real compute, host-scale).

Runs the paper's full workflow — synthetic data gen → index-batching
preprocessing → GPU(accelerator)-index-batching placement → distributed-index-
batching training with global shuffling — on whatever devices exist.  On the
CPU container this trains the reduced configs for real; on a TPU slice the
same entry point trains the full ones (mesh picked by ``--mesh``).

Examples:
  python -m repro.launch.train --arch pgt-dcrnn-pems-all-la --nodes 200 \
      --entries 2000 --epochs 3 --batch 32
  python -m repro.launch.train --arch qwen1.5-4b --smoke --steps 100
  python -m repro.launch.train --arch dcrnn-pems --placement partitioned ...
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import (GlobalShuffleSampler, IndexDataset, LocalBatchShuffleSampler,
                        ShardInfo, WindowSpec, gather_batch)
from repro.data import (gaussian_adjacency, make_token_stream, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import Checkpointer, latest_step, restore
from repro.models import a3tgcn, dcrnn, pgt_dcrnn
from repro.models.lm import model as lm
from repro.optim import AdamConfig, warmup_cosine
from repro.train.loop import TrainLoopConfig, init_train_state, make_train_step, run_training


def _stgnn_setup(arch, args):
    mcfg = arch.model
    if args.nodes:
        mcfg = dataclasses.replace(mcfg, num_nodes=args.nodes)
    coords = random_sensor_coords(mcfg.num_nodes, seed=args.seed)
    adj = gaussian_adjacency(coords)
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    series = make_traffic_series(args.entries, mcfg.num_nodes,
                                 mcfg.in_features, seed=args.seed, adjacency=adj)
    spec = WindowSpec(horizon=mcfg.horizon, input_len=mcfg.input_len)
    ds = IndexDataset.from_raw(series, spec).to_device()

    mod = dcrnn if isinstance(mcfg, dcrnn.DCRNNConfig) else pgt_dcrnn
    params = mod.init(jax.random.PRNGKey(args.seed), mcfg)

    def loss_fn(p, starts):
        x, y = gather_batch(ds.series, starts, input_len=mcfg.input_len,
                            horizon=mcfg.horizon)
        return mod.loss_fn(p, mcfg, supports, x, y), {}

    def eval_fn(state):
        ids = ds.val_windows[: args.batch * 4]
        losses = []
        for i in range(0, len(ids) - args.batch + 1, args.batch):
            l, _ = loss_fn(state["params"], jnp.asarray(ds.starts[ids[i:i + args.batch]]))
            losses.append(float(l))
        return {"val_mae": float(np.mean(losses))} if losses else {}

    return params, loss_fn, eval_fn, ds


def _lm_setup(arch, args):
    cfg = arch.smoke_config() if args.smoke else arch.lm
    stream = jnp.asarray(make_token_stream(args.entries, cfg.vocab, seed=args.seed))
    spec = WindowSpec(horizon=1, input_len=args.seq_len)
    ds = IndexDataset.from_raw(np.asarray(stream), spec, scale_feature=None)
    ds = dataclasses.replace(ds, series=stream)  # tokens: no standardisation
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)

    from repro.core import lm_window_batch

    def loss_fn(p, starts):
        toks, labels = lm_window_batch(ds.series, starts, seq_len=args.seq_len)
        l, metrics = lm.loss_fn(p, cfg, toks, labels)
        return l, metrics

    return params, loss_fn, None, ds


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--entries", type=int, default=2_000)
    ap.add_argument("--nodes", type=int, default=0, help="override graph nodes")
    ap.add_argument("--seq-len", type=int, default=128, help="LM window")
    ap.add_argument("--batch", type=int, default=32, help="global batch")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0, help="cap steps (0 = epochs)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="reduced LM config")
    ap.add_argument("--shuffle", default="global", choices=["global", "local-batch"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family == "stgnn":
        params, loss_fn, eval_fn, ds = _stgnn_setup(arch, args)
    else:
        params, loss_fn, eval_fn, ds = _lm_setup(arch, args)

    adam = AdamConfig(lr=args.lr)
    total = max(args.steps, 100)
    sched = lambda s: warmup_cosine(s, base_lr=args.lr, warmup_steps=total // 10,
                                    total_steps=total)
    train_step = make_train_step(loss_fn, adam, sched)
    state = init_train_state(params, adam)

    shard = ShardInfo(0, 1)
    sampler_cls = (GlobalShuffleSampler if args.shuffle == "global"
                   else LocalBatchShuffleSampler)
    sampler = sampler_cls(ds.train_windows, args.batch, shard, seed=args.seed)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_epoch = start_step = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start_step = restore(args.ckpt_dir, state)
        start_epoch = start_step // sampler.steps_per_epoch
        print(f"resumed from step {start_step} (epoch {start_epoch})")

    loop = TrainLoopConfig(epochs=args.epochs, log_every=10,
                           ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)
    t0 = time.perf_counter()
    state, history = run_training(
        state=state, train_step=train_step, sampler=sampler,
        batch_of_starts=lambda s: jnp.asarray(ds.starts[s]),
        loop=loop, eval_fn=eval_fn, checkpointer=ckpt,
        start_epoch=start_epoch, start_step=start_step)
    wall = time.perf_counter() - t0
    final = [h for h in history if "loss" in h]
    print(f"done: {len(final)} logs, wall {wall:.1f}s, "
          f"loss {final[0]['loss']:.4f} -> {final[-1]['loss']:.4f}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
