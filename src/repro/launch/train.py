"""End-to-end training launcher (real compute, host-scale).

Runs the paper's full workflow — synthetic data gen → index-batching
preprocessing → GPU(accelerator)-index-batching placement → distributed-index-
batching training with global shuffling — on whatever devices exist.  On the
CPU container this trains the reduced configs for real; on a TPU slice the
same entry point trains the full ones.

Every arch runs through `repro.pipeline` (placement-aware: the sampler,
series sharding and fused gather/step come from one definition).  LM archs
use the pipeline's `lm` gather (token-stream windows, y = shift(x)).

Multi-host: call with `--init-distributed` under a jax.distributed-capable
launcher (env-configured coordinator) and each process trains from its own
per-rank index feed (`DataPlane.feed(jax.process_index(), epoch)`) — no host
ever materialises the global index grid.  Epoch-end evaluation rides the
same plane: each process scores only its own rank-block of the val pool
(`DataPlane.eval_feed`), `--eval-every` sets the cadence, and the eval rows
land in the crash-durable `--history-out` sink.  `--elastic` attaches the
heartbeat/re-mesh policy so worker loss shrinks the data axis and resumes
from the latest checkpoint instead of killing the run; when the worker
returns, the inverse GROW plan re-admits it with the per-worker batch scaled
back down.  `--heartbeat file:<dir>|tcp://a:p[,b:p,...]` replaces the
simulated all-healthy feed with a REAL transport: every process emits its
ranks' beats each step, and every collector-capable process runs the monitor
over them — but only the LEADER (lowest live rank, see
`repro.distributed.leader`) acts on a verdict.  A `tcp://` spec may be an
ordered failover list: address k is served by process k (beats peer-mirror
between collectors, emitters fail over down the list), so when host 0 dies
the successor's collector is already primed and it takes over plan emission,
checkpoint writing and the durable history sink.

Single-process runs re-mesh in place.  A real fleet cannot (a dead peer's
shards are gone and its collectives would hang), so under
`--init-distributed` use `--elastic-remesh relaunch`: on a re-mesh plan the
process checkpoints, writes the plan to `--plan-out`, and exits with code 75
(EX_TEMPFAIL) — the external launcher (e.g. tests/multihost.py's driver)
tears the gang down and relaunches into the planned topology with the SAME
--batch (the global batch is preserved; per-rank batches re-divide).

Examples:
  python -m repro.launch.train --arch pgt-dcrnn-pems-all-la --nodes 200 \
      --entries 2000 --epochs 3 --batch 32
  python -m repro.launch.train --arch qwen1.5-4b --smoke --steps 100
  python -m repro.launch.train --arch dcrnn-pems --placement partitioned \
      --elastic --ckpt-dir /tmp/ck ...
  python -m repro.launch.train --arch dcrnn-pems --init-distributed \
      --elastic --elastic-remesh relaunch --heartbeat file:/shared/hb \
      --ckpt-dir /shared/ck --plan-out /shared/plan.json ...
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import IndexDataset, Placement, WindowSpec
from repro.data import (gaussian_adjacency, make_token_stream, make_traffic_series,
                        random_sensor_coords, transition_matrices)
from repro.distributed import (LeaderHistorySink, LeaderTracker, latest_step,
                               make_transport)
from repro.distributed.transport import tcp_addresses
from repro.launch.mesh import make_host_mesh
from repro.models import dcrnn, pgt_dcrnn
from repro.models.lm import model as lm
from repro.optim import AdamConfig, warmup_cosine
from repro.pipeline import ElasticConfig, PipelineConfig, build_pipeline
from repro.train.loop import RestartSignal, TrainLoopConfig


def _train_stgnn(arch, args, adam, sched, loop: TrainLoopConfig,
                 sink: list | None = None):
    """Full pipeline path: placement-aware sampler/sharding/fused step."""
    mcfg = arch.model
    if args.nodes:
        mcfg = dataclasses.replace(mcfg, num_nodes=args.nodes)
    coords = random_sensor_coords(mcfg.num_nodes, seed=args.seed)
    adj = gaussian_adjacency(coords)
    supports = tuple(jnp.asarray(s) for s in transition_matrices(adj))
    series = make_traffic_series(args.entries, mcfg.num_nodes,
                                 mcfg.in_features, seed=args.seed, adjacency=adj)
    spec = WindowSpec(horizon=mcfg.horizon, input_len=mcfg.input_len)

    mod = dcrnn if isinstance(mcfg, dcrnn.DCRNNConfig) else pgt_dcrnn
    params = mod.init(jax.random.PRNGKey(args.seed), mcfg)

    def loss_fn(p, x, y):
        return mod.loss_fn(p, mcfg, supports, x, y), {}

    mesh = make_host_mesh()
    # --batch is the GLOBAL batch; the pipeline takes a per-rank size
    from repro.core.distributed import dp_size
    dp = max(dp_size(mesh), 1)
    if args.batch % dp:
        raise SystemExit(f"--batch {args.batch} not divisible by "
                         f"data-parallel size {dp}")
    pipe = build_pipeline(
        series, spec, mesh, loss_fn, params,
        PipelineConfig(batch_per_rank=args.batch // dp,
                       placement=Placement(args.placement),
                       gather=args.gather, halo=not args.no_halo,
                       seed=args.seed, adam=adam,
                       schedule=sched, loop=loop),
        elastic=_elastic_config(args))
    if args.resume and loop.ckpt_dir:
        step = latest_step(loop.ckpt_dir)
        if step is not None:
            print(f"resuming from step {step}")
    transport = _wire_heartbeat(pipe, args, sink)
    try:
        return pipe.fit(resume=args.resume, history_sink=sink)
    finally:
        if transport is not None:
            transport.close()


def _train_lm(arch, args, adam, sched, loop: TrainLoopConfig,
              sink: list | None = None):
    """Token-stream windows (nodes==1 case) through the same pipeline: the
    ``lm`` gather entry reconstructs (tokens, shifted labels) on-device."""
    cfg = arch.smoke_config() if args.smoke else arch.lm
    stream = np.asarray(make_token_stream(args.entries, cfg.vocab, seed=args.seed))
    spec = WindowSpec(horizon=1, input_len=args.seq_len)
    ds = IndexDataset.from_raw(stream, spec, scale_feature=None)
    ds = dataclasses.replace(ds, series=stream)  # tokens: no standardisation
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)

    def loss_fn(p, toks, labels):
        return lm.loss_fn(p, cfg, toks, labels)

    mesh = make_host_mesh()
    from repro.core.distributed import dp_size
    dp = max(dp_size(mesh), 1)
    if args.batch % dp:
        raise SystemExit(f"--batch {args.batch} not divisible by "
                         f"data-parallel size {dp}")
    # --shuffle selects the sampler through the placement contract: global
    # draws over a replicated stream, or the fixed count-split partitions
    # (local batch shuffling) over a time-sharded stream.
    placement = (Placement.REPLICATED if args.shuffle == "global"
                 else Placement.PARTITIONED)
    pipe = build_pipeline(
        stream, spec, mesh, loss_fn, params,
        PipelineConfig(batch_per_rank=args.batch // dp, placement=placement,
                       partition="count", gather="lm", seed=args.seed,
                       adam=adam, schedule=sched, loop=loop),
        dataset=ds, elastic=_elastic_config(args))
    if args.resume and loop.ckpt_dir:
        step = latest_step(loop.ckpt_dir)
        if step is not None:
            print(f"resuming from step {step}")

    # Held-out LM evaluation through the SAME distributed eval feeds the
    # ST-GNN path rides (ISSUE 5 satellite, ex-ROADMAP item): the `lm`
    # gather reconstructs (tokens, shifted labels) for the val pool's
    # window ids, Engine.evaluate window-weights full chunks + the ragged
    # tail, and the launcher reports both the mean token cross-entropy and
    # its perplexity.  Same epoch-end cadence knob (--eval-every) as the
    # ST-GNN path; bit-identical across process counts for the same
    # reasons (every process derives the same chunk plan).
    if len(ds.val_windows) > 0:
        def eval_fn(st):
            val_loss = pipe.evaluate(st["params"], split="val")
            return {"val_loss": val_loss,
                    "val_ppl": float(np.exp(np.minimum(val_loss, 30.0)))}
    else:
        eval_fn = None
    transport = _wire_heartbeat(pipe, args, sink)
    try:
        return pipe.fit(resume=args.resume, eval_fn=eval_fn,
                        history_sink=sink)
    finally:
        if transport is not None:
            transport.close()


#: Exit code for "re-mesh requested" in relaunch mode (EX_TEMPFAIL: the run
#: is not broken, it wants to be relaunched into the planned topology).
EX_REMESH = 75


def _elastic_config(args) -> ElasticConfig | None:
    if not args.elastic:
        return None
    return ElasticConfig(heartbeat_timeout=args.heartbeat_timeout,
                         remesh=args.elastic_remesh,
                         target_world=args.target_world or None)


def _wire_heartbeat(pipe, args, sink=None):
    """Attach a real transport to an elastic pipeline: every process emits
    beats for the feed ranks it owns; every process that CAN collect polls
    them, but only the current LEADER — lowest live rank, tracked by a
    ``LeaderTracker`` over the same beat stream — acts on a verdict.  One
    decider at a time (no split-brain races on plans or checkpoint
    coordinates), yet the decider role survives the death of process 0:
    the successor's monitor state is already primed when it takes over.
    Returns the transport (caller closes it) or None."""
    if not args.heartbeat or pipe.elastic is None:
        return None
    idx = jax.process_index()
    addrs = tcp_addresses(args.heartbeat)
    if addrs is not None:
        # Address k of the failover list is served by process k; processes
        # beyond the list emit only.  The list length therefore bounds the
        # succession depth — ship one address per host that may ever lead.
        serve = idx < len(addrs)
        transport = make_transport(args.heartbeat, serve=serve,
                                   serve_index=idx)
    else:
        serve = True  # the file transport is symmetric: every process polls
        transport = make_transport(args.heartbeat)

    def emitter(step: int) -> None:
        # Re-read the topology every step: an in-process re-mesh changes the
        # world mid-fit, and beating for a rank outside the current world
        # would read as a returned worker.
        ranks = pipe.dataplane.process_ranks
        for r in (ranks if ranks is not None else range(pipe.world)):
            transport.emit(r, step)

    tracker = None
    if serve:
        # Only collector-capable processes can become the leader (a
        # non-polling process would decide plans off the simulated
        # all-healthy feed).  The rest keep leader=None, i.e. the fixed
        # process-0 gate — false for them by construction — and never
        # standby-buffer history rows they could never flush.
        tracker = LeaderTracker(pipe.world,
                                timeout=args.heartbeat_timeout)
        ranks = pipe.dataplane.process_ranks
        tracker.bind(ranks if ranks is not None else range(pipe.world))
        if isinstance(sink, LeaderHistorySink):
            sink.bind(tracker.is_leader, buffer_standby=True)
    pipe.elastic = dataclasses.replace(
        pipe.elastic, emitter=emitter, leader=tracker,
        step_feed=(transport.step_feed
                   if serve and hasattr(transport, "step_feed")
                   else pipe.elastic.step_feed))
    return transport


def _write_plan(args, sig) -> None:
    """Relaunch mode: persist the re-mesh plan for the external launcher.

    The LEADER only (the engine stamps ``sig.leader`` before re-raising:
    it is the decider and the checkpoint writer, so its (epoch, step)
    coordinates are the ones that match the durable checkpoint — process 0
    classically, the succession winner after a leader death), written
    atomically so the launcher can never read a torn plan."""
    if not getattr(sig, "leader", jax.process_index() == 0):
        return
    plan = sig.plan
    out = {
        "kind": plan.kind if plan is not None else "unknown",
        "reason": str(plan.reason) if plan is not None else str(sig),
        "dropped_workers": list(plan.dropped_workers) if plan else [],
        "readmitted_workers": list(plan.readmitted_workers) if plan else [],
        "mesh_shape": list(plan.mesh_shape) if plan else [],
        "decided_by": getattr(plan, "decided_by", None) if plan else None,
        "epoch": sig.epoch, "step": sig.step,
    }
    payload = json.dumps(out, indent=1)
    if args.plan_out:
        import os
        import tempfile
        fd, tmp = tempfile.mkstemp(
            prefix=".plan-", dir=os.path.dirname(args.plan_out) or ".")
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, args.plan_out)
    print(f"re-mesh requested (exit {EX_REMESH}): {payload}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--entries", type=int, default=2_000)
    ap.add_argument("--nodes", type=int, default=0, help="override graph nodes")
    ap.add_argument("--seq-len", type=int, default=128, help="LM window")
    ap.add_argument("--batch", type=int, default=32, help="global batch")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0, help="cap steps (0 = epochs)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="reduced LM config")
    ap.add_argument("--placement", default="replicated",
                    choices=[p.value for p in Placement],
                    help="ST-GNN dataset placement (pipeline)")
    ap.add_argument("--gather", default="slice",
                    choices=["slice", "take", "fused", "pallas", "auto"],
                    help="window-gather lowering fused into the train step; "
                         "'auto' dispatches per (backend, shape-bucket) "
                         "through the measured tuning cache (see --autotune)")
    ap.add_argument("--autotune", default="load",
                    choices=["off", "load", "tune"],
                    help="kernel autotune policy for backend='auto' dispatch: "
                         "'off' = static per-backend defaults, 'load' = use "
                         "results/TUNING_<backend>.json when a verdict covers "
                         "the shape bucket (never measures), 'tune' = measure "
                         "candidates on a cache miss and persist the verdict")
    ap.add_argument("--tuning-dir", default="results",
                    help="directory holding TUNING_<backend>.json")
    ap.add_argument("--shuffle", default="global", choices=["global", "local-batch"],
                    help="LM sampler (ST-GNN samplers follow --placement)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=1,
                    help="epoch-end eval cadence: score the val split through "
                         "the distributed eval feeds after every N-th epoch "
                         "(0 disables eval).  Works under --init-distributed: "
                         "each process scores only its own rank-block of the "
                         "eval pool and the window-weighted metric is "
                         "bit-identical to the single-host value")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="async feed pipeline: materialize feed rows this "
                         "many chunks ahead on a background thread (0 = the "
                         "synchronous pull-per-step path).  At --staleness 0 "
                         "the pipelined run is bit-identical to synchronous")
    ap.add_argument("--staleness", type=int, default=0,
                    help="bounded-stale transfer overlap: 0 keeps lockstep "
                         "semantics (host->device transfer at consume, on "
                         "the step thread — provably bit-identical); s >= 1 "
                         "lets the transfer for step k+s run on a background "
                         "thread while step k computes (values unchanged — "
                         "feeds are pure in (seed, epoch, rank) — only the "
                         "overlap changes)")
    ap.add_argument("--prefetch-chunk", type=int, default=8,
                    help="feed rows per prefetched block")
    ap.add_argument("--no-halo", action="store_true",
                    help="PARTITIONED: keep windows strictly interior to each "
                         "rank's series shard (communication-free; see "
                         "launch/dryrun.py --halo-evidence)")
    ap.add_argument("--elastic", action="store_true",
                    help="attach the heartbeat->plan_remesh->re-mesh-and-"
                         "resume policy (needs --ckpt-dir).  Without "
                         "--heartbeat the transport simulates an all-healthy "
                         "fleet; pass a real transport to detect actual "
                         "worker loss and return")
    ap.add_argument("--heartbeat", default=None,
                    help="real heartbeat transport: file:<shared-dir> "
                         "(same-host multi-process; symmetric — every "
                         "process polls) or tcp://a:p[,b:p,...] — an "
                         "ordered FAILOVER list in leader-succession "
                         "order: process k binds address k and collectors "
                         "peer-mirror accepted beats, emitters fail over "
                         "down the list, so the heartbeat decider survives "
                         "the death of host 0 (list length = succession "
                         "depth)")
    ap.add_argument("--heartbeat-timeout", type=float, default=60.0)
    ap.add_argument("--elastic-remesh", default="inprocess",
                    choices=["inprocess", "relaunch"],
                    help="who executes a re-mesh plan: this process "
                         "(single-host only) or an external launcher — the "
                         "process then checkpoints, writes --plan-out and "
                         f"exits {EX_REMESH}")
    ap.add_argument("--target-world", type=int, default=0,
                    help="grow ceiling: re-admit returned workers up to this "
                         "world size.  0 = the world THIS process started "
                         "with — after a relaunch that is the SHRUNK world, "
                         "so a relaunching controller must pass the original "
                         "fleet size explicitly or the fleet never grows "
                         "back (see tests/multihost.py)")
    ap.add_argument("--plan-out", default=None,
                    help="relaunch mode: path for the re-mesh plan JSON")
    ap.add_argument("--init-distributed", action="store_true",
                    help="call jax.distributed.initialize() (env-configured "
                         "coordinator); each process then trains from its "
                         "own per-rank feed via jax.process_index()")
    ap.add_argument("--history-out", default=None,
                    help="crash-durable history: every logged row (train "
                         "steps AND epoch-end eval rows) is appended to this "
                         "file as one JSON object per line and fsynced as it "
                         "lands, so a crash or exit-75 relaunch loses "
                         "nothing; duplicate (epoch, step) rows from a "
                         "relaunch re-running an epoch tail are suppressed "
                         "(idempotent resume).  Process 0 writes it")
    args = ap.parse_args()
    # Set the autotune policy before anything builds a pipeline: 'auto'
    # dispatch resolves per call, so this only configures WHERE verdicts come
    # from — it never touches the backend (jax.distributed.initialize() below
    # must still run first against an untouched client).
    from repro.kernels.autotune import set_autotune
    set_autotune(mode=args.autotune, cache_dir=args.tuning_dir)
    if args.heartbeat and not args.elastic:
        # Silently ignoring the transport would leave the operator believing
        # health monitoring is active when nothing emits or collects beats.
        raise SystemExit("--heartbeat requires --elastic: the transport only "
                         "feeds the elastic heartbeat monitor")
    if args.elastic and args.elastic_remesh == "relaunch" \
            and not args.target_world:
        print("warning: --elastic-remesh relaunch without --target-world — "
              "growth is capped at this process's starting world; a "
              "relaunching controller should pass the original fleet size")
    if args.init_distributed and args.elastic \
            and args.elastic_remesh != "relaunch":
        # The in-process re-mesh path re-materialises the series on the host
        # (DataPlane.remesh), which needs every shard addressable — true on
        # one process, not on a real fleet.
        raise SystemExit("--elastic with --init-distributed needs "
                         "--elastic-remesh relaunch: a fleet re-meshes by "
                         "relaunching into the planned topology")
    if args.init_distributed:
        # CPU fleets need gloo for cross-process collectives: the default
        # CPU client ships NO collectives implementation, so psums would
        # fail outright once the mesh spans processes.  Must be set before
        # the backend is first touched; harmless on accelerator fleets.
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize()
        print(f"jax.distributed: process {jax.process_index()} of "
              f"{jax.process_count()} (per-rank feed selection active)")

    arch = get_arch(args.arch)
    adam = AdamConfig(lr=args.lr)
    total = max(args.steps, 100)
    sched = lambda s: warmup_cosine(s, base_lr=args.lr, warmup_steps=total // 10,
                                    total_steps=total)
    loop = TrainLoopConfig(epochs=args.epochs, log_every=10,
                           ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                           eval_every=args.eval_every,
                           prefetch_depth=args.prefetch_depth,
                           staleness=args.staleness,
                           prefetch_chunk=args.prefetch_chunk)

    t0 = time.perf_counter()
    # The sink mirrors every logged row AS IT LANDS, so the rows survive the
    # crash paths too — a peer death surfaces as a plain collective error,
    # not a RestartSignal.  With --history-out the sink is crash-durable
    # (JSONL, fsynced per row) and idempotent across exit-75 relaunches, so
    # there is nothing to dump on any exit path: the file is always current.
    # EVERY process carries the leader-gated sink: the current leader's rows
    # land durably, standbys buffer — so history-writer duty survives the
    # leader's death.  Buffering starts OFF (without a succession tracker a
    # non-leader could never flush, so holding every row would be pure
    # waste); _wire_heartbeat turns it on when it binds a LeaderTracker to
    # a collector-capable process.
    sink: list | LeaderHistorySink = \
        (LeaderHistorySink(args.history_out,
                           lambda: jax.process_index() == 0,
                           buffer_standby=False)
         if args.history_out else [])
    try:
        if arch.family == "stgnn":
            state, history = _train_stgnn(arch, args, adam, sched, loop, sink)
        else:
            state, history = _train_lm(arch, args, adam, sched, loop, sink)
    except RestartSignal as sig:
        # relaunch-mode elastic: the state is already checkpointed with its
        # (epoch, done_in_epoch) coordinates; hand the plan to the launcher.
        _write_plan(args, sig)
        raise SystemExit(EX_REMESH)
    wall = time.perf_counter() - t0
    final = [h for h in history if "loss" in h]
    if final:
        print(f"done: {len(final)} logs, wall {wall:.1f}s, "
              f"loss {final[0]['loss']:.4f} -> {final[-1]['loss']:.4f}")
    else:
        print(f"done: nothing to train (resumed past requested epochs), "
              f"wall {wall:.1f}s")
    if isinstance(sink, LeaderHistorySink):
        sink.close()


if __name__ == "__main__":
    main()
