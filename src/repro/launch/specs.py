"""Step builders + ShapeDtypeStruct input specs for every (arch × shape) cell.

``build_cell(arch_id, shape_name, mesh, ...)`` returns a ``CellProgram``: the
step function to lower, its ShapeDtypeStruct args (weak-type-correct, no
allocation) and the in/out shardings — everything ``dryrun.py`` needs to
``.lower().compile()`` and everything ``train.py`` needs to run for real.

The paper's technique is baked into the train steps: the jitted program takes
the RESIDENT series/stream plus int32 window starts and reconstructs the
batch on-device (index-batching).  ``placement`` selects the paper's three
distributed designs: replicated (distributed-index-batching), partitioned
(generalized-…, local windows), ondemand (baseline DDP: partitioned series,
global windows → data collectives).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchSpec, ShapeCell
from repro.core.batching import gather_batch_fused, lm_window_batch
from repro.models import a3tgcn, dcrnn, pgt_dcrnn, stllm
from repro.models.lm import model as lm
from repro.optim import AdamConfig, apply_updates
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes

# Dry-run token-stream length (resident series for LM index-batching).
STREAM_LEN = 1 << 22  # 4M tokens, 16 MiB int32 — replicated everywhere


@dataclasses.dataclass
class CellProgram:
    name: str
    kind: str  # train | prefill | decode
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _adam_for(arch: ArchSpec) -> AdamConfig:
    # bf16 optimizer state for the very large archs (grok) — see DESIGN.md
    state_dtype = "bfloat16" if arch.lm is not None and arch.lm.param_count() > 1e11 else "float32"
    return AdamConfig(lr=3e-4, weight_decay=0.1, state_dtype=state_dtype)


def _opt_shapes(params_shape, adam: AdamConfig):
    dt = jnp.dtype(adam.state_dtype)
    like = lambda p: _sds(p.shape, dt)
    return {"m": jax.tree.map(like, params_shape),
            "v": jax.tree.map(like, params_shape),
            "step": _sds((), jnp.int32)}


# ---------------------------------------------------------------------- LM
def _lm_params_shape(cfg):
    return jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg))


def act_hints(cfg, mesh: Mesh, *, seq_shard: bool = False,
              batch_all_axes: bool = False) -> dict:
    """Activation-sharding hints for the LM stack on this mesh.

    act:    [B, S, d]     batch over dp (+ optionally sequence over model: SP)
    logits: [B, S, V]     batch over dp, vocab over model (when divisible)
    tokens: [B, S]        batch over dp
    kv/ckv: written cache rows — batch over dp, SEQUENCE over model, matching
            the resident cache so the prefill write is a local slice (without
            this the partitioner full-rematerializes k/v per layer: measured
            3.3 TiB/device of collectives on qwen prefill_32k)
    """
    dp = tuple(mesh.axis_names) if batch_all_axes else dp_axes(mesh)
    tp = 1 if batch_all_axes else int(mesh.shape.get("model", 1))
    seq_ax = "model" if seq_shard and not batch_all_axes else None
    vocab_ax = "model" if tp > 1 and cfg.padded_vocab % tp == 0 else None
    cache_seq_ax = "model" if tp > 1 else None
    return {
        "act": NamedSharding(mesh, P(dp, seq_ax, None)),
        "logits": NamedSharding(mesh, P(dp, None, vocab_ax)),
        "tokens": NamedSharding(mesh, P(dp, None)),
        "kv": NamedSharding(mesh, P(dp, cache_seq_ax, None, None)),
        "ckv": NamedSharding(mesh, P(dp, cache_seq_ax, None)),
        "qkv": NamedSharding(mesh, P(dp, seq_ax, None, None)),
        # MoE dispatch [E, C, d] sharding hint.  Measured on grok (E=8 ∤ 16):
        # capacity-over-model conflicts with TP expert weights (2.6× flops,
        # 3× collectives); capacity-over-data adds dispatch churn (+50%
        # collectives).  Baseline leaves dispatch buffers replicated across
        # model (weights TP on d_expert) — revisited in §Perf.
        "moe_cap": None,
    }


def _serve_params_shape(cfg):
    """Inference weights are served in bf16 (f32 master copies live with the
    trainer, not the server) — halves weight HBM and doubles streaming rate."""
    shapes = _lm_params_shape(cfg)
    return jax.tree.map(
        lambda s: _sds(s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        shapes)


def build_lm_train(arch: ArchSpec, cell: ShapeCell, mesh: Mesh, *,
                   remat: bool = True, fsdp: tuple[str, ...] = ("data",),
                   microbatches: int | None = None,
                   mode2d: bool = False,
                   q_chunk: int | None = None,
                   kv_chunk: int | None = None) -> CellProgram:
    """``mode2d``: beyond-paper ZeRO-3/2D scheme — no TP, batch sharded over
    EVERY mesh axis, params fully FSDP-sharded across all axes.  Removes the
    tp-fold redundant attention/embedding compute that the baseline pays when
    head counts don't divide the model axis (see EXPERIMENTS.md §Perf)."""
    cfg = arch.lm
    if q_chunk or kv_chunk:
        cfg = dataclasses.replace(cfg, q_chunk=q_chunk or cfg.q_chunk,
                                  kv_chunk=kv_chunk or cfg.kv_chunk)
    adam = _adam_for(arch)
    seq, gb = cell.seq_len, cell.global_batch
    from repro.launch.mesh import dp_size, mesh_chips

    workers = mesh_chips(mesh) if mode2d else dp_size(mesh)
    if microbatches is None:
        # default: one sequence row per device per microbatch — bounds the
        # remat activation stack to [layers, 1, seq, d] per device
        microbatches = max(gb // workers, 1)
    big = cfg.param_count() > 1e11
    # >100B params: bf16 gradient accumulation / compression (halves both the
    # accumulator and the cross-pod gradient all-reduce bytes), and FSDP over
    # the pod axis too — a 314B f32 master + Adam state cannot fit one pod
    grad_dtype = jnp.bfloat16 if big else jnp.float32
    if big and "pod" in mesh.axis_names and "pod" not in fsdp:
        fsdp = ("pod",) + tuple(fsdp)
    if mode2d:
        fsdp = tuple(mesh.axis_names)
    params_shape = _lm_params_shape(cfg)
    state_shape = {"params": params_shape, "opt": _opt_shapes(params_shape, adam)}
    param_sh = shd.lm_param_shardings(params_shape, cfg, mesh, fsdp=fsdp,
                                      tp_rules=not mode2d)
    state_sh = shd.state_shardings(param_sh, mesh)

    n_prefix = cfg.n_prefix if cfg.frontend == "patches" else 0
    text_len = seq - n_prefix
    hints = act_hints(cfg, mesh, batch_all_axes=mode2d)

    def step(state, stream, starts, prefix_embeds=None):
        def loss(p):
            toks, labels = lm_window_batch(stream, starts, seq_len=text_len)
            # anchor activation sharding: batch over the data axes.  Without
            # this GSPMD replicates the batch dim through the gather and the
            # whole network (measured: 370 GiB/device temps on qwen train_4k).
            toks = jax.lax.with_sharding_constraint(toks, hints["tokens"])
            labels = jax.lax.with_sharding_constraint(labels, hints["tokens"])
            l, aux = lm.loss_fn(p, cfg, toks, labels, prefix_embeds=prefix_embeds,
                                remat=remat, shardings=hints)
            return l, aux

        if microbatches > 1:
            def one_mb(i):
                st = starts.reshape(microbatches, -1)[i]
                pe = (None if prefix_embeds is None else
                      prefix_embeds.reshape((microbatches, -1) + prefix_embeds.shape[1:])[i])
                def loss_mb(p):
                    toks, labels = lm_window_batch(stream, st, seq_len=text_len)
                    toks = jax.lax.with_sharding_constraint(toks, hints["tokens"])
                    labels = jax.lax.with_sharding_constraint(labels, hints["tokens"])
                    return lm.loss_fn(p, cfg, toks, labels, prefix_embeds=pe,
                                      remat=remat, shardings=hints)
                return jax.value_and_grad(lambda p: loss_mb(p)[0])(state["params"])

            def acc(carry, i):
                l_a, g_a = carry
                l, g = one_mb(i)
                return (l_a + l,
                        jax.tree.map(lambda a, b: a + b.astype(grad_dtype), g_a, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype),
                                state["params"])
            (l, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zero),
                                         jnp.arange(microbatches))
            l, grads = l / microbatches, jax.tree.map(lambda g: g / microbatches, grads)
        else:
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(state["params"])
        new_p, new_opt, _ = apply_updates(state["params"], grads, state["opt"],
                                          adam, adam.lr)
        return {"params": new_p, "opt": new_opt}, l

    args = [state_shape, _sds((STREAM_LEN,), jnp.int32), _sds((gb,), jnp.int32)]
    in_sh = [state_sh, shd.replicated(mesh), shd.batch_sharding(mesh)]
    if n_prefix:
        args.append(_sds((gb, n_prefix, cfg.d_model), jnp.dtype(cfg.dtype)))
        in_sh.append(NamedSharding(mesh, P(dp_axes(mesh))))
    out_sh = (state_sh, shd.replicated(mesh))

    return CellProgram(
        name=f"{arch.id}:{cell.name}", kind="train", fn=step,
        args=tuple(args), in_shardings=tuple(in_sh), out_shardings=out_sh,
        meta={"tokens_per_step": gb * seq, "seq": seq, "batch": gb,
              "params": cfg.param_count(), "active_params": cfg.active_param_count(),
              "microbatches": microbatches},
    )


def build_lm_prefill(arch: ArchSpec, cell: ShapeCell, mesh: Mesh, *,
                     moe_groups: int = 1) -> CellProgram:
    cfg = arch.lm
    seq, gb = cell.seq_len, cell.global_batch
    params_shape = _serve_params_shape(cfg)
    param_sh = shd.lm_param_shardings(params_shape, cfg, mesh, fsdp=())
    cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, gb, seq))
    cache_sh = shd.cache_shardings(cache_shape, cfg, mesh)
    hints = act_hints(cfg, mesh)
    if moe_groups > 1:
        dp = dp_axes(mesh)
        hints = {**hints, "moe_groups": moe_groups,
                 "moe_group": NamedSharding(mesh, P(dp, None, None)),
                 "moe_disp": NamedSharding(mesh, P(dp, None, None, None))}

    def step(params, tokens, cache):
        logits, new_cache, lengths = lm.prefill(params, cfg, tokens, cache,
                                                shardings=hints)
        return logits, new_cache, lengths

    return CellProgram(
        name=f"{arch.id}:{cell.name}", kind="prefill", fn=step,
        args=(params_shape, _sds((gb, seq), jnp.int32), cache_shape),
        in_shardings=(param_sh, shd.batch_sharding(mesh), cache_sh),
        out_shardings=(NamedSharding(mesh, P(dp_axes(mesh))), cache_sh,
                       shd.batch_sharding(mesh)),
        meta={"tokens_per_step": gb * seq, "seq": seq, "batch": gb,
              "params": cfg.param_count(), "active_params": cfg.active_param_count(),
              "donate": (2,)},  # cache buffers alias in/out
    )


def build_lm_decode(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    cfg = arch.lm
    seq, gb = cell.seq_len, cell.global_batch
    params_shape = _serve_params_shape(cfg)
    param_sh = shd.lm_param_shardings(params_shape, cfg, mesh, fsdp=())
    cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, gb, seq))
    cache_sh = shd.cache_shardings(cache_shape, cfg, mesh)
    b_sh = shd.batch_sharding(mesh) if gb > 1 else shd.replicated(mesh)
    hints = act_hints(cfg, mesh)
    if gb == 1:  # long_500k: nothing to shard the batch over
        hints = {**hints, "act": None, "tokens": None,
                 "logits": hints["logits"]}

    def step(params, token, cache, lengths):
        return lm.decode_step(params, cfg, token, cache, lengths,
                              shardings=hints)

    return CellProgram(
        name=f"{arch.id}:{cell.name}", kind="decode", fn=step,
        args=(params_shape, _sds((gb, 1), jnp.int32), cache_shape,
              _sds((gb,), jnp.int32)),
        in_shardings=(param_sh, b_sh, cache_sh, b_sh),
        out_shardings=(b_sh, cache_sh),
        meta={"tokens_per_step": gb, "seq": seq, "batch": gb,
              "params": cfg.param_count(), "active_params": cfg.active_param_count(),
              "donate": (2,)},  # cache buffers alias in/out
    )


# -------------------------------------------------------------------- ST-GNN
def build_stgnn_train(arch, cell: ShapeCell, mesh: Mesh, *,
                      placement: str = "replicated",
                      use_pallas: bool = False,
                      compute_dtype: str | None = None,
                      series_len: int = 105_120) -> CellProgram:
    """DCRNN / PGT-DCRNN training cell.

    placement: replicated   — distributed-index-batching (paper §4.2): every
               device holds the series; window gathers are local by
               construction; only the gradient all-reduce crosses chips.
               partitioned  — generalized-distributed-index-batching (§5.4):
               series time-sharded over dp; the step is a ``shard_map`` whose
               per-rank body gathers windows with SHARD-LOCAL indices — the
               compiled program provably contains no data collectives, only
               the gradient psum (the paper's local-batch-shuffling contract).
               ondemand     — baseline DDP: series time-sharded but windows
               sampled globally — every gather crosses shards and the
               partitioner materialises the paper's Fig-7 communication wall.
    """
    mcfg = dataclasses.replace(arch.model, remat=True)
    adam = AdamConfig(lr=1e-2)
    gb = cell.global_batch
    n, f = mcfg.num_nodes, mcfg.in_features
    in_len, hor = mcfg.input_len, mcfg.horizon
    is_dcrnn = isinstance(mcfg, dcrnn.DCRNNConfig)
    mod = dcrnn if is_dcrnn else pgt_dcrnn

    params_shape = jax.eval_shape(lambda: mod.init(jax.random.PRNGKey(0), mcfg))
    param_sh = shd.stgnn_param_shardings(params_shape, mesh)
    state_shape = {"params": params_shape, "opt": _opt_shapes(params_shape, adam)}
    state_sh = shd.state_shardings(param_sh, mesh)
    series_sh = shd.series_sharding(mesh, partitioned=placement != "replicated")

    # the paper's DDP: every chip is one worker — batch shards over ALL axes
    batch_sh = shd.batch_sharding(mesh, pure_dp=True)
    if placement == "partitioned":
        step = _stgnn_partitioned_step(mod, mcfg, adam, mesh, in_len, hor,
                                       use_pallas)
    else:
        cdt = jnp.dtype(compute_dtype) if compute_dtype else None

        def step(state, series, starts, supports):
            def loss(p):
                x, y = gather_batch_fused(series, starts, input_len=in_len,
                                          horizon=hor, use_pallas=use_pallas)
                x = jax.lax.with_sharding_constraint(
                    x, shd.batch_sharding(mesh, pure_dp=True))
                if cdt is not None:
                    x = x.astype(cdt)
                    p = jax.tree.map(lambda w: w.astype(cdt), p)
                return mod.loss_fn(p, mcfg, supports, x, y)

            l, grads = jax.value_and_grad(loss)(state["params"])
            new_p, new_opt, _ = apply_updates(state["params"], grads,
                                              state["opt"], adam, adam.lr)
            return {"params": new_p, "opt": new_opt}, l

    # bf16 supports enter the program already cast — an in-program convert is
    # NOT hoisted out of the time scan (measured +13% traffic instead of -2x)
    sup_dt = jnp.dtype(compute_dtype) if compute_dtype else jnp.float32
    supports_shape = (_sds((n, n), sup_dt), _sds((n, n), sup_dt))
    return CellProgram(
        name=f"{arch.id}:{cell.name}:{placement}", kind="train", fn=step,
        args=(state_shape, _sds((series_len, n, f), jnp.float32),
              _sds((gb,), jnp.int32), supports_shape),
        in_shardings=(state_sh, series_sh, batch_sh,
                      (shd.replicated(mesh), shd.replicated(mesh))),
        out_shardings=(state_sh, shd.replicated(mesh)),
        meta={"windows_per_step": gb, "nodes": n, "placement": placement,
              "series_len": series_len,
              "flops_model": stgnn_model_flops(mcfg, gb)},
    )


def _stgnn_partitioned_step(mod, mcfg, adam, mesh: Mesh, in_len, hor, use_pallas):
    """shard_map step for the generalized variant: per-rank local gathers.

    starts are SHARD-LOCAL offsets (the LocalBatchShuffleSampler emits them);
    each rank gathers from its own series shard, computes grads, and the only
    collective is the explicit gradient psum over the data axes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    dp = dp_axes(mesh)
    all_axes = tuple(mesh.axis_names)
    # series time-sharded over the data axes; every chip is one DDP worker,
    # so the window batch shards over ALL axes (model-axis workers share
    # their data rank's series shard)
    series_spec = PS(dp if len(dp) > 1 else dp[0])
    batch_spec = PS(all_axes)
    rep = PS()

    def body(state, series_shard, starts_shard, supports):
        def loss(p):
            x, y = gather_batch_fused(series_shard, starts_shard,
                                      input_len=in_len, horizon=hor,
                                      use_pallas=use_pallas)
            return mod.loss_fn(p, mcfg, supports, x, y)

        l, grads = jax.value_and_grad(loss)(state["params"])
        # the paper's ONLY collective: average gradients across workers
        grads = jax.lax.pmean(grads, all_axes)
        l = jax.lax.pmean(l, all_axes)
        new_p, new_opt, _ = apply_updates(state["params"], grads,
                                          state["opt"], adam, adam.lr)
        return {"params": new_p, "opt": new_opt}, l

    def step(state, series, starts, supports):
        sm = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, state), series_spec,
                      batch_spec, (rep, rep)),
            out_specs=(jax.tree.map(lambda _: rep, state), rep),
            check_rep=False,
        )
        return sm(state, series, starts, supports)

    return step


def stgnn_model_flops(mcfg, batch: int) -> float:
    """Analytic useful FLOPs per train step (fwd+bwd ≈ 3× fwd matmul FLOPs).

    Per diffusion-conv: K hops × 2 supports of [N,N]@[N,B·C] plus the
    [B·N, (1+2K)·C] @ [(1+2K)·C, H] projection.
    """
    n = mcfg.num_nodes
    k = mcfg.max_diffusion_step
    h = mcfg.hidden
    f = mcfg.in_features
    layers = getattr(mcfg, "layers", 1)  # PGT variant is single-layer
    t = mcfg.input_len + (mcfg.horizon if hasattr(mcfg, "layers") else 0)
    c_in = f + h  # gate input width
    n_mat = 1 + 2 * k
    per_dconv = 2 * k * 2 * n * n * batch * c_in + 2 * batch * n * n_mat * c_in * h
    # DCGRU cell: ru (2h out) + c (h out) ≈ 2 dconvs with different out widths
    per_cell = per_dconv * 2
    return 3.0 * per_cell * layers * t


# ------------------------------------------------------------------ registry
def build_cell(arch_id: str, shape_name: str, mesh: Mesh, **kw) -> CellProgram:
    arch = get_arch(arch_id)
    cell = next((s for s in arch.shapes if s.name == shape_name), None)
    if cell is None:
        raise KeyError(f"{arch_id} has no shape {shape_name!r}")
    if shape_name in arch.skips:
        raise ValueError(f"{arch_id}:{shape_name} skipped — {arch.skips[shape_name]}")
    if arch.family == "stgnn":
        return build_stgnn_train(arch, cell, mesh, **kw)
    if cell.kind == "train":
        return build_lm_train(arch, cell, mesh, **kw)
    if cell.kind == "prefill":
        return build_lm_prefill(arch, cell, mesh, **kw)
    return build_lm_decode(arch, cell, mesh, **kw)


def all_cells():
    """Yield (arch_id, shape_name, skip_reason | None) over the full matrix."""
    from repro.configs import ARCHS

    for aid, arch in ARCHS.items():
        for s in arch.shapes:
            yield aid, s.name, arch.skips.get(s.name)
