"""Roofline model: three terms per (arch × shape × mesh) from the dry-run.

    compute    t_c = HLO_FLOPs/device   / peak_FLOP/s          (MXU ceiling)
    memory     t_m = HLO_bytes/device   / HBM_bw               (HBM ceiling)
    collective t_x = coll_bytes/device  / link_bw              (ICI ceiling)

``cost_analysis()`` and the HLO collective parse are already per-device
(the compiled module is the SPMD per-device program), so each term divides by
a single chip's ceiling — equivalent to the global-total/(chips × ceiling)
formulation.  Step time lower bound = max(terms) assuming perfect overlap;
the dominant term is the bottleneck the §Perf loop iterates on.

MODEL_FLOPS = 6·N·D (dense train), 6·N_active·D (MoE train), 2·N·D forward
(prefill/decode); the ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes
remat/redundancy waste (>1/3 expected under full remat, ~1 with none).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def model_flops(rec: dict) -> float:
    """Useful (6ND-style) FLOPs for the whole step, all chips."""
    meta = rec.get("meta", {})
    if "flops_model" in meta:  # ST-GNN analytic count
        return float(meta["flops_model"])
    n_active = float(meta.get("active_params", 0.0))
    tokens = float(meta.get("tokens_per_step", 0.0))
    if rec.get("kind") == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens  # prefill/decode forward only


def roofline_terms(rec: dict) -> dict:
    """Three terms (seconds) + bottleneck + usefulness ratio for one record."""
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["collectives"]["total"]
    chips = rec["chips"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec)
    hlo_total = flops_dev * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "step_lower_bound_s": bound,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        # fraction of the roofline the *useful* math achieves if the step ran
        # exactly at the lower bound — the score §Perf pushes up
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
    }


def summarize(records: list[dict]) -> list[dict]:
    out = []
    for rec in records:
        if rec.get("status") != "ok":
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "mesh": rec.get("mesh"), "status": rec.get("status"),
                        "reason": rec.get("reason") or rec.get("error")})
            continue
        shape = rec["shape"]
        placement = rec.get("meta", {}).get("placement")
        if placement and placement != "replicated":
            shape = f"{shape}:{placement[:4]}"
        row = {"arch": rec["arch"], "shape": shape, "mesh": rec["mesh"],
               "kind": rec["kind"], "status": "ok",
               "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
               **roofline_terms(rec)}
        out.append(row)
    return out


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24} {'shape':12} {'mesh':8} {'t_comp(s)':>10} {'t_mem(s)':>10} "
           f"{'t_coll(s)':>10} {'bound':>10} {'dom':>7} {'useful':>7} {'RF%':>6} {'GiB/dev':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r.get('arch', '?'):24} {r.get('shape', '?'):12} "
                         f"{r.get('mesh', '-'):8} {r.get('status')}: "
                         f"{str(r.get('reason'))[:60]}")
            continue
        lines.append(
            f"{r['arch']:24} {r['shape']:12} {r['mesh']:8} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['step_lower_bound_s']:10.4f} {r['dominant']:>7} "
            f"{r['useful_ratio']:7.3f} {100*r['roofline_fraction']:6.1f} "
            f"{r['peak_gib']:8.2f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("records", help="JSON file written by repro.launch.dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    with open(args.records) as f:
        records = json.load(f)
    rows = summarize(records)
    print(format_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
