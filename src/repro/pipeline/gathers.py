"""Selectable window-gather implementations for the fused training step.

Every variant has the same contract:
``gather(series, starts, *, input_len, horizon) -> (x, y)`` with
``x: [B, input_len, ...]`` and ``y: [B, horizon, ...]`` — bit-identical
results, different lowerings:

- ``slice``  — per-window ``dynamic_slice`` under ``vmap`` (the default).
- ``take``   — one fused ``jnp.take`` over explicit index grids.
- ``fused``  — one gather of the whole span, split into (x, y).
- ``pallas`` — the fused span gather through the scalar-prefetch Pallas
  kernel (``kernels/window_gather``).
"""
from __future__ import annotations

import functools
from typing import Callable

from repro.core.batching import gather_batch, gather_batch_fused, gather_batch_take

GATHERS: dict[str, Callable] = {
    "slice": gather_batch,
    "take": gather_batch_take,
    "fused": gather_batch_fused,
    "pallas": functools.partial(gather_batch_fused, use_pallas=True),
}


def resolve_gather(name: str) -> Callable:
    try:
        return GATHERS[name]
    except KeyError:
        raise ValueError(
            f"unknown gather {name!r}; expected one of {sorted(GATHERS)}") from None
