"""Selectable window-gather implementations for the fused training step.

Every variant has the same contract:
``gather(series, starts, *, input_len, horizon) -> (x, y)`` with
``x: [B, input_len, ...]`` and ``y: [B, horizon, ...]`` — bit-identical
results, different lowerings:

- ``slice``  — per-window ``dynamic_slice`` under ``vmap`` (the default).
- ``take``   — one fused ``jnp.take`` over explicit index grids.
- ``fused``  — one gather of the whole span, split into (x, y).
- ``pallas`` — the fused span gather through the scalar-prefetch Pallas
  kernel (``kernels/window_gather``).
- ``auto``   — measured dispatch (``kernels/autotune``): the fastest of the
  above for this (backend, shape-bucket), from the persisted tuning cache
  (``results/TUNING_<backend>.json``) or a live measurement under
  ``--autotune tune``; falls back to the static per-backend default when no
  verdict covers the bucket.  Every variant is bit-identical, so ``auto``
  only ever changes speed, never values.
- ``lm``     — token-stream windows (``core.batching.lm_window_batch``):
  the one contract deviation — y is x shifted by one inside the same span
  (``x: [B, input_len]``, ``y: [B, input_len]``), so ``horizon`` only sets
  the window span (use ``WindowSpec(horizon=1, input_len=seq_len)``).
"""
from __future__ import annotations

import functools
from typing import Callable

from repro.core.batching import (gather_batch, gather_batch_fused,
                                 gather_batch_take, lm_window_batch)


def lm_gather(series, starts, *, input_len: int, horizon: int):
    """LM next-token windows: inputs = stream[s:s+L], labels = shift-by-one.

    ``horizon`` is fixed by the WindowSpec span (the extra label token) and
    intentionally unused here — the gather reads ``input_len + 1`` tokens and
    splits them into the (x, y) pair.
    """
    del horizon
    return lm_window_batch(series, starts, seq_len=input_len)


def gather_batch_auto(series, starts, *, input_len: int, horizon: int):
    """Measured dispatch through the shape-bucketed autotuner.

    Resolution happens per call (the backend is read NOW, the verdict is
    keyed by it), so the same training step picks the CPU verdict on the
    CPU container and the TPU verdict on a slice.  The candidate set is
    exactly the named variants above — all bit-identical — so the dispatch
    decision can never change training values.
    """
    from repro.kernels.autotune import dispatch

    return dispatch("gather", series, starts, input_len=input_len,
                    horizon=horizon)


GATHERS: dict[str, Callable] = {
    "slice": gather_batch,
    "take": gather_batch_take,
    "fused": gather_batch_fused,
    "pallas": functools.partial(gather_batch_fused, use_pallas=True),
    "auto": gather_batch_auto,
    "lm": lm_gather,
}


def resolve_gather(name: str) -> Callable:
    try:
        return GATHERS[name]
    except KeyError:
        raise ValueError(
            f"unknown gather {name!r}; expected one of {sorted(GATHERS)}") from None
