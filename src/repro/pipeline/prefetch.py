"""Async feed prefetch pipeline: staleness-aware batch construction overlap.

The synchronous feed path puts host-side batch construction and the
host→device transfer squarely on the critical path of every step:

    feed row -> starts lookup -> device_put -> jitted step   (lockstep)

Index-batching made the *device* side of the step cheap (the gather runs
from the resident series), which leaves the host feed path as the visible
overhead — exactly the latency MSPipe (arXiv:2402.15113) shows can be
hidden by bounded staleness in the temporal-GNN data path with no accuracy
loss.  This module is that pipeline, in two explicit stages:

- **Stage 1 — host materialization** (always on, background thread): pull
  ``[<=chunk, width]`` numpy row blocks from a :meth:`feed_stream`-style
  iterator and queue them, bounded by ``depth`` blocks.  Pure host work:
  feeds are pure functions of (seed, epoch, rank), so a row materialized
  several steps early holds the identical window ids it would hold if built
  lockstep.

- **Stage 2 — host→device transfer**:

  * ``staleness == 0`` — transfer at consume, on the CALLER thread:
    ``next()`` pops a host row and calls ``transfer(row)`` right there,
    which is the exact op order of the synchronous path (`batch_of_starts`
    immediately before the step).  This is the provable identity: pure
    rows + unchanged caller-thread op order ⇒ bit-identical training.
  * ``staleness >= 1`` — a second background thread runs ``transfer`` up to
    ``staleness`` batches beyond the one being consumed, so the transfer
    for step k+1..k+staleness is dispatched (and its copy proceeds) while
    step k's jitted computation is in flight.  Batch construction may then
    overlap across step boundaries — bounded-stale semantics.  Values are
    still identical (pure feeds); what changes is only *when* host/transfer
    work happens relative to the step stream.

Threading rules: stage 1 never touches jax (numpy only), so it is safe to
start before ``jax.distributed.initialize()`` has run.  Stage 2 calls the
transfer fn (``device_put`` / ``make_array_from_process_local_data``) from
its own thread — process-local calls with no collectives, safe under
``jax.distributed`` — and only exists at staleness >= 1.  Kernel-level
backend defaults are resolved lazily per call (``repro.kernels.common``),
so neither thread can pin a backend verdict the main thread has not made.

``close()`` drains the pipeline: both threads stop, queued work is dropped,
and the iterator ends.  The engine drains on every elastic re-mesh so a
kill→shrink→grow cycle resumes from checkpoint coordinates with no stale
in-flight batches — determinism is the checkpoint's, not the pipeline's.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterator

import numpy as np

#: End-of-stream marker flowing through the stage queues.
_DONE = object()

#: Queue put/get timeout — how often blocked stage threads re-check stop.
_TICK = 0.05


@dataclasses.dataclass(frozen=True)
class PrefetchPlan:
    """How far ahead each pipeline stage may run.

    ``depth``      host row blocks stage 1 may materialize beyond the block
                   being consumed (bounds host memory: depth × chunk rows).
    ``staleness``  device batches stage 2 may transfer beyond the batch
                   being consumed.  0 = today's lockstep semantics (transfer
                   at consume, caller thread — bit-identical by
                   construction); s >= 1 = the transfer for step k+s may be
                   in flight while step k computes.
    ``chunk``      feed rows per stage-1 block.
    """

    depth: int = 2
    staleness: int = 0
    chunk: int = 8

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {self.depth}")
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")
        if self.chunk < 1:
            raise ValueError(f"prefetch chunk must be >= 1, got {self.chunk}")


class FeedPrefetcher:
    """Iterator of device-ready batches over a host feed-chunk stream.

    ``rows``: iterator of ``[<=chunk, width]`` numpy blocks (e.g.
    ``DataPlane.grid_stream(epoch)``).  ``transfer``: one host row ->
    device batch (e.g. ``DataPlane.batch_of_starts``).  Yields exactly
    ``transfer(row)`` for every row of every block, in order — the same
    sequence the synchronous loop produces.
    """

    def __init__(self, rows: Iterator[np.ndarray],
                 transfer: Callable[[np.ndarray], Any],
                 plan: PrefetchPlan = PrefetchPlan()):
        self.plan = plan
        self._transfer = transfer
        self._stop = threading.Event()
        self._error: BaseException | None = None
        self._finished = False
        # Stage 1: host row blocks, materialized `depth` blocks ahead.
        self._host_q: queue.Queue = queue.Queue(maxsize=plan.depth)
        self._host_thread = threading.Thread(
            target=self._host_stage, args=(rows,),
            name="feed-prefetch-host", daemon=True)
        # Stage 2 (staleness >= 1 only): device batches, transferred up to
        # `staleness` beyond the consumed batch (queue slots + the row the
        # thread is transferring bound the run-ahead).
        self._dev_q: queue.Queue | None = None
        self._dev_thread: threading.Thread | None = None
        if plan.staleness >= 1:
            self._dev_q = queue.Queue(maxsize=plan.staleness)
            self._dev_thread = threading.Thread(
                target=self._transfer_stage, name="feed-prefetch-transfer",
                daemon=True)
        # staleness-0 consume path: rows of the block currently being drained
        self._pending: list[np.ndarray] = []
        self._host_thread.start()
        if self._dev_thread is not None:
            self._dev_thread.start()

    # ------------------------------------------------------------- stages
    def _put(self, q: queue.Queue, item) -> bool:
        """Bounded put that aborts (returns False) once close() is called."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=_TICK)
                return True
            except queue.Full:
                continue
        return False

    def _host_stage(self, rows: Iterator[np.ndarray]) -> None:
        try:
            for block in rows:
                if self._stop.is_set() or not self._put(self._host_q, block):
                    break
            else:
                self._put(self._host_q, _DONE)
        except BaseException as e:  # surfaced to the consumer in __next__
            self._error = e
            self._put(self._host_q, _DONE)
        finally:
            close = getattr(rows, "close", None)
            if close is not None:
                close()

    def _transfer_stage(self) -> None:
        try:
            while not self._stop.is_set():
                try:
                    block = self._host_q.get(timeout=_TICK)
                except queue.Empty:
                    continue
                if block is _DONE:
                    self._put(self._dev_q, _DONE)
                    return
                for row in block:
                    if not self._put(self._dev_q, self._transfer(row)):
                        return
            # closed mid-stream: nothing more to do
        except BaseException as e:
            self._error = e
            self._put(self._dev_q, _DONE)

    # ----------------------------------------------------------- consumer
    def __iter__(self) -> "FeedPrefetcher":
        return self

    def __next__(self):
        if self._finished:
            raise StopIteration
        src = self._dev_q
        if src is None:
            # staleness 0: pop a host row and transfer it HERE, on the
            # caller thread — the synchronous path's exact op order.
            while not self._pending:
                block = self._get(self._host_q)
                if block is _DONE:
                    return self._finish()
                self._pending = list(block)
            return self._transfer(self._pending.pop(0))
        batch = self._get(src)
        if batch is _DONE:
            return self._finish()
        return batch

    def _get(self, q: queue.Queue):
        while True:
            if self._stop.is_set():
                return _DONE
            try:
                return q.get(timeout=_TICK)
            except queue.Empty:
                if self._error is not None:
                    return _DONE

    def _finish(self):
        self._finished = True
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        raise StopIteration

    # -------------------------------------------------------------- drain
    def close(self, *, timeout: float = 5.0) -> None:
        """Drain the pipeline: stop both threads, drop queued work.

        Idempotent, and safe to call from the step loop's ``finally`` as
        well as the engine's elastic re-mesh path.  After close() the
        iterator is exhausted; a re-mesh builds a fresh prefetcher over the
        new data plane rather than reusing this one.
        """
        self._stop.set()
        self._finished = True
        for t in (self._host_thread, self._dev_thread):
            if t is None or not t.is_alive():
                continue
            deadline = time.monotonic() + timeout
            while t.is_alive() and time.monotonic() < deadline:
                # unblock producers stuck on a full queue
                for q in (self._host_q, self._dev_q):
                    if q is not None:
                        try:
                            q.get_nowait()
                        except queue.Empty:
                            pass
                t.join(timeout=_TICK)
