"""Engine — the execution half of the pipeline: jitted step, checkpoints,
topology, and elastic restarts.

The engine owns everything the :class:`~repro.pipeline.dataplane.DataPlane`
deliberately does not: the fused gather/loss train step, the checkpointer,
and — when an :class:`ElasticConfig` is attached — the fault-tolerance loop
that lets a run survive worker loss:

1. every step, worker heartbeats reach the :class:`HeartbeatMonitor`
   (``ElasticConfig.step_feed`` is the transport — a real collector on a
   fleet, a deterministic fake for single-host fault-injection tests);
2. when the monitor flags a worker, ``plan_remesh`` computes the largest
   healthy sub-mesh (TP groups whole, data axis shrunk) and the in-flight
   state is checkpointed with its (epoch, done_in_epoch) coordinates;
3. the engine shrinks the mesh (``shrink_mesh``), rebuilds the data plane
   for the new world (series re-placed via ``series_sharding``, sampler
   rebuilt, per-worker batch re-scaled by ``scale_batch_or_steps``),
   re-jits the step, and restores the latest checkpoint into the new
   topology (``restore(..., shardings=...)`` re-shards on the way in);
4. training resumes from the same (seed, epoch, step) coordinates —
   samplers are deterministic functions of (seed, epoch), so the resumed
   schedule is reproducible.  (Within the interrupted epoch the coverage is
   approximate when the batch re-scales: the same permutation re-rows into
   a different grid, so a few boundary windows may repeat or drop.  The
   global step counter stays monotonic across re-meshes.)

``Engine`` also keeps the whole legacy ``Pipeline`` surface (``.sampler``,
``.dataset``, ``.describe()``, ``.fit``, ``.evaluate``, …) so
``build_pipeline`` remains a working compatibility constructor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import Placement, data_axes, dp_size
from repro.core.index_dataset import IndexDataset
from repro.core.windows import WindowSpec
from repro.distributed import (Checkpointer, HeartbeatMonitor,
                               LeaderCheckpointer, checkpoint_meta,
                               latest_step, plan_remesh, restore,
                               scale_batch_or_steps)
from repro.launch.mesh import shrink_mesh
from repro.pipeline.dataplane import DataPlane, PipelineConfig, build_dataplane
from repro.pipeline.gathers import resolve_gather
from repro.pipeline.prefetch import FeedPrefetcher, PrefetchPlan
from repro.pipeline.samplers import ShardAlignedBatchSampler
from repro.train.loop import (RestartSignal, combine_weighted,
                              init_train_state, make_train_step, run_training)


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Fault-tolerance policy for :meth:`Engine.fit`.

    Heartbeat workers are indexed by DATA-PARALLEL rank (0..world−1); with
    the defaults ``model_parallel == chips_per_host`` each worker is its own
    TP group, so losing one drops exactly one data rank.  Set them per your
    fleet layout when a TP group spans hosts — ``plan_remesh`` then drops
    whole groups and the engine shrinks the world by the dropped-rank count.

    ``step_feed(global_step, world) -> {rank: (step, step_time | None)}`` is
    the heartbeat transport: which workers reported in since the last step.
    None (the default) simulates an all-healthy fleet — every rank beats
    every step — which is correct for single-process runs and lets tests
    inject faults by omitting ranks (and driving ``clock``) instead.  Real
    transports live in :mod:`repro.distributed.transport`.  A beat from a
    rank OUTSIDE the current world is a dropped worker announcing its
    return: the engine plans the inverse GROW re-mesh (up to
    ``target_world``, defaulting to the world the engine was built with) and
    the per-worker batch scales back down against the BASE global batch —
    shrink and grow round-trip to the original topology.

    ``emitter(global_step)`` is the worker-side half of a real transport:
    called once per step so THIS process's ranks heartbeat out (wire it to
    ``transport.emit``); None for single-process fakes.

    ``remesh`` selects who executes a plan: ``"inprocess"`` (default) has
    the engine shrink/grow the mesh and resume inside this process — valid
    single-host, where every shard stays addressable; ``"relaunch"`` makes
    :meth:`Engine.fit` re-raise the checkpoint-annotated
    :class:`RestartSignal` so an external launcher can tear the gang down
    and relaunch into the planned topology (the only sound option under a
    real ``jax.distributed`` fleet, where a dead peer's shards are gone and
    the next collective would hang).

    On shrink with ``keep_global_batch=True`` the per-worker batch is
    ``ceil(global/new_dp)``, so the global batch can GROW by up to
    ``new_dp − 1`` windows (no ragged trim exists — uniform SPMD batches);
    ``False`` keeps the per-worker batch and shrinks the global batch.
    Both directions always re-scale from the engine's BASE global batch, so
    repeated re-meshes never compound the ceil rounding.
    """

    check_every: int = 1           # poll the monitor every N steps
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 3.0
    model_parallel: int = 1        # TP group size, kept whole by plan_remesh
    chips_per_host: int = 1
    keep_global_batch: bool = True  # scale_batch_or_steps policy on re-mesh
    max_restarts: int = 8
    clock: Callable[[], float] = time.monotonic
    step_feed: Callable[[int, int], dict] | None = None
    emitter: Callable[[int], None] | None = None
    target_world: int | None = None  # grow ceiling; None = the build world
    remesh: str = "inprocess"      # or "relaunch" (external launcher re-meshes)
    # A returned worker must announce on this many polls (and still be
    # fresh) before a grow is planned — one stray beat from a crash-looping
    # host must not trigger a grow that immediately shrinks back.  The
    # launcher owns any stronger quarantine policy (e.g. exponential rejoin
    # backoff across relaunches); this is the in-process debounce.
    readmit_after_beats: int = 3
    # Leader succession (repro.distributed.leader.LeaderTracker): when set,
    # every single-writer duty — checkpoint writes, plan decisions, plan/
    # history emission — follows `leader.is_leader()` instead of the fixed
    # `jax.process_index() == 0`, so the death of process 0 hands the
    # decider role to the lowest surviving rank (whose transport state is
    # already primed: the file transport is symmetric, the TCP collectors
    # peer-mirror).  None keeps the classic process-0 gating.
    leader: Any | None = None


@dataclasses.dataclass
class Engine:
    """Jitted step + checkpointing + topology over a rebuildable DataPlane."""

    dataplane: DataPlane
    loss_fn: Callable
    init_params: Any
    train_step: Callable
    _eval_loss: Callable  # jitted (params, starts) -> (loss, metrics)
    elastic: ElasticConfig | None = None
    # One record per elastic restart: the plan plus the resume coordinates.
    restarts: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        # The BASE topology: re-mesh scaling is always computed against it
        # (never against the previous re-mesh's inflated output) and grow
        # plans re-expand its mesh — a shrink→grow round trip restores the
        # original (mesh, world, per-worker batch) exactly.
        self._base_mesh = self.dataplane.mesh
        self._base_world = self.dataplane.world
        self._base_global_batch = self.dataplane.global_batch
        self._checkpointer: Any = None  # fit's writer, kept for succession

    # -------------------------------------------------------------- leadership
    def is_leader(self) -> bool:
        """Whether THIS process currently owns the single-writer duties
        (checkpoints, plan emission, durable history).  With an
        ``ElasticConfig.leader`` tracker attached the verdict follows the
        succession rule (lowest live rank wins); without one it is the
        classic fixed gate, process 0."""
        el = self.elastic
        if el is not None and el.leader is not None:
            return el.leader.is_leader()
        return jax.process_index() == 0

    def leader_rank(self) -> int:
        el = self.elastic
        if el is not None and el.leader is not None:
            return el.leader.leader()
        return 0

    # ------------------------------------------- legacy Pipeline surface
    @property
    def config(self) -> PipelineConfig:
        return self.dataplane.config

    @property
    def mesh(self):
        return self.dataplane.mesh

    @property
    def spec(self) -> WindowSpec:
        return self.dataplane.spec

    @property
    def dataset(self) -> IndexDataset:
        return self.dataplane.dataset

    @property
    def sampler(self):
        return self.dataplane.sampler

    @property
    def series_sharding(self):
        return self.dataplane.series_sharding

    @property
    def world(self) -> int:
        return self.dataplane.world

    @property
    def steps_per_epoch(self) -> int:
        return self.dataplane.steps_per_epoch

    @property
    def global_batch(self) -> int:
        return self.dataplane.global_batch

    def describe(self) -> dict:
        return self.dataplane.describe()

    def batch_of_starts(self, window_ids: np.ndarray, *,
                        replicate: bool = False) -> jnp.ndarray:
        return self.dataplane.batch_of_starts(window_ids, replicate=replicate)

    # --------------------------------------------------------------- training
    def fit(
        self,
        *,
        epochs: int | None = None,
        eval_fn: Callable[[Any], dict] | None | str = "auto",
        resume: bool = True,
        history_sink: list | None = None,
    ) -> tuple[Any, list[dict]]:
        """Train (resuming from ``loop.ckpt_dir`` when a checkpoint exists).

        Returns ``(state, history)`` exactly like ``run_training``.
        ``eval_fn="auto"`` evaluates val-split MAE at every epoch end.  With
        an :class:`ElasticConfig` attached, worker loss mid-run triggers a
        re-mesh-and-resume instead of killing the run (requires ``ckpt_dir``).
        ``history_sink`` mirrors every logged row into a caller-owned list
        that survives non-elastic crashes (see ``run_training``).

        Under ``jax.distributed``, every process restores from ``ckpt_dir``
        but only the LEADER writes to it — one writer, no torn manifests.
        Without an ``ElasticConfig.leader`` tracker the leader is fixed at
        process 0 (the historical behavior); with one, every process keeps
        a warm-standby :class:`LeaderCheckpointer` so checkpoint-writer
        duty survives the leader's death (``succeed_as_leader``).
        """
        loop = self.config.loop
        if epochs is not None:
            loop = dataclasses.replace(loop, epochs=epochs)
        if self.elastic is not None and not loop.ckpt_dir:
            raise ValueError("elastic fit needs loop.ckpt_dir: the re-mesh "
                             "path restores from the latest checkpoint")
        if (self.elastic is not None and self.elastic.remesh == "inprocess"
                and jax.process_count() > 1):
            raise ValueError(
                "elastic remesh='inprocess' cannot run under jax.distributed: "
                "a dead peer's shards are unaddressable and its collectives "
                "would hang; use ElasticConfig(remesh='relaunch') so the "
                "launcher tears the gang down and relaunches into the "
                "planned topology (see tests/multihost.py)")
        # Copy params into the fresh state: the jitted step donates its state
        # argument, and aliasing the caller's arrays would delete them after
        # the first step (breaking re-fits and sibling pipelines).
        params = jax.tree.map(jnp.copy, self.init_params)
        state = init_train_state(params, self.config.adam)
        # Every process that could ever become the leader drives a
        # (leader-gated) checkpointer: the current leader's saves land on
        # disk, standbys hold warm host snapshots for succession.  Without
        # a tracker only process 0 can lead, so other processes skip the
        # snapshot work entirely (the historical single-writer setup).
        has_tracker = self.elastic is not None and self.elastic.leader is not None
        checkpointer = (LeaderCheckpointer(Checkpointer(loop.ckpt_dir),
                                           self.is_leader)
                        if loop.ckpt_dir
                        and (has_tracker or jax.process_index() == 0)
                        else None)
        self._checkpointer = checkpointer
        start_step, start_epoch, start_done = 0, 0, None
        if resume and loop.ckpt_dir and latest_step(loop.ckpt_dir) is not None:
            state, start_step = restore(loop.ckpt_dir, state)
            # Prefer the checkpoint's own (epoch, done_in_epoch) coordinates
            # over deriving them from the raw step: after an elastic shrink
            # changed steps_per_epoch the derivation would land on the wrong
            # (epoch, position).  start_step stays the raw checkpoint step —
            # a monotonic counter — so later saves always outrank this one.
            meta = checkpoint_meta(loop.ckpt_dir)
            if "epoch" in meta:
                start_epoch = int(meta["epoch"])
                start_done = max(int(meta.get("done_in_epoch", 0)), 0)
            else:
                start_epoch = start_step // self.steps_per_epoch
        if eval_fn == "auto":
            # Works single- AND multi-process: evaluate() rides the per-rank
            # eval feeds, and every process derives the identical chunk plan
            # from the pool alone, so the epoch-end eval collectives stay in
            # lock step across the fleet.
            eval_fn = (lambda st: {"val_mae": self.evaluate(st["params"])}) \
                if len(self.dataset.val_windows) > 0 else None
        if eval_fn is not None and self.elastic is not None \
                and self.elastic.emitter is not None:
            # Epoch-end eval is a coordinated pause of the lockstep program:
            # nobody steps, so nobody heartbeats, and an eval (or its first
            # compile) longer than heartbeat_timeout would make the first
            # post-eval poll read the whole HEALTHY fleet as stale and plan a
            # bogus shrink.  Re-announce liveness the moment eval returns —
            # every process runs eval_fn, so every rank re-beats before the
            # decider's next poll.
            inner_eval = eval_fn

            def eval_fn(st):
                out = inner_eval(st)
                try:
                    self.elastic.emitter(self._hb_step)
                except OSError:
                    pass  # fire-and-forget, like the per-step emit
                return out
        history: list[dict] = []
        self._hb_step = start_step  # last health-polled step (eval re-beats)
        monitor = self._make_monitor()
        restarts_this_fit = 0
        # Feed the step loop through the async prefetch pipeline when the
        # loop config asks for it.  The factory reads self.dataplane at CALL
        # time (once per epoch), so after an elastic re-mesh the next epoch's
        # stream is built over the new plane — the old stream was already
        # drained by run_training's finally when the RestartSignal unwound.
        batch_stream = None
        if loop.prefetch_depth >= 1:
            plan = PrefetchPlan(depth=loop.prefetch_depth,
                                staleness=loop.staleness,
                                chunk=loop.prefetch_chunk)

            def batch_stream(epoch: int, done: int) -> FeedPrefetcher:
                dp = self.dataplane
                return FeedPrefetcher(
                    dp.grid_stream(epoch, start=done, chunk=plan.chunk),
                    dp.prefetch_transfer(plan.staleness), plan)
        while True:
            try:
                state, hist = run_training(
                    state=state,
                    train_step=self.train_step,
                    sampler=self.dataplane,
                    batch_of_starts=self.dataplane.batch_of_starts,
                    loop=loop,
                    eval_fn=eval_fn,
                    checkpointer=checkpointer,
                    start_epoch=start_epoch,
                    start_step=start_step,
                    start_done_in_epoch=start_done,
                    health_cb=self._health_cb(monitor),
                    history_sink=history_sink,
                    batch_stream=batch_stream,
                )
                history.extend(hist)
                return state, history
            except RestartSignal as sig:
                history.extend(sig.history)
                sig.leader = self.is_leader()
                if self.elastic.remesh == "relaunch":
                    # The external launcher owns re-meshing: run_training
                    # already checkpointed the in-flight state with its
                    # (epoch, done_in_epoch) coordinates, so hand the
                    # annotated signal (plan + resume coordinates +
                    # whether THIS process is the deciding leader) up.
                    raise
                if restarts_this_fit >= self.elastic.max_restarts:
                    raise RuntimeError(
                        f"elastic restart budget exhausted "
                        f"({self.elastic.max_restarts})") from sig
                restarts_this_fit += 1
                state, start_epoch, start_step, start_done = \
                    self._apply_plan(sig, loop)
                monitor = self._make_monitor()
                if self.elastic.emitter is not None:
                    # Draining the prefetcher + re-meshing + re-jitting is a
                    # coordinated pause just like epoch-end eval: nobody
                    # steps, so nobody heartbeats.  Re-announce liveness
                    # before resuming so the first post-restart poll doesn't
                    # read the healthy fleet as stale.
                    try:
                        self.elastic.emitter(self._hb_step)
                    except OSError:
                        pass
            except BaseException:
                # A non-elastic failure (e.g. a collective erroring out when
                # a real peer died) must not strand the in-flight async
                # checkpoint write: flush it so a relaunch resumes from the
                # newest durable step instead of one step earlier.
                if checkpointer is not None:
                    try:
                        checkpointer.wait()
                    except Exception:
                        pass
                raise

    # ------------------------------------------------------------- evaluation
    def evaluate(self, params, *, split: str = "val", max_batches: int = 4) -> float:
        """Window-weighted mean loss over up to ``max_batches`` eval chunks.

        Rides the distributed eval feeds (``DataPlane.eval_grid``): full
        chunks are the pool's global batches, assembled from each process's
        own ``eval_feed`` rank-block columns under ``jax.distributed`` (no
        process ever materialises — or gathers windows for — more than its
        own shard of a chunk), and the ragged tail is scored once as a small
        replicated batch (one extra compile for its shape) so small splits
        are never silently truncated.  Per-chunk ``(loss, windows)`` pairs
        combine through :func:`repro.train.loop.combine_weighted`, making
        the multi-process result bit-identical to the single-host
        window-weighted reference.
        """
        dp = self.dataplane
        pool = dp.eval_pool(split)
        if len(pool) == 0:
            return float("nan")
        rows, tail = dp.eval_grid(split)
        pairs = []
        for i in range(min(rows.shape[0], max_batches)):
            loss, _ = self._eval_loss(params, dp.batch_of_starts(rows[i]))
            pairs.append((float(loss), self.global_batch))
        # The tail only contributes when the budget was not already spent on
        # full chunks — the same coverage the pre-distributed evaluate gave.
        # Its replicated device row is identical every call, so it comes
        # from the data plane's per-split cache (one transfer per plane).
        if len(tail) and rows.shape[0] < max_batches:
            tail_len, tail_batch = dp.eval_tail_batch(split)
            loss, _ = self._eval_loss(params, tail_batch)
            pairs.append((float(loss), tail_len))
        return combine_weighted(pairs)

    # ---------------------------------------------------------------- elastic
    def succeed_as_leader(self, dead_ranks) -> dict | None:
        """Post-collective-failure leader succession.

        A peer's death surfaces to the survivors as a failed collective —
        a plain exception out of :meth:`fit` — and the launcher attributes
        WHO died through the transport's ``snapshot()`` (whose beats went
        silent).  It then hands the verdict here: the tracker marks the
        dead ranks (immediately — the survivor must not wait out a
        heartbeat timeout to start writing), and if the lowest live rank
        is now ours, this process takes over every single-writer duty the
        dead leader held:

        - the warm-standby checkpoint (the exact failure-step state,
          snapshotted to host before the buffers could be donated or
          poisoned) is durably written — ``ckpt_step``;
        - the SHRINK plan is decided by the successor and returned for the
          launcher to relaunch against.

        Returns ``{"leader", "plan", "ckpt_step"}`` when this process is
        now the leader, else None.  (History succession is the sink's job:
        call ``LeaderHistorySink.flush_as_leader()`` alongside this.)
        """
        el = self.elastic
        dead = sorted({int(r) for r in dead_ranks})
        if el is not None and el.leader is not None:
            el.leader.note_dead(dead)
        if not self.is_leader():
            return None
        ckpt_step = None
        if isinstance(self._checkpointer, LeaderCheckpointer):
            try:
                self._checkpointer.wait()
            except Exception:
                pass  # an earlier async write failing must not block takeover
            ckpt_step = self._checkpointer.takeover()
        plan = None
        if el is not None and dead:
            try:
                plan = plan_remesh(self.world, dead,
                                   model_parallel=el.model_parallel,
                                   chips_per_host=el.chips_per_host,
                                   decided_by=self.leader_rank())
            except RuntimeError:
                plan = None  # no healthy TP group left: nothing to relaunch
        return {"leader": self.leader_rank(), "plan": plan,
                "ckpt_step": ckpt_step}

    def _make_monitor(self) -> HeartbeatMonitor | None:
        if self.elastic is None:
            return None
        el = self.elastic
        return HeartbeatMonitor(self.world, timeout=el.heartbeat_timeout,
                                straggler_factor=el.straggler_factor,
                                clock=el.clock)

    def _health_cb(self, monitor: HeartbeatMonitor | None):
        if monitor is None:
            return None
        el = self.elastic
        world = self.world
        target = el.target_world or self._base_world
        returned: dict[int, list] = {}  # rank -> [poll count, last clock]
        announced: set[int] = set()     # out-of-world beats since last poll

        def cb(global_step: int) -> None:
            self._hb_step = global_step
            if el.emitter is not None:
                try:
                    el.emitter(global_step)  # this process's ranks beat out
                except OSError:
                    # Fire-and-forget, like the transports themselves: a
                    # transient emit failure (NFS stall, ENOSPC) makes this
                    # worker look late to the MONITOR — it must not crash a
                    # healthy training process.
                    pass
            beats = (el.step_feed(global_step, world)
                     if el.step_feed is not None
                     else {r: (global_step, None) for r in range(world)})
            if el.leader is not None:
                # Leadership derives from the SAME seq-gated beat stream the
                # monitor consumes — every survivor reaches the same verdict
                # from the same state, no election round-trips.
                el.leader.observe(beats)
            for rank, (step, step_time) in beats.items():
                if rank in monitor.workers:
                    monitor.beat(rank, step, step_time)
                else:
                    # A beat from outside the current world: a dropped
                    # worker announcing its return.  REJOIN CONTRACT: the
                    # announcement must use the TARGET fleet's numbering
                    # (anything ≥ world) — a rebooted host re-using an id
                    # below the current world is indistinguishable from the
                    # live rank that now owns that id, so the launcher's
                    # rejoin agent assigns out-of-world ids (see
                    # tests/multihost.py's announcer).
                    announced.add(rank)
            if el.check_every > 1 and global_step % el.check_every:
                return
            # A returned worker is only re-admitted once it has announced
            # across ``readmit_after_beats`` DISTINCT decision polls AND is
            # still fresh: a worker that beat once and went silent — or a
            # crash-looping host burst-announcing inside one poll window —
            # is flapping, and growing toward it would just shrink right
            # back, burning restart budget each time.
            now = el.clock()
            for rank in announced:
                seen = returned.setdefault(rank, [0, 0.0])
                seen[0] += 1
                seen[1] = now
            announced.clear()
            unhealthy = monitor.unhealthy()
            fresh = sorted(r for r, (n, t) in returned.items()
                           if n >= el.readmit_after_beats
                           and now - t <= el.heartbeat_timeout)
            recovered = (fresh[: target - world]
                         if not unhealthy and world < target else [])
            if not unhealthy and not recovered:
                return
            # Only the CURRENT leader turns a verdict into a plan.  Every
            # survivor keeps polling (its monitor/tracker state stays primed
            # — that is what makes it a viable successor), but a non-leader
            # acting on the same verdict would race a divergent plan and
            # checkpoint coordinates against the leader's.  When the leader
            # itself is what died, the tracker times it out right here and
            # the successor's NEXT poll passes this gate: a dead rank 0
            # yields a shrink plan decided by rank 1, not a hung fleet.
            if not self.is_leader():
                return
            plan = plan_remesh(world, unhealthy, recovered=recovered,
                               model_parallel=el.model_parallel,
                               chips_per_host=el.chips_per_host,
                               decided_by=self.leader_rank())
            if plan is not None:
                raise RestartSignal(plan)

        return cb

    def _apply_plan(self, sig: RestartSignal, loop
                    ) -> tuple[Any, int, int, int]:
        """Re-mesh to the plan's topology and restore the latest checkpoint.

        Shrink plans drop the plan's dead workers; grow plans re-admit the
        plan's returned workers (capped at ``target_world``) and
        inverse-apply the batch scaling.  Both directions re-scale against
        the BASE global batch and carve the new mesh out of the BASE mesh,
        so shrink→grow restores the original topology exactly.

        Returns ``(state, start_epoch, start_step, start_done_in_epoch)``:
        the same (seed, epoch) and completed-step count within the
        interrupted epoch, with ``start_step`` continuing the MONOTONIC
        global counter from the failure checkpoint — step numbers never go
        backwards, so ``latest_step`` can never resurrect a stale
        pre-restart checkpoint.
        """
        el = self.elastic
        plan = sig.plan
        old_spe = self.steps_per_epoch
        # Workers ARE data-parallel ranks here, so the new world is simply
        # the surviving (or re-admitted) rank count.  (plan.mesh_shape[0]
        # counts TP GROUPS — the same number only when model_parallel ==
        # chips_per_host.)
        if plan.kind == "grow":
            target = el.target_world or self._base_world
            new_world = min(self.world + len(set(plan.readmitted_workers)),
                            target)
        else:
            new_world = self.world - len(set(plan.dropped_workers))
        per_new, _ = scale_batch_or_steps(
            self._base_global_batch, old_dp=self._base_world,
            new_dp=new_world, keep_global_batch=el.keep_global_batch)
        new_mesh = shrink_mesh(self._base_mesh, new_world)
        self.dataplane = self.dataplane.remesh(
            new_mesh, world=new_world, batch_per_rank=per_new)
        if el.leader is not None:
            # Ranks renumber with the topology; in-process re-meshing is
            # single-host (fit() enforces it), so this process owns every
            # rank of the new world and stays the leader.
            el.leader.reset(new_world)
        self.train_step, self._eval_loss = _compile(
            self.dataplane, self.loss_fn, self.config)
        # Restore the failure-step checkpoint into the new topology: params
        # and opt state are replicated in this runtime, so one re-sharding
        # NamedSharding covers every leaf.
        template = init_train_state(
            jax.tree.map(jnp.copy, self.init_params), self.config.adam)
        state, ckpt_step = restore(
            loop.ckpt_dir, template,
            shardings=NamedSharding(new_mesh, P()))
        meta = checkpoint_meta(loop.ckpt_dir)
        epoch = int(meta.get("epoch", sig.epoch))
        done = max(int(meta.get("done_in_epoch", ckpt_step - epoch * old_spe)),
                   0)
        self.restarts.append({
            "plan": plan, "kind": plan.kind, "epoch": epoch,
            "step": ckpt_step, "world": new_world, "batch_per_rank": per_new,
            "global_batch": self.global_batch,
        })
        return state, epoch, ckpt_step, done


def _shard_local_gather_ok(dataplane: DataPlane, config: PipelineConfig) -> bool:
    """Whether the train-step gather can lower as a shard_map (§5.4 proof).

    The global-index gather over a time-sharded series makes XLA all-gather
    the series (it cannot prove locality from runtime start values).  When
    every sampled window is GUARANTEED interior to its rank's shard — the
    aligned sampler with halo=False, one feed rank per device shard, even
    time split — the gather can instead run per-shard with local offsets,
    and the compiled program's only collective is the gradient all-reduce
    (see launch/dryrun.py --halo-evidence for the byte counts).
    """
    mesh = dataplane.mesh
    dp = dp_size(mesh)
    return (config.placement is Placement.PARTITIONED
            and not config.halo
            and isinstance(dataplane.sampler, ShardAlignedBatchSampler)
            and dp > 1
            and dataplane.world == dp
            and len(data_axes(mesh)) == 1
            and dataplane.dataset.entries % dp == 0
            and config.loop.microbatches == 1)


def _shard_local_gather(gather: Callable, dataplane: DataPlane) -> Callable:
    """Wrap ``gather`` in a shard_map: each rank gathers from ITS series
    shard with shard-local offsets (global start − shard origin)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = dataplane.mesh
    axis = data_axes(mesh)[0]
    shard_len = dataplane.dataset.entries // int(mesh.shape[axis])

    def local(series_shard, starts_shard, *, input_len, horizon):
        lo = jax.lax.axis_index(axis) * shard_len
        return gather(series_shard, starts_shard - lo,
                      input_len=input_len, horizon=horizon)

    def fn(series, starts, *, input_len, horizon):
        import functools
        body = functools.partial(local, input_len=input_len, horizon=horizon)
        return shard_map(body, mesh=mesh,
                         in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis), P(axis)),
                         check_rep=False)(series, starts)

    return fn


def _compile(dataplane: DataPlane, loss_fn: Callable, config: PipelineConfig):
    """(train_step, eval_loss) with the window gather fused over THIS data
    plane's resident series — rebuilt on every re-mesh."""
    gather = resolve_gather(config.gather)
    spec = dataplane.spec
    series = dataplane.dataset.series
    # The series is CLOSED OVER, so without a constraint GSPMD is free to
    # re-shard the captured constant — it replicates it, silently voiding
    # the PARTITIONED/ONDEMAND memory contract and hiding the gathers'
    # cross-shard traffic.  Pin the placement's sharding inside the step.
    pin = (dataplane.series_sharding if dataplane.mesh.size > 1 else None)
    # halo=False + aligned feeds: provably-local gathers lower as a
    # shard_map — zero data collectives.  Eval stays on the global-index
    # gather: val/test pools are drawn globally, not shard-aligned.
    train_gather = (_shard_local_gather(gather, dataplane)
                    if _shard_local_gather_ok(dataplane, config) else gather)

    def train_loss(params, starts):
        s = jax.lax.with_sharding_constraint(series, pin) if pin else series
        x, y = train_gather(s, starts, input_len=spec.in_len,
                            horizon=spec.horizon)
        return loss_fn(params, x, y)

    def eval_loss(params, starts):
        s = jax.lax.with_sharding_constraint(series, pin) if pin else series
        x, y = gather(s, starts, input_len=spec.in_len,
                      horizon=spec.horizon)
        return loss_fn(params, x, y)

    schedule = config.schedule or (lambda s: config.adam.lr)
    loop = config.loop
    train_step = make_train_step(
        train_loss, config.adam, schedule,
        microbatches=loop.microbatches, grad_dtype=loop.grad_dtype,
        donate=loop.donate)
    return train_step, jax.jit(eval_loss)


def build_engine(
    raw: np.ndarray | None,
    spec: WindowSpec,
    mesh,
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], tuple[jnp.ndarray, dict]],
    init_params: Any,
    config: PipelineConfig = PipelineConfig(),
    *,
    dataset: IndexDataset | None = None,
    elastic: ElasticConfig | None = None,
) -> Engine:
    """Assemble the full placement-aware trainer (DataPlane + Engine).

    ``loss_fn(params, x, y) -> (loss, metrics)`` is the only model-specific
    piece; the engine supplies (x, y) by fusing the selected window gather
    into the jitted step.  Pass ``dataset=`` to reuse an already-built
    ``IndexDataset``; pass ``elastic=`` to survive worker loss mid-fit.
    """
    dataplane = build_dataplane(raw, spec, mesh, config, dataset=dataset)
    train_step, eval_loss = _compile(dataplane, loss_fn, config)
    return Engine(dataplane=dataplane, loss_fn=loss_fn,
                  init_params=init_params, train_step=train_step,
                  _eval_loss=eval_loss, elastic=elastic)
