"""Shard-aligned local batch sampler for the PARTITIONED placement.

``series_sharding`` splits the resident series' TIME axis evenly across the
data-parallel devices (``local_time_range``).  For the §5.4 communication-free
contract to hold, each rank's sampled windows must lie inside the time range
its device actually owns — a plain count-split of the train windows lands on
different boundaries and silently turns local gathers into cross-shard ones.

``ShardAlignedBatchSampler`` draws rank r's windows from
``local_window_ids(entries, spec, r, world) ∩ train`` — the same definition
the placement math uses — so gathers stay on-shard (halo windows excepted).
Batch ORDER shuffles between epochs; partition content is fixed (local batch
shuffling, Table 5).

Alignment is only possible when every rank's local train-window count covers
at least one batch; with the standard 70/10/20 contiguous split, ranks owning
the val/test tail of the series may have none.  ``build_pipeline`` falls back
to the contiguous count-split (``LocalBatchShuffleSampler``) in that case and
the locality claim weakens to approximate — callers that need strict
alignment should widen the train fraction (see ``benchmarks/fig9``).
"""
from __future__ import annotations

import numpy as np

from repro.core.distributed import local_window_ids
from repro.core.sampler import EvalFeeds, _rng
from repro.core.windows import WindowSpec


class ShardAlignedBatchSampler(EvalFeeds):
    """Per-rank fixed partitions aligned to ``local_time_range`` boundaries."""

    def __init__(
        self,
        entries: int,
        spec: WindowSpec,
        train_ids: np.ndarray,
        batch_per_rank: int,
        world: int,
        *,
        seed: int = 0,
        halo: bool = True,
    ):
        if spec.stride != 1:
            raise ValueError("shard alignment requires stride=1 "
                             "(window id == start step)")
        train = np.asarray(train_ids, dtype=np.int32)
        self.rank_ids = []
        for r in range(world):
            ids = local_window_ids(entries, spec, r, world, halo=halo)
            self.rank_ids.append(ids[np.isin(ids, train)])
        counts = [len(ids) for ids in self.rank_ids]
        self.batch = batch_per_rank
        self.world = world
        self.seed = seed
        # Batch CONTENT is fixed once per rank (local batch shuffling); the
        # lock-step step count is set by the smallest rank.  Time-aligned
        # shards hold unequal train-window counts, so larger ranks draw a
        # cyclically-rotating window over a fixed permutation of their
        # batches each epoch: every batch is guaranteed to be visited at
        # least once every ceil(n_batches / steps_per_epoch) epochs instead
        # of the surplus being truncated away permanently.
        self.rank_batches = []
        for ids in self.rank_ids:
            n_b = len(ids) // batch_per_rank
            self.rank_batches.append(
                ids[:n_b * batch_per_rank].reshape(n_b, batch_per_rank))
        self.steps_per_epoch = min(b.shape[0] for b in self.rank_batches)
        if self.steps_per_epoch == 0:
            raise ValueError(
                f"rank partition too small for one batch (counts={counts}); "
                "widen the train split or use the count-split sampler")

    def feed(self, rank: int, epoch: int) -> np.ndarray:
        """[steps, batch] window ids for ``rank`` — the per-process feed,
        deterministic in (seed, epoch) — no communication, every rank
        derives the schedule.

        Selection: a cyclic window of ``steps_per_epoch`` entries over a
        FIXED (per-rank) permutation of the rank's batches, advanced by
        ``steps_per_epoch`` each epoch — guaranteed full coverage of uneven
        partitions.  Order within the epoch reshuffles per (seed, epoch).
        """
        batches = self.rank_batches[rank]
        n_b = batches.shape[0]
        steps = self.steps_per_epoch
        # fixed per-rank permutation (epoch-independent; rank offsets the seed)
        base = _rng(self.seed, 1_000_003 + rank).permutation(n_b)
        start = (epoch * steps) % n_b
        chosen = base[np.arange(start, start + steps) % n_b]
        order = _rng(self.seed, epoch).permutation(steps)
        return batches[chosen[order]]

    def epoch_rank(self, epoch: int, rank: int) -> np.ndarray:
        """Transposed-argument alias of :meth:`feed` (kept for callers that
        predate the first-class feed contract)."""
        return self.feed(rank, epoch)

    def epoch(self, epoch: int) -> np.ndarray:
        return self.feed(0, epoch)

    def epoch_global(self, epoch: int) -> np.ndarray:
        """[steps, world*batch] rank-major assembly of the per-rank feeds."""
        return np.concatenate(
            [self.feed(r, epoch) for r in range(self.world)], axis=1)
