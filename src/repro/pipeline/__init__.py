"""Placement-aware training pipeline, split into two layers:

- DataPlane: placement → sampler → deterministic per-rank feeds;
- Engine: jitted gather/step, checkpointing, topology, elastic restarts.

``build_pipeline`` is the compatibility constructor (returns an Engine).
"""
from repro.pipeline.gathers import GATHERS, resolve_gather
from repro.pipeline.prefetch import FeedPrefetcher, PrefetchPlan
from repro.pipeline.samplers import ShardAlignedBatchSampler
from repro.pipeline.dataplane import DataPlane, PipelineConfig, build_dataplane
from repro.pipeline.engine import ElasticConfig, Engine, build_engine
from repro.pipeline.pipeline import Pipeline, build_pipeline

__all__ = [
    "Pipeline",
    "PipelineConfig",
    "build_pipeline",
    "DataPlane",
    "build_dataplane",
    "Engine",
    "ElasticConfig",
    "build_engine",
    "FeedPrefetcher",
    "PrefetchPlan",
    "GATHERS",
    "resolve_gather",
    "ShardAlignedBatchSampler",
]
