"""Placement-aware training pipeline (dataset placement + sampler + fused step)."""
from repro.pipeline.gathers import GATHERS, resolve_gather
from repro.pipeline.samplers import ShardAlignedBatchSampler
from repro.pipeline.pipeline import Pipeline, PipelineConfig, build_pipeline

__all__ = [
    "Pipeline",
    "PipelineConfig",
    "build_pipeline",
    "GATHERS",
    "resolve_gather",
    "ShardAlignedBatchSampler",
]
