"""DataPlane — the per-rank half of the pipeline: placement → sampler → feeds.

The data plane owns everything that decides *which window ids reach which
worker*: dataset placement (``core/distributed.series_sharding``), the
matching sampler, and the deterministic per-process feed
``feed(rank, epoch) -> [steps, batch_per_rank]`` built on the samplers'
first-class feed contract.  ``epoch_global`` is kept only as the single-host
assembly of the per-rank feed columns (rank-major) — the lock-step SPMD
simulation the tests verify equal to ``concat([feed(r, e) ...], axis=1)``.

It deliberately knows nothing about the jitted step, checkpoints, or
topology changes — that is the :class:`repro.pipeline.engine.Engine`'s job.
A data plane is cheap to rebuild, which is exactly what the engine does on an
elastic re-mesh: same dataset, new mesh/world, new sampler.

==============  ==========================  =================================
Placement       series sharding             sampler
==============  ==========================  =================================
REPLICATED      ``P()`` (every device)      GlobalShuffleSampler
PARTITIONED     ``P(data axes)`` on time    ShardAlignedBatchSampler (per-rank
                                            partitions on the device shard
                                            boundaries; falls back to the
                                            contiguous count-split when the
                                            train split leaves ranks empty)
ONDEMAND        ``P(data axes)`` on time    GlobalShuffleSampler (global
                                            draws — the measured DDP baseline
                                            whose gathers cross shards)
==============  ==========================  =================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.distributed import (Placement, batch_sharding, dp_size,
                                    series_sharding)
from repro.core.index_dataset import IndexDataset
from repro.core.sampler import (GlobalShuffleSampler, LocalBatchShuffleSampler,
                                ShardInfo)
from repro.core.windows import WindowSpec
from repro.optim import AdamConfig
from repro.pipeline.samplers import ShardAlignedBatchSampler
from repro.train.loop import TrainLoopConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything the pipeline decides beyond the data/model themselves."""

    batch_per_rank: int = 8
    placement: Placement = Placement.REPLICATED
    gather: str = "slice"  # slice | take | fused | pallas | auto | lm
    seed: int = 0
    # Worker count for the sampler.  None = the mesh's data-parallel size;
    # benchmarks override it to simulate w lock-step SPMD workers on a small
    # host mesh (the global batch is then world × batch_per_rank).
    world: int | None = None
    # PARTITIONED partitioning: "aligned" places each rank's windows on its
    # device's series-shard boundaries (local gathers; falls back to the
    # count-split when a rank's shard holds no train windows); "count" forces
    # the equal count-split (the paper's Table-5 local-batch-shuffling arm,
    # equal per-rank training budget, approximate locality only).
    partition: str = "aligned"
    # PARTITIONED window domain (core/distributed.local_window_ids): halo=True
    # lets a rank's windows spill span−1 steps into the next shard (full
    # coverage, bounded neighbour exchange); halo=False keeps windows strictly
    # interior — zero data communication, slightly fewer samples (the paper's
    # communication-free claim; see launch/dryrun.py --halo-evidence).
    halo: bool = True
    adam: AdamConfig = AdamConfig()
    schedule: Callable[[Any], Any] | None = None  # step -> lr; None = adam.lr
    loop: TrainLoopConfig = TrainLoopConfig()


def _make_sampler(config: PipelineConfig, ds: IndexDataset, world: int):
    shard = ShardInfo(0, world)
    if config.placement is Placement.PARTITIONED:
        if config.partition == "aligned":
            # Per-rank partitions aligned to the series time-shards, so each
            # rank's gathers stay inside the shard its device owns (§5.4).
            try:
                return ShardAlignedBatchSampler(
                    ds.entries, ds.spec, ds.train_windows,
                    config.batch_per_rank, world, seed=config.seed,
                    halo=config.halo)
            except ValueError:
                # A rank's shard holds no (or too few) train windows — e.g.
                # the 70/10/20 split leaves the val/test-tail ranks empty,
                # or stride > 1.  Fall back to the contiguous count-split,
                # whose boundaries only approximate the device shards (some
                # gathers cross shards) — widen the train fraction if strict
                # locality matters.
                pass
        elif config.partition != "count":
            raise ValueError(f"unknown partition {config.partition!r}; "
                             "expected 'aligned' or 'count'")
        return LocalBatchShuffleSampler(ds.train_windows, config.batch_per_rank,
                                        shard, seed=config.seed)
    # REPLICATED: the paper's communication-free global shuffle.
    # ONDEMAND: same global draws over a time-sharded series — every gather
    # crosses shard boundaries; kept as the measured DDP baseline.
    return GlobalShuffleSampler(ds.train_windows, config.batch_per_rank, shard,
                                seed=config.seed)


@dataclasses.dataclass
class DataPlane:
    """A placed dataset + matching sampler + deterministic per-rank feeds."""

    config: PipelineConfig
    mesh: Mesh
    spec: WindowSpec
    dataset: IndexDataset
    sampler: Any
    series_sharding: NamedSharding
    world: int
    batch_sharding: NamedSharding | None
    # split -> (tail_len, replicated device batch | None): the ragged eval
    # tail is identical every evaluate call, so its device row is built once
    # per data plane (a re-mesh builds a fresh plane, naturally invalidating
    # the cache).  See :meth:`eval_tail_batch`.
    _eval_tail_cache: dict = dataclasses.field(default_factory=dict,
                                               repr=False, compare=False)

    # ------------------------------------------------------------- accessors
    @property
    def steps_per_epoch(self) -> int:
        return self.sampler.steps_per_epoch

    @property
    def global_batch(self) -> int:
        return self.config.batch_per_rank * self.world

    @property
    def process_ranks(self) -> list[int] | None:
        """Feed ranks this process owns under ``jax.distributed``; None when
        the run is single-process (lock-step simulation via ``epoch_global``).

        Assumes the standard mesh construction (devices ordered by process):
        process p owns the contiguous block of ``world / process_count`` feed
        ranks aligned with its addressable series/batch shards — one rank per
        process when every host drives a single data-parallel slot, several
        when a host's processes own multiple device shards.
        """
        pc = jax.process_count()
        if pc <= 1:
            return None
        if self.world % pc:
            raise NotImplementedError(
                f"world {self.world} is not divisible by the process count "
                f"{pc}; per-process feeds need world % processes == 0")
        per = self.world // pc
        p = jax.process_index()
        return list(range(p * per, (p + 1) * per))

    def describe(self) -> dict:
        """The placement contract this data plane instantiated (testable)."""
        return {
            "placement": self.config.placement,
            "sampler": type(self.sampler).__name__,
            "series_spec": tuple(self.series_sharding.spec),
            "gather": self.config.gather,
            "world": self.world,
            "global_batch": self.global_batch,
            "halo": self.config.halo,
        }

    # ----------------------------------------------------------------- feeds
    def feed(self, rank: int, epoch: int) -> np.ndarray:
        """[steps, batch_per_rank] window ids for ``rank`` — the per-process
        index feed, a pure function of (seed, epoch, rank)."""
        return self.sampler.feed(rank, epoch)

    def epoch_global(self, epoch: int) -> np.ndarray:
        """[steps, world*batch] — single-host assembly of the feed columns."""
        return self.sampler.epoch_global(epoch)

    def epoch_grid(self, epoch: int) -> np.ndarray:
        """What the train loop iterates this epoch: the full global grid in
        single-process mode, the concatenation of this process's own feed
        columns under multi-process SPMD (no process ever materialises the
        global index grid)."""
        ranks = self.process_ranks
        if ranks is None:
            return self.epoch_global(epoch)
        return np.concatenate([self.feed(r, epoch) for r in ranks], axis=1)

    def feed_stream(self, rank: int, epoch: int, *, start: int = 0,
                    chunk: int = 8):
        """Chunk-iterable ``feed(rank, epoch)`` (see
        :class:`repro.core.sampler.FeedStream`): yields ``[<=chunk, batch]``
        row blocks that concatenate exactly to the feed, beginning at row
        ``start``."""
        return self.sampler.feed_stream(rank, epoch, start=start, chunk=chunk)

    def grid_stream(self, epoch: int, *, start: int = 0, chunk: int = 8):
        """Chunk-iterable :meth:`epoch_grid`: ``[<=chunk, width]`` row blocks
        of what THIS process iterates, beginning at row ``start``.

        This is the host half of the prefetch pipeline's contract — pure
        numpy, safe to drain from a background thread.  Under multi-process
        SPMD each block is the concatenation of this process's per-rank
        ``feed_stream`` blocks (all streams share start/chunk, so the blocks
        are row-aligned); single-process it slices ``epoch_global`` directly.
        Either way the blocks reassemble exactly to ``epoch_grid(epoch)`` —
        the invariant test_feeds_property pins.
        """
        ranks = self.process_ranks
        if ranks is None:
            grid = self.epoch_global(epoch)
            for lo in range(start, grid.shape[0], chunk):
                yield grid[lo:lo + chunk]
            return
        streams = [self.sampler.feed_stream(r, epoch, start=start, chunk=chunk)
                   for r in ranks]
        for blocks in zip(*streams):
            yield np.concatenate(blocks, axis=1)

    # ------------------------------------------------------------ eval feeds
    def eval_pool(self, split: str = "val") -> np.ndarray:
        """The split's global window-id pool (``val_windows``/``test_windows``)."""
        return np.asarray(getattr(self.dataset, f"{split}_windows"))

    def eval_feed(self, rank: int, split: str = "val") -> np.ndarray:
        """[steps, batch_per_rank] eval window ids for ``rank`` — the eval
        mirror of :meth:`feed`: rank ``rank``'s column block of the split
        pool's full global chunks, in pool order (no shuffle, no epoch)."""
        return self.sampler.eval_feed(rank, self.eval_pool(split))

    def eval_tail(self, split: str = "val") -> np.ndarray:
        """The split's ragged remainder — global, identical on every rank."""
        return self.sampler.eval_tail(self.eval_pool(split))

    def eval_grid(self, split: str = "val") -> tuple[np.ndarray, np.ndarray]:
        """``(rows, tail)`` — what THIS process iterates when evaluating.

        ``rows`` is the full-chunk grid: global ``[steps, world*batch]`` in
        single-process mode, the concatenation of this process's own
        ``eval_feed`` columns under multi-process SPMD (each process
        materialises only its rank-block of every chunk).  ``tail`` is the
        global ragged remainder, scored once as a replicated small batch.
        """
        pool = self.eval_pool(split)
        tail = self.sampler.eval_tail(pool)
        ranks = self.process_ranks
        if ranks is None:
            return self.sampler.eval_global(pool), tail
        return np.concatenate(
            [self.sampler.eval_feed(r, pool) for r in ranks], axis=1), tail

    def eval_tail_batch(self, split: str = "val"):
        """``(tail_len, replicated device batch | None)`` for the split's
        ragged eval tail — built ONCE per data plane and cached.

        The tail is a pure function of the split pool (no epoch, no
        shuffle), so re-running ``batch_of_starts(tail, replicate=True)``
        every evaluate call only repeats the same host→device transfer; the
        cache keeps the replicated row resident instead.  A re-mesh rebuilds
        the whole plane, so the cache can never serve a stale topology.
        """
        hit = self._eval_tail_cache.get(split)
        if hit is None:
            tail = self.eval_tail(split)
            batch = (self.batch_of_starts(tail, replicate=True)
                     if len(tail) else None)
            hit = (len(tail), batch)
            self._eval_tail_cache[split] = hit
        return hit

    # --------------------------------------------------------- data plumbing
    def host_batch_of_starts(self, window_ids: np.ndarray) -> np.ndarray:
        """Window ids -> HOST array of start steps (the batch, uncommitted).

        The bounded-stale transfer mode (:meth:`prefetch_transfer`,
        staleness >= 1): batch construction happens here — on the prefetch
        thread, ahead of consumption — and the host→device commit rides the
        jitted step's own dispatch, which enqueues it into the async stream
        while the PREVIOUS step's computation is still in flight.  On this
        runtime the Python-side ``device_put`` of a small starts row costs
        an order of magnitude more caller time than committing the same row
        inside dispatch, so this is where the pipeline's measured step-time
        win comes from (benchmarks/smoke.py records it, trend.py gates it).
        Values are identical either way — same bytes reach the same
        compiled program.
        """
        return np.asarray(self.dataset.starts[np.asarray(window_ids)])

    def can_defer_transfer(self) -> bool:
        """Whether the step can commit a HOST batch during its own dispatch:
        single-process with no batch sharding (one device).  Sharded or
        multi-process batches need the explicit assembly in
        :meth:`batch_of_starts` (``make_array_from_process_local_data``) —
        handing jit a raw host row there would let it pick a placement
        instead of the data plane."""
        return jax.process_count() == 1 and self.batch_sharding is None

    def prefetch_transfer(self, staleness: int):
        """The transfer fn the :class:`~repro.pipeline.prefetch.FeedPrefetcher`
        should run for this staleness.

        ``staleness == 0`` — :meth:`batch_of_starts`, on the consumer
        thread: the synchronous path's exact op order (the provable
        bit-identity).  ``staleness >= 1`` — the deferred host-batch mode
        when the topology allows it, else still :meth:`batch_of_starts`
        (just moved onto the transfer thread).
        """
        if staleness >= 1 and self.can_defer_transfer():
            return self.host_batch_of_starts
        return self.batch_of_starts

    def batch_of_starts(self, window_ids: np.ndarray, *,
                        replicate: bool = False) -> jnp.ndarray:
        """Window ids (one epoch grid row) -> device array of start steps.

        Multi-process runs hand per-process rows (this rank's feed columns)
        and assemble the global sharded array from process-local data; the
        single-process path device_puts the already-global row.

        ``replicate=True`` is the ragged-eval-tail path: ``window_ids`` is a
        GLOBAL row every process derived identically, and the batch stays
        replicated in both single- and multi-process runs — same program,
        same reduction grouping, bit-identical tail metrics.
        """
        starts_np = np.asarray(self.dataset.starts[np.asarray(window_ids)])
        if replicate:
            if jax.process_count() > 1:
                shd = NamedSharding(self.mesh, PartitionSpec())
                return jax.make_array_from_callback(
                    starts_np.shape, shd, lambda idx: starts_np[idx])
            return jnp.asarray(starts_np)
        ranks = self.process_ranks
        if ranks is not None and self.batch_sharding is not None:
            local_width = len(ranks) * self.config.batch_per_rank
            if starts_np.shape[0] != local_width:
                # Only per-process feed rows have process-local semantics;
                # treating a GLOBAL row as local data would assemble a
                # duplicated wrong-shaped batch.  Eval chunks ride the
                # eval_grid feed columns; the ragged tail passes
                # replicate=True.
                raise NotImplementedError(
                    f"under jax.distributed, batch_of_starts expects this "
                    f"process's feed row of width {local_width}, got "
                    f"{starts_np.shape[0]}; hand global rows through "
                    f"replicate=True instead")
            return jax.make_array_from_process_local_data(
                self.batch_sharding, starts_np)
        starts = jnp.asarray(starts_np)
        # Ragged eval tails may not divide the data axis — leave those
        # replicated (jit re-shards as needed) rather than fail the put.
        if self.batch_sharding is not None \
                and starts.shape[0] % max(dp_size(self.mesh), 1) == 0:
            starts = jax.device_put(starts, self.batch_sharding)
        return starts

    # --------------------------------------------------------------- elastic
    def remesh(self, mesh: Mesh, *, world: int, batch_per_rank: int) -> "DataPlane":
        """Rebuild this data plane for a new topology (elastic shrink OR
        grow — the direction only changes the mesh/world handed in).

        Re-places the series via ``series_sharding`` on the new mesh and
        rebuilds the sampler for the new world size; the dataset's windows,
        splits and scaler are untouched so (seed, epoch) determinism holds.
        Single-host only: re-materialising the series needs every shard
        addressable (a real multi-process fleet relaunches instead —
        ``ElasticConfig(remesh="relaunch")`` — and the new gang re-places
        from storage).
        """
        config = dataclasses.replace(self.config, world=world,
                                     batch_per_rank=batch_per_rank)
        host_ds = dataclasses.replace(self.dataset,
                                      series=np.asarray(self.dataset.series))
        return build_dataplane(None, self.spec, mesh, config, dataset=host_ds)


def build_dataplane(
    raw: np.ndarray | None,
    spec: WindowSpec,
    mesh: Mesh,
    config: PipelineConfig = PipelineConfig(),
    *,
    dataset: IndexDataset | None = None,
) -> DataPlane:
    """Place the dataset and pair it with the placement's sampler.

    Pass ``dataset=`` to reuse an already-built ``IndexDataset`` (it will
    still be (re)placed for the chosen placement); otherwise ``raw`` is
    windowed/standardised into one.
    """
    world = config.world if config.world is not None else max(dp_size(mesh), 1)
    sharding = series_sharding(mesh, config.placement)
    ds = dataset if dataset is not None else IndexDataset.from_raw(raw, spec)
    ds = ds.to_device(sharding)
    sampler = _make_sampler(config, ds, world)
    batch_shd = batch_sharding(mesh) if mesh.size > 1 else None
    return DataPlane(config=config, mesh=mesh, spec=spec, dataset=ds,
                     sampler=sampler, series_sharding=sharding, world=world,
                     batch_sharding=batch_shd)
