"""Placement-aware training pipeline — the paper's workflow as one subsystem.

``build_pipeline`` takes ``(raw series, WindowSpec, mesh, model loss_fn)`` and
returns a ready-to-run trainer.  It owns every decision the examples and
benchmark harnesses used to re-glue by hand, keeping the dataset placement,
the sampler and the jitted gather/step in agreement with one definition
(``core/distributed.py``):

==============  ==========================  =================================
Placement       series sharding             sampler
==============  ==========================  =================================
REPLICATED      ``P()`` (every device)      GlobalShuffleSampler.epoch_global
PARTITIONED     ``P(data axes)`` on time    ShardAlignedBatchSampler (per-rank
                                            partitions on the device shard
                                            boundaries, shuffled batch order;
                                            falls back to the contiguous
                                            count-split when the train split
                                            leaves tail ranks empty)
ONDEMAND        ``P(data axes)`` on time    GlobalShuffleSampler (global
                                            draws — the measured DDP baseline
                                            whose gathers cross shards)
==============  ==========================  =================================

The window gather (``slice`` / ``take`` / ``fused`` / ``pallas``, see
``pipeline/gathers.py``) is fused into the jitted train step: the host only
ever ships int32 window starts; batches are reconstructed on-device from the
resident series.  ``Pipeline.fit`` drives ``run_training`` with deterministic
(seed, epoch) sampling plus step-granular checkpoints, so a kill-and-resume
run is bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.distributed import (Placement, batch_sharding, dp_size,
                                    series_sharding)
from repro.core.index_dataset import IndexDataset
from repro.core.sampler import (GlobalShuffleSampler, LocalBatchShuffleSampler,
                                ShardInfo)
from repro.core.windows import WindowSpec
from repro.distributed import Checkpointer, latest_step, restore
from repro.optim import AdamConfig
from repro.pipeline.gathers import resolve_gather
from repro.pipeline.samplers import ShardAlignedBatchSampler
from repro.train.loop import (TrainLoopConfig, init_train_state,
                              make_train_step, run_training)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything the pipeline decides beyond the data/model themselves."""

    batch_per_rank: int = 8
    placement: Placement = Placement.REPLICATED
    gather: str = "slice"  # slice | take | fused | pallas
    seed: int = 0
    # Worker count for the sampler.  None = the mesh's data-parallel size;
    # benchmarks override it to simulate w lock-step SPMD workers on a small
    # host mesh (the global batch is then world × batch_per_rank).
    world: int | None = None
    # PARTITIONED partitioning: "aligned" places each rank's windows on its
    # device's series-shard boundaries (local gathers; falls back to the
    # count-split when a rank's shard holds no train windows); "count" forces
    # the equal count-split (the paper's Table-5 local-batch-shuffling arm,
    # equal per-rank training budget, approximate locality only).
    partition: str = "aligned"
    adam: AdamConfig = AdamConfig()
    schedule: Callable[[Any], Any] | None = None  # step -> lr; None = adam.lr
    loop: TrainLoopConfig = TrainLoopConfig()


@dataclasses.dataclass
class Pipeline:
    """A placed dataset + matching sampler + fused jitted step, ready to run."""

    config: PipelineConfig
    mesh: Mesh
    spec: WindowSpec
    dataset: IndexDataset
    sampler: Any
    series_sharding: NamedSharding
    train_step: Callable
    init_params: Any
    world: int
    _eval_loss: Callable  # jitted (params, starts) -> (loss, metrics)
    _batch_sharding: NamedSharding | None

    # ------------------------------------------------------------- accessors
    @property
    def steps_per_epoch(self) -> int:
        return self.sampler.steps_per_epoch

    @property
    def global_batch(self) -> int:
        return self.config.batch_per_rank * self.world

    def describe(self) -> dict:
        """The placement contract this pipeline instantiated (testable)."""
        return {
            "placement": self.config.placement,
            "sampler": type(self.sampler).__name__,
            "series_spec": tuple(self.series_sharding.spec),
            "gather": self.config.gather,
            "world": self.world,
            "global_batch": self.global_batch,
        }

    # ------------------------------------------------------------ data plumbing
    def batch_of_starts(self, window_ids: np.ndarray) -> jnp.ndarray:
        """Window ids (one epoch_global row) -> device array of start steps."""
        starts = jnp.asarray(self.dataset.starts[np.asarray(window_ids)])
        if self._batch_sharding is not None:
            starts = jax.device_put(starts, self._batch_sharding)
        return starts

    # --------------------------------------------------------------- training
    def fit(
        self,
        *,
        epochs: int | None = None,
        eval_fn: Callable[[Any], dict] | None | str = "auto",
        resume: bool = True,
    ) -> tuple[Any, list[dict]]:
        """Train (resuming from ``loop.ckpt_dir`` when a checkpoint exists).

        Returns ``(state, history)`` exactly like ``run_training``.
        ``eval_fn="auto"`` evaluates val-split MAE at every epoch end.
        """
        loop = self.config.loop
        if epochs is not None:
            loop = dataclasses.replace(loop, epochs=epochs)
        # Copy params into the fresh state: the jitted step donates its state
        # argument, and aliasing the caller's arrays would delete them after
        # the first step (breaking re-fits and sibling pipelines).
        params = jax.tree.map(jnp.copy, self.init_params)
        state = init_train_state(params, self.config.adam)
        checkpointer = Checkpointer(loop.ckpt_dir) if loop.ckpt_dir else None
        start_step = 0
        if resume and loop.ckpt_dir and latest_step(loop.ckpt_dir) is not None:
            state, start_step = restore(loop.ckpt_dir, state)
        if eval_fn == "auto":
            has_val = len(self.dataset.val_windows) > 0
            eval_fn = (lambda st: {"val_mae": self.evaluate(st["params"])}) \
                if has_val else None
        return run_training(
            state=state,
            train_step=self.train_step,
            sampler=self.sampler,
            batch_of_starts=self.batch_of_starts,
            loop=loop,
            eval_fn=eval_fn,
            checkpointer=checkpointer,
            start_epoch=start_step // self.sampler.steps_per_epoch,
            start_step=start_step,
        )

    def evaluate(self, params, *, split: str = "val", max_batches: int = 4) -> float:
        """Mean loss over up to ``max_batches`` global batches of a split.

        A split smaller than one global batch is evaluated as a single
        smaller batch (recompiles the eval loss once) rather than skipped.
        """
        pool = getattr(self.dataset, f"{split}_windows")
        if len(pool) == 0:
            return float("nan")
        b = min(self.global_batch, len(pool))
        losses = []
        for i in range(0, min(len(pool), max_batches * b) - b + 1, b):
            loss, _ = self._eval_loss(params, self.batch_of_starts(pool[i:i + b]))
            losses.append(float(loss))
        return float(np.mean(losses))


def _make_sampler(config: PipelineConfig, ds: IndexDataset, world: int):
    shard = ShardInfo(0, world)
    if config.placement is Placement.PARTITIONED:
        if config.partition == "aligned":
            # Per-rank partitions aligned to the series time-shards, so each
            # rank's gathers stay inside the shard its device owns (§5.4).
            try:
                return ShardAlignedBatchSampler(
                    ds.entries, ds.spec, ds.train_windows,
                    config.batch_per_rank, world, seed=config.seed)
            except ValueError:
                # A rank's shard holds no (or too few) train windows — e.g.
                # the 70/10/20 split leaves the val/test-tail ranks empty,
                # or stride > 1.  Fall back to the contiguous count-split,
                # whose boundaries only approximate the device shards (some
                # gathers cross shards) — widen the train fraction if strict
                # locality matters.
                pass
        elif config.partition != "count":
            raise ValueError(f"unknown partition {config.partition!r}; "
                             "expected 'aligned' or 'count'")
        return LocalBatchShuffleSampler(ds.train_windows, config.batch_per_rank,
                                        shard, seed=config.seed)
    # REPLICATED: the paper's communication-free global shuffle.
    # ONDEMAND: same global draws over a time-sharded series — every gather
    # crosses shard boundaries; kept as the measured DDP baseline.
    return GlobalShuffleSampler(ds.train_windows, config.batch_per_rank, shard,
                                seed=config.seed)


def build_pipeline(
    raw: np.ndarray,
    spec: WindowSpec,
    mesh: Mesh,
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], tuple[jnp.ndarray, dict]],
    init_params: Any,
    config: PipelineConfig = PipelineConfig(),
    *,
    dataset: IndexDataset | None = None,
) -> Pipeline:
    """Assemble the full placement-aware trainer.

    ``loss_fn(params, x, y) -> (loss, metrics)`` is the only model-specific
    piece; the pipeline supplies (x, y) by fusing the selected window gather
    into the jitted step.  Pass ``dataset=`` to reuse an already-built
    ``IndexDataset`` (it will still be (re)placed for the chosen placement).
    """
    world = config.world if config.world is not None else max(dp_size(mesh), 1)
    sharding = series_sharding(mesh, config.placement)
    ds = dataset if dataset is not None else IndexDataset.from_raw(raw, spec)
    ds = ds.to_device(sharding)
    sampler = _make_sampler(config, ds, world)
    gather = resolve_gather(config.gather)

    def starts_loss(params, starts):
        x, y = gather(ds.series, starts, input_len=spec.in_len,
                      horizon=spec.horizon)
        return loss_fn(params, x, y)

    schedule = config.schedule or (lambda s: config.adam.lr)
    loop = config.loop
    train_step = make_train_step(
        starts_loss, config.adam, schedule,
        microbatches=loop.microbatches, grad_dtype=loop.grad_dtype,
        donate=loop.donate)
    batch_shd = batch_sharding(mesh) if mesh.size > 1 else None
    return Pipeline(
        config=config, mesh=mesh, spec=spec, dataset=ds, sampler=sampler,
        series_sharding=sharding, train_step=train_step,
        init_params=init_params, world=world,
        _eval_loss=jax.jit(starts_loss), _batch_sharding=batch_shd)
