"""Compatibility layer: the original ``Pipeline`` API over DataPlane+Engine.

The monolithic ``Pipeline`` was split into two layers:

- :mod:`repro.pipeline.dataplane` — placement → sampler → per-rank feeds
  (``feed(rank, epoch)``), with ``epoch_global`` kept as the single-host
  assembly of the feed columns;
- :mod:`repro.pipeline.engine` — the jitted gather/step, checkpointing,
  topology, and the elastic shrink-and-resume loop.

``build_pipeline`` remains the one-call constructor every example and
benchmark uses; it returns an :class:`~repro.pipeline.engine.Engine`, which
keeps the whole legacy surface (``.fit``, ``.evaluate``, ``.sampler``,
``.dataset``, ``.describe()``, ``.batch_of_starts``, ``.train_step``, …).
"""
from __future__ import annotations

from typing import Any, Callable

import numpy as np
from jax.sharding import Mesh

from repro.core.index_dataset import IndexDataset
from repro.core.windows import WindowSpec
from repro.pipeline.dataplane import DataPlane, PipelineConfig, build_dataplane
from repro.pipeline.engine import ElasticConfig, Engine, build_engine

#: The legacy name: an assembled trainer IS the engine now.
Pipeline = Engine


def build_pipeline(
    raw: np.ndarray,
    spec: WindowSpec,
    mesh: Mesh,
    loss_fn: Callable,
    init_params: Any,
    config: PipelineConfig = PipelineConfig(),
    *,
    dataset: IndexDataset | None = None,
    elastic: ElasticConfig | None = None,
) -> Engine:
    """Thin compatibility constructor — see :func:`build_engine`."""
    return build_engine(raw, spec, mesh, loss_fn, init_params, config,
                        dataset=dataset, elastic=elastic)


__all__ = ["Pipeline", "PipelineConfig", "build_pipeline", "DataPlane",
           "build_dataplane", "Engine", "ElasticConfig", "build_engine"]
