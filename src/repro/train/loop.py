"""Training loop: index-batched steps, microbatch accumulation, checkpointing.

The step function is the paper's workflow fused into one jitted SPMD program:

    starts --(window gather from the RESIDENT series)--> (x, y) --> loss
           --> grads --(all-reduce inserted by the partitioner)--> Adam

i.e. distributed-index-batching: the host only ever ships int32 window starts
to the device; the series was placed once (GPU-index-batching) and every
worker gathers its own batch locally.

Microbatch gradient accumulation (``microbatches > 1``) scans over microbatch
slices; besides fitting memory this overlaps per-microbatch compute with the
final cross-pod gradient reduce.  ``grad_dtype="bfloat16"`` compresses the
gradient tree before the all-reduce (the cross-pod axis is the slow link) —
the distributed-optimization knobs the 1000-node posture calls for.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamConfig, apply_updates, init_opt_state


class RestartSignal(Exception):
    """Raised by a ``health_cb`` to request an engine-level restart.

    ``run_training`` checkpoints the in-flight state (so no step is lost),
    annotates the signal with everything the engine needs to re-mesh and
    resume — ``state``, ``history``, ``epoch``, ``step`` — and re-raises.
    """

    def __init__(self, plan=None, reason: str = ""):
        super().__init__(reason or getattr(plan, "reason", "restart requested"))
        self.plan = plan
        self.state = None
        self.history: list[dict] = []
        self.epoch = 0
        self.step = 0
        # Set by Engine.fit before re-raising in relaunch mode: whether the
        # raising process is the current LEADER (the one whose checkpoint
        # coordinates are durable and who should emit the plan).  True by
        # default so non-engine raisers keep the old single-process behavior.
        self.leader = True


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    epochs: int = 1
    log_every: int = 50
    ckpt_every: int = 0  # steps; 0 = only at end
    ckpt_dir: str | None = None
    microbatches: int = 1
    grad_dtype: str | None = None  # "bfloat16" compresses grads pre-all-reduce
    donate: bool = True
    # Epoch-end eval cadence: run eval_fn after every N-th epoch (1 = every
    # epoch, the historical behavior; 0 = never, even with an eval_fn).
    # Epoch-indexed, not call-counted, so a relaunch-resume keeps the cadence.
    eval_every: int = 1
    # Async feed prefetch (repro.pipeline.prefetch).  prefetch_depth 0 keeps
    # the synchronous pull-per-step path; >= 1 streams batches through a
    # FeedPrefetcher that materializes feed rows `depth` chunks ahead on a
    # background thread.  staleness 0 transfers at consume on the caller
    # thread — bit-identical to the synchronous path; staleness s >= 1 lets
    # the host→device transfer for step k+s overlap step k's computation.
    prefetch_depth: int = 0
    staleness: int = 0
    prefetch_chunk: int = 8


def combine_weighted(pairs) -> float:
    """Reduce ``(metric, weight)`` pairs to their weighted mean.

    This is the psum-style combine the evaluation paths share: each full
    eval chunk contributes ``(chunk_loss, chunk_windows)`` and the ragged
    tail ``(tail_loss, tail_windows)``.  Accumulated in float64 in pair
    order, so the single-host reference and the distributed per-rank-feed
    path perform the exact same arithmetic — bit-identical results.
    """
    weighted_sum = np.float64(0.0)
    weight = np.float64(0.0)
    for value, w in pairs:
        weighted_sum += np.float64(value) * np.float64(w)
        weight += np.float64(w)
    return float(weighted_sum / weight) if weight else float("nan")


class JsonlHistorySink:
    """Crash-durable, resume-idempotent history sink (one JSON row per line).

    Drop-in for the plain-list ``history_sink``: every logged row is appended
    to ``path`` and flushed+fsynced as it lands, so rows survive hard crashes
    (a peer death surfaces as a collective error, not a clean return).  On
    construction it reloads the rows already durable from a previous
    incarnation and silently drops re-logged duplicates — an exit-75
    relaunch that restores a mid-epoch checkpoint re-RUNS the tail of the
    epoch (training needs the steps), but its step rows and the epoch
    summary (including eval metrics) carry the same ``(epoch, step)``
    coordinates and must not appear twice in the durable history.

    ``rows`` holds only the rows ACCEPTED this incarnation (what this
    process actually contributed); ``load()`` returns the full durable
    history across all incarnations.

    Dedup is FIRST-WINS on coordinates, which leans on the repo's
    deterministic-resume contract: a resume that re-runs (epoch, step)
    recomputes the identical row (samplers are pure in (seed, epoch) and
    the global batch is preserved across relaunches), so keeping the
    already-durable copy is exact.  A re-mesh that CHANGES the global batch
    (``keep_global_batch`` ceil on a non-dividing world) breaks that
    premise — re-run coordinates then carry different losses and the sink
    keeps the pre-crash values; the returned ``fit`` history is the
    authoritative trajectory in that case.
    """

    def __init__(self, path: str):
        self.path = path
        self.rows: list[dict] = []
        self._seen: set = set()
        rows, durable_end = self._scan(path)
        for row in rows:
            self._seen.add(self._key(row))
        if durable_end is not None:
            # Drop the torn tail a crash mid-write left behind: it was never
            # durable (the row will be re-logged on resume), and appending
            # after a partial line would corrupt the NEXT row too.
            with open(path, "r+") as f:
                f.truncate(durable_end)
        self._f = open(path, "a")

    @staticmethod
    def _key(row: dict) -> tuple:
        kind = "summary" if "epoch_time_s" in row else "step"
        return (kind, row.get("epoch"), row.get("step"))

    @staticmethod
    def _scan(path: str) -> tuple[list[dict], int | None]:
        """(durable rows, truncation offset): a row is durable only when its
        line parses AND is newline-terminated; the offset points past the
        last such line when anything torn follows, else None."""
        if not os.path.exists(path):
            return [], None
        with open(path, "rb") as f:
            data = f.read()
        rows, offset, pos = [], 0, 0
        for line in data.splitlines(keepends=True):
            pos += len(line)
            if not line.endswith(b"\n"):
                break
            text = line.decode("utf-8", "replace").strip()
            if not text:
                offset = pos
                continue
            try:
                rows.append(json.loads(text))
            except ValueError:
                break
            offset = pos
        return rows, (offset if offset < len(data) else None)

    def append(self, row: dict) -> bool:
        key = self._key(row)
        if key in self._seen:
            return False
        self._seen.add(key)
        self.rows.append(row)
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        return True

    def load(self) -> list[dict]:
        """All durable rows, across every incarnation, in logged order."""
        return self._scan(self.path)[0]

    def close(self) -> None:
        self._f.close()


def zero_grads_like(params, grad_dtype: str | None):
    """Zero tree for microbatch gradient accumulation.

    Each leaf takes the dtype the gradients will actually have — the
    ``grad_dtype`` compression target when set, else the param leaf's own
    dtype.  (A float32 default would silently up-cast bf16/f16 gradient
    trees through ``jnp.add``'s promotion inside the scan.)
    """
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, grad_dtype or p.dtype), params)


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jnp.ndarray, dict]],
    adam: AdamConfig,
    schedule: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    microbatches: int = 1,
    grad_dtype: str | None = None,
    donate: bool = True,
    in_shardings=None,
    out_shardings=None,
):
    """Build the jitted train step.

    loss_fn(params, batch) -> (loss, metrics).  ``batch`` is any pytree whose
    leaves have a leading per-step batch dim (divisible by ``microbatches``).
    Returns step(state, batch) -> (state, metrics).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if grad_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        return loss, metrics, grads

    def step(state, batch):
        params, opt_state = state["params"], state["opt"]
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i], batch)

            def acc_step(carry, i):
                loss_a, grads_a = carry
                loss, _, grads = grads_of(params, slice_mb(i))
                return (loss_a + loss,
                        jax.tree.map(jnp.add, grads_a, grads)), None

            zero_g = zero_grads_like(params, grad_dtype)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero_g), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {}
        lr = schedule(opt_state["step"])
        new_params, new_opt, gnorm = apply_updates(params, grads, opt_state, adam, lr)
        out_metrics = {"loss": loss, "lr": lr, **metrics}
        if gnorm is not None:
            out_metrics["grad_norm"] = gnorm
        return {"params": new_params, "opt": new_opt}, out_metrics

    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(step, donate_argnums=(0,) if donate else (), **kw)


def init_train_state(params, adam: AdamConfig):
    return {"params": params, "opt": init_opt_state(params, adam)}


def run_training(
    *,
    state,
    train_step,
    sampler,
    batch_of_starts: Callable[[np.ndarray], Any],
    loop: TrainLoopConfig,
    eval_fn: Callable[[Any], dict] | None = None,
    checkpointer=None,
    start_epoch: int = 0,
    start_step: int = 0,
    start_done_in_epoch: int | None = None,
    health_cb: Callable[[int], None] | None = None,
    history_sink: list | None = None,
    batch_stream: Callable[[int, int], Any] | None = None,
) -> tuple[Any, list[dict]]:
    """Generic epoch loop.

    ``sampler.epoch_global(e)`` yields [steps, global_batch] window starts
    (``sampler.epoch_grid(e)`` is preferred when present — a DataPlane
    returns only this process's feed columns under multi-process SPMD);
    ``batch_of_starts`` maps one row to the step's batch pytree (typically a
    device_put of the starts with the batch sharding — the gather itself
    happens inside the jitted step, from the resident series).
    Deterministic (seed, epoch) sampling + step-granular checkpoints mean a
    restart resumes bit-identically mid-epoch.

    ``start_done_in_epoch`` decouples the resume position from the step
    numbering: when given, ``start_epoch`` resumes after that many completed
    steps (later epochs start at 0) and ``start_step`` is ONLY the monotonic
    step counter.  Elastic restarts need this — after a re-mesh changes
    ``steps_per_epoch``, deriving the position from ``start_step`` would
    renumber checkpoints non-monotonically and ``latest_step`` could later
    resurrect a stale pre-restart checkpoint.  When None (the default), the
    position is derived from ``start_step`` as before.

    ``health_cb(global_step)`` runs after every step; it may raise
    :class:`RestartSignal` (e.g. the elastic engine's heartbeat monitor
    flagging a dead worker), in which case the loop checkpoints the current
    state with its (epoch, done_in_epoch) coordinates, annotates the signal,
    and re-raises for the engine to re-mesh and resume.

    ``history_sink``: optional caller-owned list mirroring every history row
    as it is logged.  Unlike the returned history it survives NON-elastic
    failures (a collective erroring out when a peer process dies raises
    straight through), so an external launcher can still persist the rows
    logged before the crash.  Pass a :class:`JsonlHistorySink` to make the
    rows crash-durable AND idempotent across relaunch-resumes (duplicate
    ``(epoch, step)`` rows from a re-run epoch tail are suppressed).

    ``batch_stream(epoch, done) -> iterator`` decouples the step loop from
    feed assembly: when given, each epoch's remaining batches are pulled
    from the iterator it returns (typically a
    :class:`repro.pipeline.prefetch.FeedPrefetcher` over the data plane's
    ``grid_stream``) instead of ``batch_of_starts(grid[i])`` per step.  The
    iterator must yield exactly ``steps_per_epoch - done`` device-ready
    batches — the same values the synchronous path would build.  If it has
    a ``close()`` it is drained on every exit from the epoch, normal or
    not — in particular on :class:`RestartSignal`, so an elastic re-mesh
    never leaves stale in-flight batches behind.
    """
    history: list[dict] = []
    global_step = start_step
    grid_of_epoch = getattr(sampler, "epoch_grid", sampler.epoch_global)

    def log_row(row: dict) -> None:
        history.append(row)
        if history_sink is not None:
            history_sink.append(row)

    def epoch_meta(epoch: int, done: int, steps: int) -> dict:
        """Checkpoint coordinates, normalised so a COMPLETE epoch reads as
        the start of the next one — a resume into a topology whose
        steps_per_epoch grew must not re-enter (and re-summarise) an epoch
        that already finished."""
        if done >= steps:
            return {"epoch": epoch + 1, "done_in_epoch": 0}
        return {"epoch": epoch, "done_in_epoch": done}

    def check_health(done_now: int, steps: int) -> None:
        """Poll health_cb; on RestartSignal checkpoint-and-annotate."""
        if health_cb is None:
            return
        try:
            health_cb(global_step)
        except RestartSignal as sig:
            if checkpointer is not None:
                checkpointer.save(state, step=global_step,
                                  meta=epoch_meta(epoch, done_now, steps))
                checkpointer.wait()
            sig.state, sig.history = state, history
            sig.epoch, sig.step = epoch, global_step
            raise

    for epoch in range(start_epoch, loop.epochs):
        if batch_stream is None:
            grid = grid_of_epoch(epoch)
            steps = grid.shape[0]
        else:
            grid, steps = None, sampler.steps_per_epoch
        t0 = time.perf_counter()
        # Resume mid-epoch: skip steps already done.  Clamp to [0, steps] —
        # a start_step beyond this epoch (resume past a partially-logged
        # epoch with a stale start_epoch) must skip it wholesale, not index
        # with a done-count larger than the grid.
        if start_done_in_epoch is not None:
            done_in_epoch = (min(start_done_in_epoch, steps)
                             if epoch == start_epoch else 0)
        else:
            done_in_epoch = min(
                max(global_step - epoch * sampler.steps_per_epoch, 0), steps)
        metrics = None
        batches = (batch_stream(epoch, done_in_epoch)
                   if batch_stream is not None and done_in_epoch < steps
                   else None)
        try:
            for i in range(done_in_epoch, steps):
                batch = (next(batches) if batches is not None
                         else batch_of_starts(grid[i]))
                state, metrics = train_step(state, batch)
                global_step += 1
                if loop.log_every and global_step % loop.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    log_row({"step": global_step, "epoch": epoch, **m})
                if (checkpointer is not None and loop.ckpt_every
                        and global_step % loop.ckpt_every == 0):
                    checkpointer.save(
                        state, step=global_step,
                        meta=epoch_meta(epoch, i + 1, steps))
                if i < steps - 1:
                    check_health(i + 1, steps)
        finally:
            # Drain the stream on EVERY exit — epoch end, RestartSignal, or
            # a peer-death collective error — so no prefetch thread is left
            # pulling feeds for a topology about to be re-meshed.
            close = getattr(batches, "close", None)
            if close is not None:
                close()
        if metrics is None:
            continue  # every step was already done on resume: nothing to log
        epoch_metrics = {"epoch": epoch, "epoch_time_s": time.perf_counter() - t0,
                         "step": global_step,
                         "loss": float(metrics["loss"])}
        if eval_fn is not None and loop.eval_every \
                and (epoch + 1) % loop.eval_every == 0:
            epoch_metrics.update(eval_fn(state))
        log_row(epoch_metrics)
        # The final step's health poll runs AFTER the epoch summary: a
        # restart landing exactly on the epoch boundary would otherwise
        # abort before the summary/eval row and the resumed run — which
        # starts at the next epoch — could never emit it.
        check_health(steps, steps)
    if checkpointer is not None:
        checkpointer.save(state, step=global_step,
                          meta={"epoch": loop.epochs, "done_in_epoch": 0})
        checkpointer.wait()
    return state, history
