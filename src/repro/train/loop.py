"""Training loop: index-batched steps, microbatch accumulation, checkpointing.

The step function is the paper's workflow fused into one jitted SPMD program:

    starts --(window gather from the RESIDENT series)--> (x, y) --> loss
           --> grads --(all-reduce inserted by the partitioner)--> Adam

i.e. distributed-index-batching: the host only ever ships int32 window starts
to the device; the series was placed once (GPU-index-batching) and every
worker gathers its own batch locally.

Microbatch gradient accumulation (``microbatches > 1``) scans over microbatch
slices; besides fitting memory this overlaps per-microbatch compute with the
final cross-pod gradient reduce.  ``grad_dtype="bfloat16"`` compresses the
gradient tree before the all-reduce (the cross-pod axis is the slow link) —
the distributed-optimization knobs the 1000-node posture calls for.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamConfig, apply_updates, init_opt_state


class RestartSignal(Exception):
    """Raised by a ``health_cb`` to request an engine-level restart.

    ``run_training`` checkpoints the in-flight state (so no step is lost),
    annotates the signal with everything the engine needs to re-mesh and
    resume — ``state``, ``history``, ``epoch``, ``step`` — and re-raises.
    """

    def __init__(self, plan=None, reason: str = ""):
        super().__init__(reason or getattr(plan, "reason", "restart requested"))
        self.plan = plan
        self.state = None
        self.history: list[dict] = []
        self.epoch = 0
        self.step = 0


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    epochs: int = 1
    log_every: int = 50
    ckpt_every: int = 0  # steps; 0 = only at end
    ckpt_dir: str | None = None
    microbatches: int = 1
    grad_dtype: str | None = None  # "bfloat16" compresses grads pre-all-reduce
    donate: bool = True


def zero_grads_like(params, grad_dtype: str | None):
    """Zero tree for microbatch gradient accumulation.

    Each leaf takes the dtype the gradients will actually have — the
    ``grad_dtype`` compression target when set, else the param leaf's own
    dtype.  (A float32 default would silently up-cast bf16/f16 gradient
    trees through ``jnp.add``'s promotion inside the scan.)
    """
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, grad_dtype or p.dtype), params)


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jnp.ndarray, dict]],
    adam: AdamConfig,
    schedule: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    microbatches: int = 1,
    grad_dtype: str | None = None,
    donate: bool = True,
    in_shardings=None,
    out_shardings=None,
):
    """Build the jitted train step.

    loss_fn(params, batch) -> (loss, metrics).  ``batch`` is any pytree whose
    leaves have a leading per-step batch dim (divisible by ``microbatches``).
    Returns step(state, batch) -> (state, metrics).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if grad_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        return loss, metrics, grads

    def step(state, batch):
        params, opt_state = state["params"], state["opt"]
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i], batch)

            def acc_step(carry, i):
                loss_a, grads_a = carry
                loss, _, grads = grads_of(params, slice_mb(i))
                return (loss_a + loss,
                        jax.tree.map(jnp.add, grads_a, grads)), None

            zero_g = zero_grads_like(params, grad_dtype)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero_g), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {}
        lr = schedule(opt_state["step"])
        new_params, new_opt, gnorm = apply_updates(params, grads, opt_state, adam, lr)
        out_metrics = {"loss": loss, "lr": lr, **metrics}
        if gnorm is not None:
            out_metrics["grad_norm"] = gnorm
        return {"params": new_params, "opt": new_opt}, out_metrics

    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(step, donate_argnums=(0,) if donate else (), **kw)


def init_train_state(params, adam: AdamConfig):
    return {"params": params, "opt": init_opt_state(params, adam)}


def run_training(
    *,
    state,
    train_step,
    sampler,
    batch_of_starts: Callable[[np.ndarray], Any],
    loop: TrainLoopConfig,
    eval_fn: Callable[[Any], dict] | None = None,
    checkpointer=None,
    start_epoch: int = 0,
    start_step: int = 0,
    start_done_in_epoch: int | None = None,
    health_cb: Callable[[int], None] | None = None,
    history_sink: list | None = None,
) -> tuple[Any, list[dict]]:
    """Generic epoch loop.

    ``sampler.epoch_global(e)`` yields [steps, global_batch] window starts
    (``sampler.epoch_grid(e)`` is preferred when present — a DataPlane
    returns only this process's feed columns under multi-process SPMD);
    ``batch_of_starts`` maps one row to the step's batch pytree (typically a
    device_put of the starts with the batch sharding — the gather itself
    happens inside the jitted step, from the resident series).
    Deterministic (seed, epoch) sampling + step-granular checkpoints mean a
    restart resumes bit-identically mid-epoch.

    ``start_done_in_epoch`` decouples the resume position from the step
    numbering: when given, ``start_epoch`` resumes after that many completed
    steps (later epochs start at 0) and ``start_step`` is ONLY the monotonic
    step counter.  Elastic restarts need this — after a re-mesh changes
    ``steps_per_epoch``, deriving the position from ``start_step`` would
    renumber checkpoints non-monotonically and ``latest_step`` could later
    resurrect a stale pre-restart checkpoint.  When None (the default), the
    position is derived from ``start_step`` as before.

    ``health_cb(global_step)`` runs after every step; it may raise
    :class:`RestartSignal` (e.g. the elastic engine's heartbeat monitor
    flagging a dead worker), in which case the loop checkpoints the current
    state with its (epoch, done_in_epoch) coordinates, annotates the signal,
    and re-raises for the engine to re-mesh and resume.

    ``history_sink``: optional caller-owned list mirroring every history row
    as it is logged.  Unlike the returned history it survives NON-elastic
    failures (a collective erroring out when a peer process dies raises
    straight through), so an external launcher can still persist the rows
    logged before the crash.
    """
    history: list[dict] = []
    global_step = start_step
    grid_of_epoch = getattr(sampler, "epoch_grid", sampler.epoch_global)

    def log_row(row: dict) -> None:
        history.append(row)
        if history_sink is not None:
            history_sink.append(row)

    def epoch_meta(epoch: int, done: int, steps: int) -> dict:
        """Checkpoint coordinates, normalised so a COMPLETE epoch reads as
        the start of the next one — a resume into a topology whose
        steps_per_epoch grew must not re-enter (and re-summarise) an epoch
        that already finished."""
        if done >= steps:
            return {"epoch": epoch + 1, "done_in_epoch": 0}
        return {"epoch": epoch, "done_in_epoch": done}

    def check_health(done_now: int, steps: int) -> None:
        """Poll health_cb; on RestartSignal checkpoint-and-annotate."""
        if health_cb is None:
            return
        try:
            health_cb(global_step)
        except RestartSignal as sig:
            if checkpointer is not None:
                checkpointer.save(state, step=global_step,
                                  meta=epoch_meta(epoch, done_now, steps))
                checkpointer.wait()
            sig.state, sig.history = state, history
            sig.epoch, sig.step = epoch, global_step
            raise

    for epoch in range(start_epoch, loop.epochs):
        grid = grid_of_epoch(epoch)
        t0 = time.perf_counter()
        # Resume mid-epoch: skip steps already done.  Clamp to [0, steps] —
        # a start_step beyond this epoch (resume past a partially-logged
        # epoch with a stale start_epoch) must skip it wholesale, not index
        # with a done-count larger than the grid.
        if start_done_in_epoch is not None:
            done_in_epoch = (min(start_done_in_epoch, grid.shape[0])
                             if epoch == start_epoch else 0)
        else:
            done_in_epoch = min(
                max(global_step - epoch * sampler.steps_per_epoch, 0),
                grid.shape[0])
        metrics = None
        for i in range(done_in_epoch, grid.shape[0]):
            state, metrics = train_step(state, batch_of_starts(grid[i]))
            global_step += 1
            if loop.log_every and global_step % loop.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                log_row({"step": global_step, "epoch": epoch, **m})
            if (checkpointer is not None and loop.ckpt_every
                    and global_step % loop.ckpt_every == 0):
                checkpointer.save(
                    state, step=global_step,
                    meta=epoch_meta(epoch, i + 1, grid.shape[0]))
            if i < grid.shape[0] - 1:
                check_health(i + 1, grid.shape[0])
        if metrics is None:
            continue  # every step was already done on resume: nothing to log
        epoch_metrics = {"epoch": epoch, "epoch_time_s": time.perf_counter() - t0,
                         "step": global_step,
                         "loss": float(metrics["loss"])}
        if eval_fn is not None:
            epoch_metrics.update(eval_fn(state))
        log_row(epoch_metrics)
        # The final step's health poll runs AFTER the epoch summary: a
        # restart landing exactly on the epoch boundary would otherwise
        # abort before the summary/eval row and the resumed run — which
        # starts at the next epoch — could never emit it.
        check_health(grid.shape[0], grid.shape[0])
    if checkpointer is not None:
        checkpointer.save(state, step=global_step,
                          meta={"epoch": loop.epochs, "done_in_epoch": 0})
        checkpointer.wait()
    return state, history
