from repro.train.loop import (JsonlHistorySink, TrainLoopConfig,
                              combine_weighted, make_train_step, run_training)
from repro.train.serve import ServeConfig, Server

__all__ = ["make_train_step", "run_training", "TrainLoopConfig",
           "combine_weighted", "JsonlHistorySink", "Server", "ServeConfig"]
