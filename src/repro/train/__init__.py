from repro.train.loop import TrainLoopConfig, make_train_step, run_training
from repro.train.serve import ServeConfig, Server

__all__ = ["make_train_step", "run_training", "TrainLoopConfig", "Server", "ServeConfig"]
