"""Batched serving loop: continuous batching over a fixed slot pool.

The serving analogue of the paper's workflow: weights and caches are resident
on device; the host only ships token ids.  ``Server`` keeps ``slots`` decode
lanes; finished lanes are refilled from the request queue via single-request
prefill into the shared cache (per-slot dynamic_update on the batch dim).

For production meshes ``launch/dryrun.py`` lowers the same ``decode_step`` /
``prefill`` programs with the cache sharded over (data × model) — this module
is the single-host driver used by the examples and tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import model as lm
from repro.models.lm.config import LMConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4  # concurrent decode lanes
    max_len: int = 256  # cache capacity per lane
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    eos_id: int | None = None


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    out: list[int] = dataclasses.field(default_factory=list)
    budget: int = 0


class Server:
    """Continuous-batching server around prefill/decode_step."""

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.queue: deque[_Request] = deque()
        self.done: dict[int, list[int]] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)

        b, s = serve.slots, serve.max_len
        self.cache = lm.init_cache(cfg, b, s)
        self.lengths = jnp.zeros((b,), jnp.int32)
        self.tokens = jnp.zeros((b, 1), jnp.int32)
        self.active: list[_Request | None] = [None] * b

        self._decode = jax.jit(
            lambda p, tok, cache, lengths: lm.decode_step(p, cfg, tok, cache, lengths))
        self._prefill1 = jax.jit(
            lambda p, tok, cache: lm.prefill(p, cfg, tok, cache))

    # ------------------------------------------------------------------ queue
    def submit(self, prompt_tokens: np.ndarray, *, max_new_tokens: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Request(rid, np.asarray(prompt_tokens, np.int32),
                                   budget=max_new_tokens or self.serve.max_new_tokens))
        return rid

    def _fill_slot(self, slot: int) -> bool:
        if not self.queue:
            return False
        req = self.queue.popleft()
        # single-lane prefill into a fresh 1-batch cache, then scatter into slot
        cache1 = lm.init_cache(self.cfg, 1, self.serve.max_len)
        logits, cache1, lengths1 = self._prefill1(
            self.params, jnp.asarray(req.prompt[None]), cache1)
        tok = self._sample(logits)[0]
        req.out.append(int(tok))

        def put(big, small):
            # stage-stacked caches: [repeats, ...] with batch at axis 1
            return jax.lax.dynamic_update_slice_in_dim(big, small.astype(big.dtype),
                                                       slot, axis=1)

        self.cache = jax.tree.map(put, self.cache, cache1)
        self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
        self.tokens = self.tokens.at[slot, 0].set(tok)
        self.active[slot] = req
        return True

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.serve.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(k, logits / self.serve.temperature).astype(jnp.int32)

    # ------------------------------------------------------------------- step
    def step(self) -> int:
        """Refill free slots, run one batched decode step.  Returns #active."""
        for slot in range(self.serve.slots):
            if self.active[slot] is None:
                if not self._fill_slot(slot):
                    break
        if not any(self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.tokens, self.cache,
                                          self.lengths)
        next_tok = self._sample(logits)
        self.lengths = self.lengths + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        self.tokens = next_tok[:, None]
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.out.append(tok)
            hit_eos = self.serve.eos_id is not None and tok == self.serve.eos_id
            full = int(self.lengths[slot]) >= self.serve.max_len - 1
            if len(req.out) >= req.budget or hit_eos or full:
                self.done[req.rid] = req.out
                self.active[slot] = None
                self.lengths = self.lengths.at[slot].set(0)
        return sum(1 for r in self.active if r is not None)

    def run(self) -> dict[int, list[int]]:
        """Drain the queue to completion."""
        while self.queue or any(self.active):
            self.step()
        return self.done
