"""Compat shim: the server moved to ``repro.serve`` (PR 8).

``repro.serve`` is the serving engine package — ``Server``/``ServeConfig``
(single-host reference), ``InferencePlane``/``Router``/``ServeEngine`` (the
sharded fleet).  Import from there; this module keeps the historical
``repro.train.serve`` import path working.
"""
from repro.serve.server import ServeConfig, Server

__all__ = ["ServeConfig", "Server"]
