"""Epoch samplers: global shuffling vs local batch shuffling (paper §4.2, §5.4).

*Global shuffling* (distributed-index-batching): every epoch draws a fresh
permutation of **all** training windows; rank r takes the r-th slice.  Because
each worker holds the full series, this costs zero communication — the paper's
key scalability win.

*Local batch shuffling* (generalized-distributed-index-batching): each rank owns
a fixed, contiguous window partition; only the *order of batches* inside the
partition is shuffled between epochs (Table 5 shows accuracy parity).

Samplers are deterministic functions of (seed, epoch) so that restarts resume
mid-epoch bit-identically (fault tolerance) and all SPMD ranks agree on the
permutation without communicating.

The first-class primitive is ``feed(rank, epoch) -> [steps, batch_per_rank]``:
the per-process index feed a real multi-host launch hands to rank ``rank``.
``epoch(epoch)`` is this rank's own feed; ``epoch_global(epoch)`` is the
single-host assembly of the per-rank feed columns (rank-major), kept for the
lock-step SPMD simulation — ``concat([feed(r, e) for r in ranks], axis=1) ==
epoch_global(e)`` is the contract the pipeline tests pin down.

Evaluation mirrors the same contract through :class:`EvalFeeds`
(``eval_feed(rank, pool)``): val/test pools are carved into the same
rank-major column blocks, deterministically and without shuffling, so a
multi-process fleet scores each eval window exactly once.

Feeds are also CHUNK-ITERABLE (:class:`FeedStream`): ``feed_stream(rank,
epoch)`` yields successive row blocks that concatenate exactly to
``feed(rank, epoch)`` — the handle the async prefetch pipeline pulls from a
background thread instead of materializing whole-epoch arrays up front.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    rank: int
    world: int

    def __post_init__(self):
        if not 0 <= self.rank < self.world:
            raise ValueError(f"rank {self.rank} outside world {self.world}")


def _rng(seed: int, epoch: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, epoch]))


class FeedStream:
    """Chunk-iterable view of the per-rank feed — the contract the async
    prefetch pipeline (:mod:`repro.pipeline.prefetch`) consumes.

    ``feed_stream(rank, epoch)`` yields successive ``[<=chunk, batch]``
    row blocks whose concatenation is EXACTLY ``feed(rank, epoch)`` — same
    values, same order (the invariant test_feeds_property pins for every
    sampler × world).  Because the feed is a pure function of
    (seed, epoch, rank), a block materialized early — e.g. on a prefetcher
    thread, several steps before it is consumed — carries the identical
    window ids it would carry if built lockstep, which is what makes the
    pipelined path's staleness-0 bit-identity provable rather than tested
    into existence.

    The default implementation slices the materialized feed; samplers whose
    feeds are expensive to assemble may override it to build blocks
    incrementally (nothing in the contract requires the whole epoch array
    to ever exist).
    """

    def feed_stream(self, rank: int, epoch: int, *, start: int = 0,
                    chunk: int = 8):
        """Yield ``[<=chunk, batch]`` blocks of ``feed(rank, epoch)`` rows,
        beginning at row ``start`` (mid-epoch resume)."""
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        feed = self.feed(rank, epoch)
        for lo in range(start, feed.shape[0], chunk):
            yield feed[lo:lo + chunk]


class EvalFeeds(FeedStream):
    """Deterministic per-rank EVAL feeds — the evaluation mirror of the
    ``feed(rank, epoch)`` contract.

    Eval pools (val/test window ids) are scored in POOL ORDER: no shuffling,
    no epoch argument, so every rank derives the identical plan from the pool
    alone — zero communication, exactly like the train feeds.  The pool's
    full global chunks ``[steps, world*batch]`` are carved rank-major like
    the train grid: ``eval_feed(rank, pool)`` is column block ``rank``, and
    ``concat([eval_feed(r, pool) for r in ranks], axis=1).ravel()`` followed
    by ``eval_tail(pool)`` reproduces the pool exactly once (nothing dropped,
    nothing double-counted — the invariant test_feeds_property pins).

    The ragged tail (``len(pool) % (world*batch)`` windows) stays GLOBAL:
    every rank sees all of it and scores it as one small replicated batch.
    Splitting it per-rank instead would change the float reduction grouping
    and break bit-identity with the single-host window-weighted reference.
    """

    def _eval_world(self) -> int:
        shard = getattr(self, "shard", None)
        return shard.world if shard is not None else self.world

    def eval_feed(self, rank: int, pool: np.ndarray) -> np.ndarray:
        """[steps, batch_per_rank] eval window ids for ``rank``: its column
        block of the pool's full global chunks, in pool order."""
        pool = np.asarray(pool)
        world, b = self._eval_world(), self.batch
        steps = len(pool) // (world * b)
        return pool[:steps * world * b].reshape(steps, world, b)[:, rank, :]

    def eval_tail(self, pool: np.ndarray) -> np.ndarray:
        """The ragged remainder after the full chunks — global, identical on
        every rank (scored once as a replicated small batch)."""
        pool = np.asarray(pool)
        world, b = self._eval_world(), self.batch
        return pool[(len(pool) // (world * b)) * world * b:]

    def eval_global(self, pool: np.ndarray) -> np.ndarray:
        """[steps, world*batch] single-host assembly of the eval feed columns
        — exactly the pool's full chunks, in order."""
        pool = np.asarray(pool)
        world, b = self._eval_world(), self.batch
        steps = len(pool) // (world * b)
        return pool[:steps * world * b].reshape(steps, world * b)


class GlobalShuffleSampler(EvalFeeds):
    """Paper default: communication-free global shuffle across all windows."""

    def __init__(self, window_ids: np.ndarray, batch_per_rank: int, shard: ShardInfo, *, seed: int = 0,
                 drop_remainder: bool = True):
        self.window_ids = np.asarray(window_ids, dtype=np.int32)
        self.batch = batch_per_rank
        self.shard = shard
        self.seed = seed
        global_batch = batch_per_rank * shard.world
        self.steps_per_epoch = len(self.window_ids) // global_batch
        if not drop_remainder and len(self.window_ids) % global_batch:
            raise NotImplementedError("padding of ragged final batch not supported")
        if self.steps_per_epoch == 0:
            raise ValueError(
                f"{len(self.window_ids)} windows < global batch {global_batch}")

    def feed(self, rank: int, epoch: int) -> np.ndarray:
        """[steps, batch_per_rank] window ids for ``rank`` — the per-process
        feed.  Any rank derives any feed from (seed, epoch) alone, so SPMD
        workers never communicate about the schedule."""
        perm = _rng(self.seed, epoch).permutation(self.window_ids)
        n = self.steps_per_epoch * self.batch * self.shard.world
        grid = perm[:n].reshape(self.steps_per_epoch, self.shard.world, self.batch)
        return grid[:, rank, :]

    def epoch(self, epoch: int) -> np.ndarray:
        """[steps, batch_per_rank] window ids for this rank."""
        return self.feed(self.shard.rank, epoch)

    def epoch_global(self, epoch: int) -> np.ndarray:
        """[steps, world*batch] — the whole global batch per step, rank-major:
        the single-host assembly of the per-rank ``feed`` columns.  This is
        what feeds a single jitted SPMD step whose batch dim is sharded."""
        perm = _rng(self.seed, epoch).permutation(self.window_ids)
        n = self.steps_per_epoch * self.batch * self.shard.world
        return perm[:n].reshape(self.steps_per_epoch, self.shard.world * self.batch)


class LocalBatchShuffleSampler(EvalFeeds):
    """Generalized variant: fixed per-rank partition, shuffled batch order."""

    def __init__(self, window_ids: np.ndarray, batch_per_rank: int, shard: ShardInfo, *, seed: int = 0):
        ids = np.asarray(window_ids, dtype=np.int32)
        parts = np.array_split(ids, shard.world)
        self.window_ids = ids
        self.batch = batch_per_rank
        self.shard = shard
        self.seed = seed
        self.steps_per_epoch = min(len(p) for p in parts) // batch_per_rank
        if self.steps_per_epoch == 0:
            raise ValueError("partition smaller than one batch")
        n = self.steps_per_epoch * batch_per_rank
        self._rank_batches = [p[:n].reshape(self.steps_per_epoch, batch_per_rank)
                              for p in parts]
        self.batches = self._rank_batches[shard.rank]

    def feed(self, rank: int, epoch: int) -> np.ndarray:
        """[steps, batch] for ``rank``: its fixed partition's batches in the
        (seed, epoch) order — identical on every host that derives it."""
        order = _rng(self.seed, epoch).permutation(self.steps_per_epoch)
        return self._rank_batches[rank][order]

    def epoch(self, epoch: int) -> np.ndarray:
        return self.feed(self.shard.rank, epoch)

    def epoch_global(self, epoch: int) -> np.ndarray:
        """[steps, world*batch] rank-major assembly of every rank's feed.

        Feeds a single jitted SPMD step whose batch dim is sharded: column
        block r is exactly ``feed(r, epoch)``, so
        ``epoch_global(e).reshape(steps, world, batch)[:, r, :] ==
        feed(r, e)`` — the same contract GlobalShuffleSampler keeps.
        """
        return np.concatenate(
            [self.feed(r, epoch) for r in range(self.shard.world)], axis=1)


def local_shuffle_sampler(window_ids, batch_per_rank, shard, *, seed=0):
    """Classic local shuffling (shuffle *samples* within a fixed partition) —
    included for the Table-5 comparison axis."""

    class _S(LocalBatchShuffleSampler):
        def feed(self, rank: int, epoch: int) -> np.ndarray:
            flat = self._rank_batches[rank].reshape(-1)
            perm = _rng(self.seed, epoch).permutation(flat)
            return perm.reshape(self.steps_per_epoch, self.batch)

    return _S(window_ids, batch_per_rank, shard, seed=seed)
