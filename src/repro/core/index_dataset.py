"""IndexDataset — the paper's compact representation (series + window indices).

Holds exactly what eq. (2) budgets for: one standardized copy of the series and
the int32 start-index array.  ``to_device`` realises GPU-index-batching: the
series is placed on the accelerator (optionally with an explicit sharding for
the distributed placements) once, before training.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import windows as W
from repro.data.normalize import Scaler, apply_scaler, fit_scaler


@dataclasses.dataclass
class IndexDataset:
    series: Any  # [T, N, F] (np.ndarray on host, jax.Array once on device)
    starts: np.ndarray  # [W] int32 — window start per sample
    spec: W.WindowSpec
    scaler: Scaler
    train_windows: np.ndarray
    val_windows: np.ndarray
    test_windows: np.ndarray

    # ------------------------------------------------------------------ build
    @classmethod
    def from_raw(
        cls,
        raw: np.ndarray,
        spec: W.WindowSpec,
        *,
        train: float = 0.7,
        val: float = 0.1,
        scale_feature: int | None = 0,
        counting: W.Counting = "exact",
    ) -> "IndexDataset":
        starts = W.window_starts(raw.shape[0], spec, counting)
        tr, va, te = W.split_windows(len(starts), train, val)
        # Scaler over the series range the training windows cover (Alg. 1 l.16-18).
        train_end_step = int(starts[tr[-1]]) + spec.in_len if len(tr) else raw.shape[0]
        scaler = fit_scaler(raw, train_end_step, feature=scale_feature)
        series = apply_scaler(raw, scaler, feature=scale_feature)
        return cls(series, starts, spec, scaler, tr, va, te)

    # -------------------------------------------------------------- placement
    def to_device(self, sharding=None) -> "IndexDataset":
        """GPU-index-batching: one host→device transfer of the compact series."""
        arr = jnp.asarray(self.series)
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        return dataclasses.replace(self, series=arr)

    # ------------------------------------------------------------- accounting
    @property
    def entries(self) -> int:
        return self.series.shape[0]

    @property
    def n_windows(self) -> int:
        return len(self.starts)

    def nbytes_index(self) -> int:
        """Actual bytes of this representation (series + index array)."""
        ser = self.series.size * self.series.dtype.itemsize
        return int(ser) + self.starts.nbytes

    def nbytes_materialized(self) -> int:
        """Bytes the Alg.-1 baseline would need for the same windows."""
        per_window = self.spec.span * int(np.prod(self.series.shape[1:]))
        return self.n_windows * per_window * self.series.dtype.itemsize
