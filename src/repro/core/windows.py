"""Sliding-window math and the paper's memory model (eqs. 1 and 2).

The paper (§2.3, §3.3) shows that standard spatiotemporal preprocessing
materialises every sliding-window snapshot, growing an ``entries × nodes ×
features`` series by ``≈ 2·horizon×``.  Index-batching (§4.1) keeps one copy of
the series plus an integer start index per window.  This module is the single
source of truth for window counting and the analytic memory model; the
benchmarks validate it against the paper's Table 1.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

Counting = Literal["exact", "paper", "table"]


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Sliding-window geometry.

    ``input_len`` (T') steps of input predict ``horizon`` (T) future steps.
    The paper uses T' == T == horizon (12 for the traffic datasets); we keep
    them independent so other seq2seq workloads (e.g. LM next-token windows)
    reuse the same machinery.
    """

    horizon: int
    input_len: int | None = None
    stride: int = 1

    def __post_init__(self) -> None:
        if self.horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {self.horizon}")
        if self.input_len is not None and self.input_len < 1:
            raise ValueError(f"input_len must be >= 1, got {self.input_len}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")

    @property
    def in_len(self) -> int:
        return self.horizon if self.input_len is None else self.input_len

    @property
    def span(self) -> int:
        """Total time steps one (x, y) pair covers."""
        return self.in_len + self.horizon


def num_windows(entries: int, spec: WindowSpec, counting: Counting = "exact") -> int:
    """Number of sliding windows over a series of ``entries`` steps.

    counting="exact"  — every valid placement: entries − (T' + T) + 1.
    counting="paper"  — the paper's eq. (1) term: entries − (2·horizon − 1)
                        (equals "exact" when T' == T == horizon).
    counting="table"  — entries − 2·horizon; this is what the paper's Table 1
                        numbers actually match (see DESIGN.md §7).
    """
    if counting == "exact":
        n = entries - spec.span + 1
    elif counting == "paper":
        n = entries - (2 * spec.horizon - 1)
    elif counting == "table":
        n = entries - 2 * spec.horizon
    else:  # pragma: no cover - guarded by Literal
        raise ValueError(f"unknown counting {counting!r}")
    n = max(n, 0)
    return (n + spec.stride - 1) // spec.stride if spec.stride > 1 else n


def window_starts(entries: int, spec: WindowSpec, counting: Counting = "exact") -> np.ndarray:
    """Start index of every window (int32)."""
    return np.arange(num_windows(entries, spec, counting), dtype=np.int32) * spec.stride


def materialized_bytes(
    entries: int,
    nodes: int,
    features: int,
    spec: WindowSpec,
    dtype_bytes: int = 8,
    counting: Counting = "paper",
) -> int:
    """Paper eq. (1): bytes after standard (snapshot-materialising) preprocessing.

    size = 2 · windows · horizon · nodes · features   (values) · dtype_bytes
    The x and y snapshot stacks each hold ``windows × horizon`` time-slices.
    """
    w = num_windows(entries, spec, counting)
    values = w * (spec.in_len + spec.horizon) * nodes * features
    return values * dtype_bytes


def index_batching_bytes(
    entries: int,
    nodes: int,
    features: int,
    spec: WindowSpec,
    dtype_bytes: int = 8,
    index_bytes: int = 8,
    counting: Counting = "paper",
) -> int:
    """Paper eq. (2): one copy of the series + one start index per window."""
    series = entries * nodes * features * dtype_bytes
    idx = num_windows(entries, spec, counting) * index_bytes
    return series + idx


def memory_reduction(
    entries: int, nodes: int, features: int, spec: WindowSpec, dtype_bytes: int = 8
) -> float:
    """Fractional reduction of index-batching vs materialised snapshots."""
    mat = materialized_bytes(entries, nodes, features, spec, dtype_bytes)
    idx = index_batching_bytes(entries, nodes, features, spec, dtype_bytes)
    return 1.0 - idx / mat if mat else 0.0


def split_windows(
    n_windows: int, train: float = 0.7, val: float = 0.1
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Contiguous train/val/test split over window indices (paper: 70/10/20)."""
    if not 0.0 < train < 1.0 or val < 0.0 or train + val > 1.0:
        raise ValueError(f"bad split train={train} val={val}")
    n_train = round(n_windows * train)
    n_val = round(n_windows * val)
    idx = np.arange(n_windows, dtype=np.int32)
    return idx[:n_train], idx[n_train : n_train + n_val], idx[n_train + n_val :]
