"""Standard (materialising) vs index batching — the paper's core contribution.

``materialize_windows`` is the faithful Alg.-1 baseline: it builds the full
(x, y) snapshot stacks with ~2·horizon× duplication.  ``gather_batch`` is
index-batching: the jitted training step receives the *resident series* and a
vector of window start indices and reconstructs the batch on-device with a
windowed gather — the TPU-native analogue of the paper's NumPy views.  XLA
keeps a single HBM copy of the series; the gather feeds the first layer
directly from it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def materialize_windows(
    series: np.ndarray, starts: np.ndarray, input_len: int, horizon: int
) -> tuple[np.ndarray, np.ndarray]:
    """Alg.-1 baseline: stack every (x, y) snapshot (paper eq. 1 memory)."""
    xs = np.stack([series[s : s + input_len] for s in starts], axis=0)
    ys = np.stack([series[s + input_len : s + input_len + horizon] for s in starts], axis=0)
    return xs, ys


def _window(series: jnp.ndarray, start: jnp.ndarray, length: int) -> jnp.ndarray:
    """One contiguous window ``series[start : start+length]`` via dynamic_slice."""
    sizes = (length,) + series.shape[1:]
    indices = (start,) + (0,) * (series.ndim - 1)
    return jax.lax.dynamic_slice(series, indices, sizes)


@functools.partial(jax.jit, static_argnames=("input_len", "horizon"))
def gather_batch(
    series: jnp.ndarray, starts: jnp.ndarray, *, input_len: int, horizon: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Index-batching: (x, y) for a batch of window starts, gathered on-device.

    series: [T, ...]   starts: [B] int32
    returns x: [B, input_len, ...], y: [B, horizon, ...]
    """
    x = jax.vmap(lambda s: _window(series, s, input_len))(starts)
    y = jax.vmap(lambda s: _window(series, s + input_len, horizon))(starts)
    return x, y


def gather_batch_take(
    series: jnp.ndarray, starts: jnp.ndarray, *, input_len: int, horizon: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather-based variant (``jnp.take`` over explicit index grids).

    Functionally identical to :func:`gather_batch`; lowers to one fused gather
    instead of B dynamic slices.  Which wins depends on the backend — the
    benchmark harness measures both (see EXPERIMENTS.md §Perf).
    """
    offs_x = jnp.arange(input_len, dtype=starts.dtype)
    offs_y = input_len + jnp.arange(horizon, dtype=starts.dtype)
    x = jnp.take(series, starts[:, None] + offs_x[None, :], axis=0)
    y = jnp.take(series, starts[:, None] + offs_y[None, :], axis=0)
    return x, y


def gather_batch_fused(
    series: jnp.ndarray, starts: jnp.ndarray, *, input_len: int, horizon: int,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One gather of the whole span, split into (x, y).

    Halves the index traffic vs :func:`gather_batch` (x and y overlap reads of
    the same rows only at the span boundary, never inside).  With
    ``use_pallas=True`` the gather runs through the scalar-prefetch Pallas
    kernel (``kernels/window_gather``).
    """
    from repro.kernels.window_gather import gather_xy

    return gather_xy(series, starts, input_len=input_len, horizon=horizon,
                     use_pallas=use_pallas)


def gather_x_batch(series: jnp.ndarray, starts: jnp.ndarray, *, length: int) -> jnp.ndarray:
    """x-only gather (serving path / LM next-token windows where y = shift(x))."""
    return jax.vmap(lambda s: _window(series, s, length))(starts)


def lm_window_batch(
    stream: jnp.ndarray, starts: jnp.ndarray, *, seq_len: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Index-batching applied to an LM token stream (the nodes==1 case):
    inputs = stream[s : s+seq], labels = stream[s+1 : s+seq+1]."""
    w = jax.vmap(lambda s: _window(stream, s, seq_len + 1))(starts)
    return w[:, :-1], w[:, 1:]
