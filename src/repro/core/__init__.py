"""Core of the paper's contribution: index-batching and its distributed forms."""
from repro.core.batching import (
    gather_batch,
    gather_batch_fused,
    gather_batch_take,
    gather_x_batch,
    lm_window_batch,
    materialize_windows,
)
from repro.core.distributed import Placement, batch_sharding, series_sharding
from repro.core.index_dataset import IndexDataset
from repro.core.sampler import (EvalFeeds, GlobalShuffleSampler,
                                LocalBatchShuffleSampler, ShardInfo)
from repro.core.windows import WindowSpec, index_batching_bytes, materialized_bytes, num_windows

__all__ = [
    "IndexDataset",
    "WindowSpec",
    "Placement",
    "EvalFeeds",
    "GlobalShuffleSampler",
    "LocalBatchShuffleSampler",
    "ShardInfo",
    "gather_batch",
    "gather_batch_fused",
    "gather_batch_take",
    "gather_x_batch",
    "lm_window_batch",
    "materialize_windows",
    "num_windows",
    "materialized_bytes",
    "index_batching_bytes",
    "series_sharding",
    "batch_sharding",
]
