"""Dataset placement policies — the distributed heart of the paper.

Three placements, matching the paper's three distributed designs:

- ``REPLICATED``  — distributed-index-batching (§4.2): every device holds the
  full compact series (PartitionSpec() on all axes).  Window gathers are local;
  global shuffling costs no communication; the only collective in the step is
  the gradient all-reduce the partitioner inserts.

- ``PARTITIONED`` — generalized-distributed-index-batching (§5.4): the series is
  sharded along TIME across the data axes.  Samplers must draw per-rank indices
  from the local time range (local batch shuffling); gathers then touch only
  local shards and XLA inserts no data collectives.

- ``ONDEMAND``    — the paper's baseline DDP: series time-sharded like
  PARTITIONED but windows sampled *globally*, so every gather crosses shard
  boundaries and the partitioner materialises all-gather / collective-permute
  traffic.  We keep it as the measured baseline for Fig 7 / Fig 9.

The helpers below return `NamedSharding`s plus the per-rank index domains so
that samplers, the train loop, and the dry-run agree on one definition.
"""
from __future__ import annotations

import enum

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.windows import WindowSpec


class Placement(enum.Enum):
    REPLICATED = "replicated"
    PARTITIONED = "partitioned"
    ONDEMAND = "ondemand"


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (everything named pod/data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def series_sharding(mesh: Mesh, placement: Placement) -> NamedSharding:
    """Sharding of the resident series [T, N, F] (or token stream [T])."""
    if placement is Placement.REPLICATED:
        return NamedSharding(mesh, P())
    # Time axis sharded across the data-parallel axes; nodes/features replicated.
    return NamedSharding(mesh, P(data_axes(mesh)))


def batch_sharding(mesh: Mesh, *, pure_dp: bool = False) -> NamedSharding:
    """Sharding of per-step batched tensors (leading batch dim).

    ``pure_dp=True`` reproduces the paper's scheme on the fixed production
    mesh: batch sharded over EVERY axis (each chip is one DDP worker, params
    fully replicated).  Otherwise batch shards over the data axes only and the
    model axis is free for TP.
    """
    axes = mesh.axis_names if pure_dp else data_axes(mesh)
    return NamedSharding(mesh, P(tuple(axes)))


def local_time_range(entries: int, rank: int, world: int) -> tuple[int, int]:
    """[start, end) of the series shard owned by ``rank`` under PARTITIONED."""
    per = entries // world
    rem = entries % world
    start = rank * per + min(rank, rem)
    return start, start + per + (1 if rank < rem else 0)


def local_window_ids(
    entries: int, spec: WindowSpec, rank: int, world: int, *, halo: bool = True
) -> np.ndarray:
    """Window ids fully contained in rank's shard (PARTITIONED placement).

    ``halo=True`` lets a window start anywhere in the local range even if it
    spills ``span−1`` steps into the next shard — the gather then reads a halo
    region, which XLA serves with a bounded neighbour exchange.  ``halo=False``
    keeps windows strictly interior (zero communication, slightly fewer
    samples), matching the paper's communication-free claim.
    """
    start, end = local_time_range(entries, rank, world)
    last_valid = entries - spec.span  # last legal window start globally
    hi = min(end - (0 if halo else spec.span - 1), last_valid + 1)
    lo = min(start, last_valid + 1)
    return np.arange(lo, max(hi, lo), dtype=np.int32)
