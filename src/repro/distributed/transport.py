"""Real heartbeat transports feeding ``ElasticConfig.step_feed``.

The elastic policy (``repro.distributed.elastic``) is pure: the
:class:`HeartbeatMonitor` consumes ``{rank: (step, step_time)}`` events and
never cares where they came from.  Tests inject fakes; a real fleet needs a
transport.  Two are provided, sharing one contract:

- ``emit(rank, step, step_time=None)`` — worker side, called once per train
  step (the engine's health callback drives it via ``ElasticConfig.emitter``);
- ``step_feed(global_step, world) -> {rank: (step, step_time)}`` — monitor
  side, plug-compatible with ``ElasticConfig.step_feed``.  Only ranks that
  reported IN SINCE THE LAST POLL are returned: a dead worker's stale beat
  must not keep refreshing ``WorkerView.last_seen`` or the monitor could
  never time it out;
- ``snapshot() -> {rank: {"step", "age"}}`` — last-known beat per rank with
  its wall-clock age, for post-mortem attribution (a survivor that caught a
  collective failure asks the transport *who* went silent);
- ``close()``.

:class:`FileHeartbeatTransport` — same-host multi-process.  Each beat is an
atomic ``os.replace`` of ``hb_<rank>.json`` in a shared directory; every
process can both emit and poll, so all survivors of a worker loss reach the
same verdict from the same files.

:class:`TcpHeartbeatCollector` / :class:`TcpHeartbeatEmitter` — cross-host.
The collector (rank 0) accepts newline-delimited JSON beats over TCP and is
the only process that polls; emitters reconnect on failure, so a rebooted
worker resumes announcing itself — which is exactly the signal the GROW
planner waits for.

Beats carry a per-emitter monotonically increasing ``seq`` so "reported in
since the last poll" is well-defined even when the step counter repeats
(e.g. a worker that restarts and re-announces step 0).
"""
from __future__ import annotations

import json
import os
import socket
import tempfile
import threading
import time


def _beat(rank: int, step: int, step_time: float | None, seq: int) -> dict:
    return {"rank": int(rank), "step": int(step), "step_time": step_time,
            "seq": int(seq), "wall": time.time()}


class FileHeartbeatTransport:
    """Heartbeats as atomic per-rank JSON files in a shared directory."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._seq: dict[int, int] = {}        # emitter side, per local rank
        # Monitor side: prime the poll baseline with whatever beat files
        # already exist, so they are NOT reported as fresh on the first
        # poll.  A relaunched trainer reuses the shared directory, and a
        # dead worker's stale file must not read as that worker "returning"
        # — only a beat emitted AFTER this transport was built counts.
        self._last_polled: dict[int, int] = {
            rank: b["seq"] for rank, b in self._read_all().items()}

    # -------------------------------------------------------------- emit side
    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        seq = self._seq.get(rank, 0) + 1
        self._seq[rank] = seq
        fd, tmp = tempfile.mkstemp(prefix=f".hb_{rank}-", dir=self.dir)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(_beat(rank, step, step_time, seq), f)
            os.replace(tmp, os.path.join(self.dir, f"hb_{rank}.json"))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ----------------------------------------------------------- monitor side
    def _read_all(self) -> dict[int, dict]:
        beats = {}
        for name in os.listdir(self.dir):
            if not (name.startswith("hb_") and name.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    b = json.load(f)
                beats[int(b["rank"])] = b
            except (OSError, ValueError, KeyError):
                continue  # mid-replace or torn write: catch it next poll
        return beats

    def step_feed(self, global_step: int, world: int) -> dict:
        """Ranks whose beat advanced since the last poll (ElasticConfig
        contract).  Includes ranks OUTSIDE [0, world) — returned workers
        announcing themselves, which the engine turns into a grow plan."""
        out = {}
        for rank, b in self._read_all().items():
            if b["seq"] != self._last_polled.get(rank):
                self._last_polled[rank] = b["seq"]
                out[rank] = (b["step"], b.get("step_time"))
        return out

    def snapshot(self) -> dict[int, dict]:
        now = time.time()
        return {rank: {"step": b["step"], "age": now - b["wall"]}
                for rank, b in self._read_all().items()}

    def close(self) -> None:
        pass


class TcpHeartbeatCollector:
    """Monitor half of the TCP transport: accepts beats, answers polls.

    Binds immediately (``port=0`` picks a free one — read ``.port``); a
    daemon thread accepts connections and one reader thread per emitter
    drains newline-delimited JSON beats into the latest-beat table.  The
    collector can also ``emit`` for its own local ranks directly — rank 0 is
    a worker too and should not dial itself.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._lock = threading.Lock()
        self._beats: dict[int, dict] = {}
        self._last_polled: dict[int, int] = {}
        self._seq = 0
        self._closed = False
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen()
        self.host, self.port = self._srv.getsockname()[:2]
        self.address = f"{self.host}:{self.port}"
        self._acceptor = threading.Thread(target=self._accept_loop, daemon=True)
        self._acceptor.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return  # socket closed
            threading.Thread(target=self._drain, args=(conn,),
                             daemon=True).start()

    def _drain(self, conn: socket.socket) -> None:
        buf = b""
        with conn:
            while True:
                try:
                    chunk = conn.recv(4096)
                except OSError:
                    return
                if not chunk:
                    return
                buf += chunk
                while b"\n" in buf:
                    line, buf = buf.split(b"\n", 1)
                    try:
                        b = json.loads(line)
                        self._store(int(b["rank"]), int(b["step"]),
                                    b.get("step_time"))
                    except (ValueError, KeyError):
                        continue

    def _store(self, rank: int, step: int, step_time: float | None) -> None:
        with self._lock:
            self._seq += 1
            self._beats[rank] = _beat(rank, step, step_time, self._seq)

    # ------------------------------------------------------ transport contract
    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        self._store(rank, step, step_time)

    def step_feed(self, global_step: int, world: int) -> dict:
        out = {}
        with self._lock:
            for rank, b in self._beats.items():
                if b["seq"] != self._last_polled.get(rank):
                    self._last_polled[rank] = b["seq"]
                    out[rank] = (b["step"], b.get("step_time"))
        return out

    def snapshot(self) -> dict[int, dict]:
        now = time.time()
        with self._lock:
            return {rank: {"step": b["step"], "age": now - b["wall"]}
                    for rank, b in self._beats.items()}

    def close(self) -> None:
        self._closed = True
        try:
            self._srv.close()
        except OSError:
            pass


class TcpHeartbeatEmitter:
    """Worker half of the TCP transport.  Beats are fire-and-forget: a send
    failure drops the beat and retries the connection on a later one —
    silence IS the failure signal, so the emitter must never take the
    training loop down with it.  After a failed dial the emitter backs off
    (``retry_after`` seconds) before dialling again: against a PARTITIONED
    collector (SYNs silently dropped) every connection attempt costs the
    full ``connect_timeout``, and paying that inside the step loop on every
    step would throttle training indefinitely."""

    def __init__(self, address: str, *, connect_timeout: float = 2.0,
                 retry_after: float = 5.0):
        host, port = address.rsplit(":", 1)
        self._addr = (host, int(port))
        self._sock: socket.socket | None = None
        self._connect_timeout = connect_timeout
        self._retry_after = retry_after
        self._next_dial = 0.0

    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        line = (json.dumps({"rank": int(rank), "step": int(step),
                            "step_time": step_time}) + "\n").encode()
        for _ in range(2):  # current socket, then one fresh reconnect
            if self._sock is None:
                if time.monotonic() < self._next_dial:
                    return  # backing off: drop the beat, stay fast
                try:
                    self._sock = socket.create_connection(
                        self._addr, timeout=self._connect_timeout)
                except OSError:
                    # Only a failed DIAL arms the backoff: a failed SEND on
                    # an established socket (collector restarted) must still
                    # get its immediate fresh-reconnect attempt below.
                    self._next_dial = time.monotonic() + self._retry_after
                    return
            try:
                self._sock.sendall(line)
                return
            except OSError:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None


def make_transport(spec: str, *, serve: bool = False):
    """Build a transport from a launcher flag.

    ``file:/shared/dir``  -> :class:`FileHeartbeatTransport` (both halves).
    ``tcp://host:port``   -> :class:`TcpHeartbeatCollector` when ``serve``
    (the monitor process binds the address) else :class:`TcpHeartbeatEmitter`
    (workers dial it).
    """
    if spec.startswith("file:"):
        return FileHeartbeatTransport(spec[len("file:"):])
    if spec.startswith("tcp://"):
        addr = spec[len("tcp://"):]
        if serve:
            host, port = addr.rsplit(":", 1)
            return TcpHeartbeatCollector(host=host, port=int(port))
        return TcpHeartbeatEmitter(addr)
    raise ValueError(f"unknown heartbeat transport {spec!r}; "
                     "expected file:<dir> or tcp://<host>:<port>")
