"""Real heartbeat transports feeding ``ElasticConfig.step_feed``.

The elastic policy (``repro.distributed.elastic``) is pure: the
:class:`HeartbeatMonitor` consumes ``{rank: (step, step_time)}`` events and
never cares where they came from.  Tests inject fakes; a real fleet needs a
transport.  Two are provided, sharing one contract:

- ``emit(rank, step, step_time=None)`` — worker side, called once per train
  step (the engine's health callback drives it via ``ElasticConfig.emitter``);
- ``step_feed(global_step, world) -> {rank: (step, step_time)}`` — monitor
  side, plug-compatible with ``ElasticConfig.step_feed``.  Only ranks that
  reported IN SINCE THE LAST POLL are returned: a dead worker's stale beat
  must not keep refreshing ``WorkerView.last_seen`` or the monitor could
  never time it out;
- ``snapshot() -> {rank: {"step", "age"}}`` — last-known beat per rank with
  its wall-clock age, for post-mortem attribution (a survivor that caught a
  collective failure asks the transport *who* went silent);
- ``close()``.

:class:`FileHeartbeatTransport` — same-host multi-process.  Each beat is an
atomic ``os.replace`` of ``hb_<rank>.json`` in a shared directory; every
process can both emit and poll, so all survivors of a worker loss reach the
same verdict from the same files.

:class:`TcpHeartbeatCollector` / :class:`TcpHeartbeatEmitter` — cross-host.
A collector accepts newline-delimited JSON beats over TCP; emitters
reconnect on failure, so a rebooted worker resumes announcing itself —
which is exactly the signal the GROW planner waits for.

The TCP path is no longer single-decider.  A ``tcp://a:p,b:p,...`` spec is
an ordered FAILOVER LIST in leader-succession order: address ``k`` is the
collector candidate on the host owning rank ``k``.  Each serving collector
*peer-mirrors*: every beat it accepts first-hand (a socket delivery or its
own local ``emit``) is replicated — tagged ``fwd`` so replicas are never
re-replicated — to the other collectors, so the standbys on the
next-lowest ranks hold the same beat table as the primary.  Emitters dial
the first reachable address and fail over down the list, so when the
primary's host dies its beats land on the standby that is about to become
the leader — a fully-primed successor (see
:mod:`repro.distributed.leader`).

Beats carry a per-emitter monotonically increasing ``seq`` so "reported in
since the last poll" is well-defined even when the step counter repeats
(e.g. a worker that restarts and re-announces step 0).
"""
from __future__ import annotations

import json
import os
import queue
import socket
import tempfile
import threading
import time


def _beat(rank: int, step: int, step_time: float | None, seq: int) -> dict:
    return {"rank": int(rank), "step": int(step), "step_time": step_time,
            "seq": int(seq), "wall": time.time()}


class FileHeartbeatTransport:
    """Heartbeats as atomic per-rank JSON files in a shared directory."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._seq: dict[int, int] = {}        # emitter side, per local rank
        # Monitor side: prime the poll baseline with whatever beat files
        # already exist, so they are NOT reported as fresh on the first
        # poll.  A relaunched trainer reuses the shared directory, and a
        # dead worker's stale file must not read as that worker "returning"
        # — only a beat emitted AFTER this transport was built counts.
        self._last_polled: dict[int, int] = {
            rank: b["seq"] for rank, b in self._read_all().items()}

    # -------------------------------------------------------------- emit side
    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        seq = self._seq.get(rank, 0) + 1
        self._seq[rank] = seq
        fd, tmp = tempfile.mkstemp(prefix=f".hb_{rank}-", dir=self.dir)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(_beat(rank, step, step_time, seq), f)
            os.replace(tmp, os.path.join(self.dir, f"hb_{rank}.json"))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ----------------------------------------------------------- monitor side
    def _read_all(self) -> dict[int, dict]:
        beats = {}
        for name in os.listdir(self.dir):
            if not (name.startswith("hb_") and name.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    b = json.load(f)
                beats[int(b["rank"])] = b
            except (OSError, ValueError, KeyError):
                continue  # mid-replace or torn write: catch it next poll
        return beats

    def step_feed(self, global_step: int, world: int) -> dict:
        """Ranks whose beat advanced since the last poll (ElasticConfig
        contract).  Includes ranks OUTSIDE [0, world) — returned workers
        announcing themselves, which the engine turns into a grow plan."""
        out = {}
        for rank, b in self._read_all().items():
            if b["seq"] != self._last_polled.get(rank):
                self._last_polled[rank] = b["seq"]
                out[rank] = (b["step"], b.get("step_time"))
        return out

    def snapshot(self) -> dict[int, dict]:
        now = time.time()
        return {rank: {"step": b["step"], "age": now - b["wall"]}
                for rank, b in self._read_all().items()}

    def close(self) -> None:
        pass


class TcpHeartbeatCollector:
    """Monitor half of the TCP transport: accepts beats, answers polls.

    Binds immediately (``port=0`` picks a free one — read ``.port``); a
    daemon thread accepts connections and one reader thread per emitter
    drains newline-delimited JSON beats into the latest-beat table.  The
    collector can also ``emit`` for its own local ranks directly — the
    collector's host is a worker too and should not dial itself.

    ``mirrors``: peer collector addresses (the REST of the failover list).
    Every first-hand beat — delivered on a socket without the ``fwd`` tag,
    or emitted locally — is replicated to them fire-and-forget, so a
    standby collector holds the same beat table as the primary and a
    leader-succession takeover starts from primed ``snapshot()`` /
    ``step_feed()`` state instead of a blank one.  Forwarded beats are
    stored but never re-forwarded (no mirror loops), and each collector
    re-stamps its own ``seq``, so the since-last-poll contract holds
    per-collector no matter which peer a beat arrived through.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 *, mirrors: tuple[str, ...] | list[str] = ()):
        self._lock = threading.Lock()
        self._beats: dict[int, dict] = {}
        self._last_polled: dict[int, int] = {}
        self._seq = 0
        self._closed = False
        self._conns: set[socket.socket] = set()
        self._mirrors = [TcpHeartbeatEmitter(a) for a in mirrors]
        # Replication runs on ONE dedicated pump thread fed by a bounded
        # queue: _store is called from the training loop (local emit) and
        # from every per-connection drain thread, and a dial to a dead or
        # partitioned mirror costs up to connect_timeout — paying that in
        # the step loop would throttle training, and concurrent send()s on
        # one mirror socket would race/interleave.  A full queue drops the
        # beat, like every other emit path: silence is the signal.
        self._mirror_q: queue.Queue | None = None
        if self._mirrors:
            self._mirror_q = queue.Queue(maxsize=1024)
            threading.Thread(target=self._mirror_pump, daemon=True).start()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen()
        self.host, self.port = self._srv.getsockname()[:2]
        self.address = f"{self.host}:{self.port}"
        self._acceptor = threading.Thread(target=self._accept_loop, daemon=True)
        self._acceptor.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return  # socket closed
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._drain, args=(conn,),
                             daemon=True).start()

    def _drain(self, conn: socket.socket) -> None:
        buf = b""
        try:
            with conn:
                while True:
                    try:
                        chunk = conn.recv(4096)
                    except OSError:
                        return
                    if not chunk:
                        return
                    buf += chunk
                    while b"\n" in buf:
                        line, buf = buf.split(b"\n", 1)
                        try:
                            b = json.loads(line)
                            self._store(int(b["rank"]), int(b["step"]),
                                        b.get("step_time"),
                                        forwarded=bool(b.get("fwd")))
                        except (ValueError, KeyError, TypeError):
                            continue
        finally:
            with self._lock:
                self._conns.discard(conn)

    def _store(self, rank: int, step: int, step_time: float | None,
               *, forwarded: bool = False) -> None:
        with self._lock:
            self._seq += 1
            self._beats[rank] = _beat(rank, step, step_time, self._seq)
        if forwarded or self._mirror_q is None:
            return
        # Replicate first-hand beats to the standby collectors via the pump
        # thread, fire-and-forget: a dead mirror is a dead HOST, and the
        # surviving collectors keep working without it.
        try:
            self._mirror_q.put_nowait({"rank": rank, "step": step,
                                       "step_time": step_time, "fwd": True})
        except queue.Full:
            pass

    def _mirror_pump(self) -> None:
        while not self._closed:
            try:
                payload = self._mirror_q.get(timeout=0.5)
            except queue.Empty:
                continue
            for m in self._mirrors:
                m.send(payload)

    # ------------------------------------------------------ transport contract
    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        self._store(rank, step, step_time)

    def step_feed(self, global_step: int, world: int) -> dict:
        out = {}
        with self._lock:
            for rank, b in self._beats.items():
                if b["seq"] != self._last_polled.get(rank):
                    self._last_polled[rank] = b["seq"]
                    out[rank] = (b["step"], b.get("step_time"))
        return out

    def snapshot(self) -> dict[int, dict]:
        now = time.time()
        with self._lock:
            return {rank: {"step": b["step"], "age": now - b["wall"]}
                    for rank, b in self._beats.items()}

    def close(self) -> None:
        self._closed = True
        # shutdown() BEFORE close(): the acceptor thread is blocked inside
        # accept(), which holds the kernel's open file description — a bare
        # close() leaves the socket LISTENing forever and the port can
        # never be re-bound by a restarted or successor collector.
        try:
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass  # ENOTCONN on some platforms: the close below suffices
        try:
            self._srv.close()
        except OSError:
            pass
        # Close accepted connections too, or their drain threads would keep
        # the local port busy and a RESTARTED collector (or the successor
        # re-binding a failover address) could never re-bind it.
        with self._lock:
            conns, self._conns = set(self._conns), set()
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        for m in self._mirrors:
            m.close()


class TcpHeartbeatEmitter:
    """Worker half of the TCP transport.  Beats are fire-and-forget: a send
    failure drops the beat and retries the connection on a later one —
    silence IS the failure signal, so the emitter must never take the
    training loop down with it.  After a failed dial the emitter backs off
    (``retry_after`` seconds) before dialling again: against a PARTITIONED
    collector (SYNs silently dropped) every connection attempt costs the
    full ``connect_timeout``, and paying that inside the step loop on every
    step would throttle training indefinitely.

    ``addresses`` may be an ordered FAILOVER list (or one ``host:port``
    string): the emitter dials the first reachable address, sticks to it,
    and on a lost connection resumes the search FROM that address down the
    list (wrapping) — so when the primary collector's host dies, beats
    land on the standby collector next in the leader-succession order.
    Only a full fruitless sweep of the list arms the backoff; a failed
    send on an established socket still gets its immediate re-dial."""

    def __init__(self, addresses: str | list[str] | tuple[str, ...], *,
                 connect_timeout: float = 2.0, retry_after: float = 5.0):
        if isinstance(addresses, str):
            addresses = [a for a in addresses.split(",") if a]
        if not addresses:
            raise ValueError("TcpHeartbeatEmitter needs at least one address")
        self._addrs = [(h, int(p))
                       for h, p in (a.rsplit(":", 1) for a in addresses)]
        self._i = 0  # index of the address the current/last socket dialled
        self._sock: socket.socket | None = None
        self._connect_timeout = connect_timeout
        self._retry_after = retry_after
        self._next_dial = 0.0
        # Serialises send(): the socket teardown-on-error races any second
        # caller, and interleaved partial sendall()s would tear JSON lines.
        self._send_lock = threading.Lock()

    def emit(self, rank: int, step: int, step_time: float | None = None) -> None:
        self.send({"rank": int(rank), "step": int(step),
                   "step_time": step_time})

    def send(self, payload: dict) -> None:
        """Fire-and-forget one JSON line (the collector mirrors ride this
        too, with their ``fwd``-tagged payloads)."""
        line = (json.dumps(payload) + "\n").encode()
        with self._send_lock:
            for _ in range(2):  # current socket, then one fresh dial sweep
                if self._sock is None and not self._dial():
                    return  # all addresses down or backing off: drop it
                try:
                    self._sock.sendall(line)
                    return
                except OSError:
                    try:
                        self._sock.close()
                    except OSError:
                        pass
                    self._sock = None

    def _dial(self) -> bool:
        """One failover sweep: current address first, then down the list.
        The per-address timeout divides by the list length so a fully
        partitioned sweep costs ~one ``connect_timeout`` total — the
        worst-case step-loop stall must not scale with the failover
        depth."""
        if time.monotonic() < self._next_dial:
            return False  # backing off: stay fast inside the step loop
        # Floored so a LONG list can't shrink the per-dial budget below
        # realistic TCP connect latency (a healthy-but-distant collector
        # must not read as down just because the succession list is deep).
        per_addr = max(self._connect_timeout / len(self._addrs), 0.5)
        for k in range(len(self._addrs)):
            j = (self._i + k) % len(self._addrs)
            try:
                self._sock = socket.create_connection(
                    self._addrs[j], timeout=per_addr)
                self._i = j
                return True
            except OSError:
                continue
        self._next_dial = time.monotonic() + self._retry_after
        return False

    def close(self) -> None:
        # Under _send_lock: a bare close() would be exactly the "second
        # caller" race the lock exists for — nulling _sock between an
        # in-flight send()'s None-check and its sendall (the collector's
        # mirror pump closes emitters another thread may be sending on).
        with self._send_lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None


def tcp_addresses(spec: str) -> list[str] | None:
    """The ordered collector-candidate list of a ``tcp://`` spec (None for
    other transports).  The one parser of the failover grammar — callers
    deciding serve/serve_index (e.g. the launcher's "do I bind slot k?")
    must use this rather than re-splitting the flag themselves."""
    if not spec.startswith("tcp://"):
        return None
    return [a for a in spec[len("tcp://"):].split(",") if a]


def make_transport(spec: str, *, serve: bool = False, serve_index: int = 0):
    """Build a transport from a launcher flag.

    ``file:/shared/dir`` -> :class:`FileHeartbeatTransport` (both halves —
    the file transport is symmetric, every process can emit AND poll).

    ``tcp://a:p,b:p,...`` -> an ordered failover list in leader-succession
    order (one address per collector candidate; a single ``tcp://host:port``
    is the list of one).  With ``serve`` this process binds address
    ``serve_index`` and peer-mirrors accepted beats to every OTHER address
    (:class:`TcpHeartbeatCollector`); without it the workers dial the first
    reachable address and fail over down the list
    (:class:`TcpHeartbeatEmitter`).
    """
    if spec.startswith("file:"):
        return FileHeartbeatTransport(spec[len("file:"):])
    addrs = tcp_addresses(spec)
    if addrs is not None:
        if serve:
            if not 0 <= serve_index < len(addrs):
                raise ValueError(
                    f"serve_index {serve_index} outside the {len(addrs)}-entry "
                    f"failover list {addrs!r}")
            host, port = addrs[serve_index].rsplit(":", 1)
            mirrors = [a for i, a in enumerate(addrs) if i != serve_index]
            return TcpHeartbeatCollector(host=host, port=int(port),
                                         mirrors=mirrors)
        return TcpHeartbeatEmitter(addrs)
    raise ValueError(f"unknown heartbeat transport {spec!r}; "
                     "expected file:<dir> or tcp://<host>:<port>[,host:port...]")
