"""Async, atomic, topology-elastic checkpoints.

Design for the 1000-node posture:

- **Async**: ``save`` snapshots device arrays to host (the only synchronous
  part) and hands serialization to a background thread — training resumes
  while bytes hit disk.
- **Atomic**: writes go to ``step_<n>.tmp-<pid>`` and are ``os.replace``d into
  place; the ``manifest.json`` (with per-file sha256) is written last, so a
  crash mid-write can never leave a readable-but-corrupt checkpoint.
- **Elastic**: arrays are stored with their GLOBAL shape (fully gathered on
  this single-host runtime; per-shard files with the same manifest schema on
  a real multi-host fleet).  ``restore(..., shardings=...)`` re-device_puts
  into ANY topology — restart on 384 healthy chips after losing a pod slice
  re-shards transparently.
- **Retention**: ``keep`` most recent steps are retained, older ones pruned.

Leaves are addressed by pytree path string ("params/stages/0/sub0/..."),
which keeps the format model-agnostic and diffable.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        # A real COPY, not np.asarray: asarray aliases host-resident leaves
        # (and may view a CPU device buffer), so the async writer — and the
        # leader-succession standby holding this snapshot across a crash —
        # would see whatever the caller mutated/donated afterwards.
        flat[key] = np.array(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class Checkpointer:
    """Async checkpoint writer with atomic manifests and retention."""

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------- save
    @staticmethod
    def snapshot(state: Any) -> dict[str, np.ndarray]:
        """Device→host snapshot of ``state`` — the synchronous half of
        :meth:`save`, exposed so a *standby* writer (leader succession:
        :class:`repro.distributed.leader.LeaderCheckpointer`) can hold the
        exact would-be checkpoint in host memory without writing anything.
        Crucially the copy is taken while the buffers are still valid: after
        a failed collective the donated device state may be unusable, but a
        held host snapshot can always be written."""
        return _flatten(state)

    def save(self, state: Any, *, step: int, meta: dict | None = None) -> None:
        """``meta``: JSON-serialisable run coordinates stored in the manifest
        (e.g. the elastic engine's {epoch, done_in_epoch}) — read back with
        :func:`checkpoint_meta` so a restart into a topology with a different
        steps_per_epoch can still resume at the same (epoch, step)."""
        # Wait BEFORE flattening: materialising the new host snapshot while
        # the previous write still holds its own would double peak host
        # memory for the duration of the slow write.
        self.wait()
        self.save_snapshot(_flatten(state), step=step, meta=meta)

    def save_snapshot(self, flat: dict[str, np.ndarray], *, step: int,
                      meta: dict | None = None, sync: bool = False) -> None:
        """Write an already-host-resident :meth:`snapshot`.  ``sync=True``
        forces a synchronous write even on an async checkpointer — the
        succession path wants the takeover checkpoint durable before the
        process exits for relaunch."""
        self.wait()  # one in-flight write at a time
        if self.async_write and not sync:
            self._thread = threading.Thread(
                target=self._write, args=(flat, step, meta), daemon=True)
            self._thread.start()
        else:
            self._write(flat, step, meta)
            self.wait()  # surface a sync-write failure immediately

    def _write(self, flat: dict[str, np.ndarray], step: int,
               meta: dict | None = None) -> None:
        try:
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = tempfile.mkdtemp(prefix=f".step_{step}-", dir=self.dir)
            arrays_path = os.path.join(tmp, "arrays.npz")
            np.savez(arrays_path, **flat)
            manifest = {
                "step": step,
                "meta": meta or {},
                "format": 1,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()},
                "files": {"arrays.npz": _sha256(arrays_path)},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._prune()
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _prune(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    # ---------------------------------------------------------------- inspect
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.startswith(".") \
                    and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)


def checkpoint_meta(directory: str, *, step: int | None = None) -> dict:
    """The run coordinates saved alongside a checkpoint (empty when absent)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with open(os.path.join(directory, f"step_{step:010d}", "manifest.json")) as f:
        return json.load(f).get("meta") or {}


def latest_step(directory: str) -> int | None:
    try:
        steps = Checkpointer(directory).steps()
    except FileNotFoundError:
        return None
    return steps[-1] if steps else None


def restore(
    directory: str,
    template: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
    verify: bool = True,
) -> tuple[Any, int]:
    """Load a checkpoint into ``template``'s structure.

    ``shardings``: optional pytree (or single sharding) of NamedShardings for
    the TARGET topology — elastic restarts pass the new mesh's shardings and
    arrays are re-sharded on the way in.  Returns (state, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays_path = os.path.join(path, "arrays.npz")
    if verify and _sha256(arrays_path) != manifest["files"]["arrays.npz"]:
        raise IOError(f"checksum mismatch in {arrays_path} — corrupt checkpoint")
    with np.load(arrays_path) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_into(template, flat)
    if shardings is not None:
        if jax.tree_util.treedef_is_leaf(jax.tree.structure(shardings)):
            state = jax.tree.map(lambda a: jax.device_put(a, shardings), state)
        else:
            state = jax.tree.map(jax.device_put, state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, manifest["step"]
