"""Elastic runtime policy: heartbeats, straggler detection, re-mesh planning.

The paper's Dask scheduler tolerates stragglers by dynamic work-stealing; a
static SPMD program cannot, so the TPU-native policy is:

1. every worker heartbeats (step counter + wall time) to the coordinator;
2. the monitor flags DEAD workers (no heartbeat past ``timeout``) and
   STRAGGLERS (per-step time > ``straggler_factor`` × fleet median, which on
   a synchronous SPMD program delays *everyone*);
3. on any flag, the planner computes the largest healthy sub-mesh that keeps
   the model-parallel axis intact (TP groups must stay whole — losing one
   chip of a 16-way TP group kills the whole group), shrinking only the
   data axis;
4. the launcher restores the latest checkpoint into the new topology
   (``distributed.checkpoint.restore`` re-shards) and resumes from the same
   (seed, epoch, step) — samplers are deterministic so no data is lost or
   repeated;
5. when a previously-dropped worker heartbeats again (it rebooted, or its
   link healed), the planner emits the inverse GROW plan: the data axis
   re-expands by whole TP groups, the per-worker batch scales back down
   (``scale_batch_or_steps`` against the BASE global batch), and the latest
   checkpoint restores into the larger topology — the same machinery as a
   shrink, run in reverse.

This module is pure policy (no jax.distributed calls) so it is fully testable
on one host; the launcher wires it to real transports
(``repro.distributed.transport``: file-based for same-host multi-process,
TCP for a fleet — both emit the events ``HeartbeatMonitor`` consumes).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class WorkerView:
    last_seen: float
    last_step: int
    step_time_ema: float | None = None
    seen_beat: bool = False


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_workers: tuple[int, ...]
    reason: str
    # Workers re-admitted by a GROW plan (empty on shrink).  A plan is one or
    # the other, never both: recovery is only planned from a healthy fleet.
    readmitted_workers: tuple[int, ...] = ()
    # The rank that decided this plan — rank 0 in the classic single-decider
    # setup, the leader-succession winner (lowest live rank, see
    # repro.distributed.leader) after the original decider died.  None when
    # the caller did not thread leadership through.
    decided_by: int | None = None

    @property
    def kind(self) -> str:
        return "grow" if self.readmitted_workers else "shrink"


class HeartbeatMonitor:
    """Tracks per-worker liveness and step latency."""

    def __init__(self, n_workers: int, *, timeout: float = 60.0,
                 straggler_factor: float = 3.0, clock=time.monotonic):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self._clock = clock
        now = clock()
        self.workers = {i: WorkerView(last_seen=now, last_step=0)
                        for i in range(n_workers)}
        # Set at the first liveness poll: a worker that has not beaten YET is
        # timed from here, not from construction — everything between
        # building the monitor and the first post-step poll (gloo init, the
        # first jit compile) would otherwise count against its first
        # heartbeat and a slow compile could flag live workers on poll one.
        self._first_poll: float | None = None

    def beat(self, worker: int, step: int, step_time: float | None = None) -> None:
        """``step_time``: the worker's self-measured COMPUTE time for the step.
        On a synchronous SPMD program wall time between beats is identical on
        every worker (all wait for the slowest), so straggler attribution
        requires self-reported compute durations; wall time is the fallback.
        """
        now = self._clock()
        w = self.workers[worker]
        if step > w.last_step:
            dt = (step_time if step_time is not None
                  else (now - w.last_seen) / max(step - w.last_step, 1))
            w.step_time_ema = dt if w.step_time_ema is None else 0.8 * w.step_time_ema + 0.2 * dt
        w.last_seen = now
        w.seen_beat = True
        # Monotonic: a beat reporting an OLDER step (a restarted process
        # re-announcing from 0, or reordered transport delivery) still
        # refreshes liveness but must not regress the step counter — the
        # next genuine advance would otherwise divide its wall time by an
        # inflated step delta and skew the straggler EMA.
        w.last_step = max(w.last_step, step)

    def dead(self) -> list[int]:
        now = self._clock()
        if self._first_poll is None:
            self._first_poll = now
        return [i for i, w in self.workers.items()
                if now - (w.last_seen if w.seen_beat
                          else max(w.last_seen, self._first_poll))
                > self.timeout]

    def stragglers(self) -> list[int]:
        times = sorted(w.step_time_ema for w in self.workers.values()
                       if w.step_time_ema is not None)
        if len(times) < max(3, len(self.workers) // 2):
            return []  # not enough signal yet
        median = times[len(times) // 2]
        return [i for i, w in self.workers.items()
                if w.step_time_ema is not None
                and w.step_time_ema > self.straggler_factor * median]

    def unhealthy(self) -> list[int]:
        return sorted(set(self.dead()) | set(self.stragglers()))


def plan_remesh(
    n_total: int,
    unhealthy: list[int],
    *,
    recovered: list[int] | tuple[int, ...] = (),
    model_parallel: int,
    chips_per_host: int = 4,
    axis_names: tuple[str, str] = ("data", "model"),
    decided_by: int | None = None,
) -> ElasticPlan | None:
    """Largest healthy mesh keeping TP groups whole.

    The planner is pure and rank-agnostic — ``unhealthy`` may include rank
    0 (the classic decider) like any other worker; WHO runs the planner is
    the leader-succession layer's problem (``repro.distributed.leader``:
    lowest live rank), and ``decided_by`` merely records that rank on the
    emitted plan for attribution.

    Workers are hosts of ``chips_per_host`` chips; a TP group spans
    ``model_parallel`` chips, so losing a host removes
    ceil(model_parallel / chips_per_host)⁻¹… in practice we drop whole TP
    groups containing an unhealthy host and shrink the data axis.

    ``recovered`` lists workers heartbeating from OUTSIDE the current fleet
    (previously-dropped hosts asking to rejoin).  When the current fleet is
    healthy, the planner re-admits them in whole TP groups and GROWS the data
    axis — the inverse of a shrink.  An unhealthy fleet is shrunk first;
    recovery is re-planned on a later poll once the fleet is stable.
    Returns None when the fleet is unchanged.
    """
    hosts_per_group = max(model_parallel // chips_per_host, 1)
    if not unhealthy:
        if not recovered:
            return None
        # Grow: re-admit whole TP groups' worth of recovered workers only —
        # a partial group can't host a TP shard any more than it could on
        # the way down.
        n_groups = n_total // hosts_per_group
        back_groups = len(set(recovered)) // hosts_per_group
        if back_groups < 1:
            return None
        readmitted = tuple(sorted(set(recovered)))[: back_groups * hosts_per_group]
        return ElasticPlan(
            mesh_shape=(n_groups + back_groups, model_parallel),
            axis_names=axis_names,
            dropped_workers=(),
            readmitted_workers=readmitted,
            reason=f"re-admitted {back_groups} TP group(s) of recovered "
                   f"workers {sorted(set(recovered))}",
            decided_by=decided_by,
        )
    n_groups = n_total // hosts_per_group
    bad_groups = {w // hosts_per_group for w in unhealthy}
    healthy_groups = n_groups - len(bad_groups)
    if healthy_groups < 1:
        raise RuntimeError("no healthy TP group left — cannot re-mesh")
    dropped = tuple(w for g in sorted(bad_groups)
                    for w in range(g * hosts_per_group, (g + 1) * hosts_per_group))
    return ElasticPlan(
        mesh_shape=(healthy_groups, model_parallel),
        axis_names=axis_names,
        dropped_workers=dropped,
        reason=f"dropped {len(bad_groups)} TP group(s) containing unhealthy hosts "
               f"{sorted(unhealthy)}",
        decided_by=decided_by,
    )


def scale_batch_or_steps(global_batch: int, old_dp: int, new_dp: int,
                         *, keep_global_batch: bool = True) -> tuple[int, int]:
    """After re-meshing DP from old_dp to new_dp (either direction), either
    keep the global batch (per-worker batch scales inversely with the world —
    preserves convergence, costs memory on shrink) or keep the per-worker
    batch (global batch scales with the world — re-scale LR by the linear
    rule).  Returns (per_worker_batch, new_global_batch).

    Callers re-meshing more than once must always pass the ORIGINAL (base)
    ``global_batch``, not the previous re-mesh's output: the ceil rounding
    below is not idempotent, so feeding an inflated global batch back in
    compounds the inflation and a shrink→grow round trip would no longer
    restore the original per-worker batch (the engine's inverse-scaling
    contract)."""
    per = global_batch // old_dp
    if keep_global_batch:
        # Distribute the remainder by rounding up: SPMD batches are uniform
        # per rank, so the new global batch is per_new * new_dp — up to
        # new_dp − 1 windows LARGER than the old one (no ragged trim).
        per_new = -(-global_batch // new_dp)
        return per_new, per_new * new_dp
    return per, per * new_dp
