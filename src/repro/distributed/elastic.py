"""Elastic runtime policy: heartbeats, straggler detection, re-mesh planning.

The paper's Dask scheduler tolerates stragglers by dynamic work-stealing; a
static SPMD program cannot, so the TPU-native policy is:

1. every worker heartbeats (step counter + wall time) to the coordinator;
2. the monitor flags DEAD workers (no heartbeat past ``timeout``) and
   STRAGGLERS (per-step time > ``straggler_factor`` × fleet median, which on
   a synchronous SPMD program delays *everyone*);
3. on any flag, the planner computes the largest healthy sub-mesh that keeps
   the model-parallel axis intact (TP groups must stay whole — losing one
   chip of a 16-way TP group kills the whole group), shrinking only the
   data axis;
4. the launcher restores the latest checkpoint into the new topology
   (``distributed.checkpoint.restore`` re-shards) and resumes from the same
   (seed, epoch, step) — samplers are deterministic so no data is lost or
   repeated.

This module is pure policy (no jax.distributed calls) so it is fully testable
on one host; the launcher wires it to real transports.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class WorkerView:
    last_seen: float
    last_step: int
    step_time_ema: float | None = None


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_workers: tuple[int, ...]
    reason: str


class HeartbeatMonitor:
    """Tracks per-worker liveness and step latency."""

    def __init__(self, n_workers: int, *, timeout: float = 60.0,
                 straggler_factor: float = 3.0, clock=time.monotonic):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self._clock = clock
        now = clock()
        self.workers = {i: WorkerView(last_seen=now, last_step=0)
                        for i in range(n_workers)}

    def beat(self, worker: int, step: int, step_time: float | None = None) -> None:
        """``step_time``: the worker's self-measured COMPUTE time for the step.
        On a synchronous SPMD program wall time between beats is identical on
        every worker (all wait for the slowest), so straggler attribution
        requires self-reported compute durations; wall time is the fallback.
        """
        now = self._clock()
        w = self.workers[worker]
        if step > w.last_step:
            dt = (step_time if step_time is not None
                  else (now - w.last_seen) / max(step - w.last_step, 1))
            w.step_time_ema = dt if w.step_time_ema is None else 0.8 * w.step_time_ema + 0.2 * dt
        w.last_seen = now
        w.last_step = step

    def dead(self) -> list[int]:
        now = self._clock()
        return [i for i, w in self.workers.items() if now - w.last_seen > self.timeout]

    def stragglers(self) -> list[int]:
        times = sorted(w.step_time_ema for w in self.workers.values()
                       if w.step_time_ema is not None)
        if len(times) < max(3, len(self.workers) // 2):
            return []  # not enough signal yet
        median = times[len(times) // 2]
        return [i for i, w in self.workers.items()
                if w.step_time_ema is not None
                and w.step_time_ema > self.straggler_factor * median]

    def unhealthy(self) -> list[int]:
        return sorted(set(self.dead()) | set(self.stragglers()))


def plan_remesh(
    n_total: int,
    unhealthy: list[int],
    *,
    model_parallel: int,
    chips_per_host: int = 4,
    axis_names: tuple[str, str] = ("data", "model"),
) -> ElasticPlan | None:
    """Largest healthy mesh keeping TP groups whole.

    Workers are hosts of ``chips_per_host`` chips; a TP group spans
    ``model_parallel`` chips, so losing a host removes
    ceil(model_parallel / chips_per_host)⁻¹… in practice we drop whole TP
    groups containing an unhealthy host and shrink the data axis.
    Returns None when the fleet is unchanged.
    """
    if not unhealthy:
        return None
    hosts_per_group = max(model_parallel // chips_per_host, 1)
    n_groups = n_total // hosts_per_group
    bad_groups = {w // hosts_per_group for w in unhealthy}
    healthy_groups = n_groups - len(bad_groups)
    if healthy_groups < 1:
        raise RuntimeError("no healthy TP group left — cannot re-mesh")
    dropped = tuple(w for g in sorted(bad_groups)
                    for w in range(g * hosts_per_group, (g + 1) * hosts_per_group))
    return ElasticPlan(
        mesh_shape=(healthy_groups, model_parallel),
        axis_names=axis_names,
        dropped_workers=dropped,
        reason=f"dropped {len(bad_groups)} TP group(s) containing unhealthy hosts "
               f"{sorted(unhealthy)}",
    )


def scale_batch_or_steps(global_batch: int, old_dp: int, new_dp: int,
                         *, keep_global_batch: bool = True) -> tuple[int, int]:
    """After shrinking DP from old_dp to new_dp, either keep the global batch
    (per-worker batch grows — preserves convergence, costs memory) or keep the
    per-worker batch (global batch shrinks — re-scale LR by the linear rule).
    Returns (per_worker_batch, new_global_batch)."""
    per = global_batch // old_dp
    if keep_global_batch:
        # Distribute the remainder by rounding up: SPMD batches are uniform
        # per rank, so the new global batch is per_new * new_dp — up to
        # new_dp − 1 windows LARGER than the old one (no ragged trim).
        per_new = -(-global_batch // new_dp)
        return per_new, per_new * new_dp
    return per, per * new_dp
