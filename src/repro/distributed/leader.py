"""Leader succession: every surviving rank can become the decider/writer.

PR 3/4 made the fleet survive the loss of any worker — except process 0,
which was simultaneously the only heartbeat decider, the only checkpoint
writer, the only plan emitter and the only history sink.  Losing host 0
therefore killed the run outright: the classic single-owner coordination
bottleneck DistTGL/MSPipe flag for distributed temporal-GNN training,
showing up as a fault-tolerance hole instead of a throughput one.

This module removes the single owner with a *deterministic succession
rule*: **the lowest live rank is the leader.**  Every process tracks the
same seq-gated heartbeat state (the file transport is symmetric; the TCP
collectors peer-mirror — :mod:`repro.distributed.transport`), so every
survivor derives the same verdict from the same beats, and no election
protocol or extra round-trips are needed — when rank 0 dies, rank 1 *is*
the leader the moment it can attribute the death, and it already holds a
primed beat table and a warm standby checkpoint.

Three pieces:

- :class:`LeaderTracker` — the pure succession rule.  Fed the same
  ``step_feed`` events the :class:`~repro.distributed.elastic
  .HeartbeatMonitor` consumes plus explicit post-collective-failure
  verdicts (``note_dead``), it answers ``leader()`` / ``is_leader()``.
- :class:`LeaderCheckpointer` — checkpoint-writer succession.  Every
  process drives it exactly like a :class:`~repro.distributed.checkpoint
  .Checkpointer`; the current leader's saves land on disk, while every
  standby holds the would-be checkpoint as a host-resident snapshot.  On
  succession, ``takeover()`` durably writes that snapshot — the exact
  failure-step state, even though the device buffers may by then be
  donated or poisoned by the failed collective.
- :class:`LeaderHistorySink` — history-writer succession.  The leader's
  rows land in the crash-durable JSONL sink immediately; standbys buffer,
  and ``flush_as_leader()`` after a takeover makes the buffered rows
  durable (the sink's first-wins (epoch, step) dedup keeps rows the dead
  leader already wrote — identical values under lock-step SPMD).

Split-brain note: at most one rank can be the minimum of any live-set, so
two DIFFERENT verdicts can only disagree transiently (one survivor has
timed the leader out, another has not — e.g. the leader is stalled, not
dead).  The writers are hardened for that window on two different
budgets: checkpoint saves tolerate a transient double-writer outright
(atomic per-step directories, lock-step-identical content, monotonic step
numbers), while the shared history FILE — where a second writer would
truncate and interleave — is only ever taken over through the explicit
attribution path (``note_dead`` → ``succeed_as_leader`` →
``flush_as_leader``), never by a timeout-flipped gate alone (see
:class:`LeaderHistorySink`).
"""
from __future__ import annotations

import time
from typing import Callable, Iterable

from repro.distributed.checkpoint import Checkpointer
from repro.train.loop import JsonlHistorySink


class LeaderTracker:
    """Deterministic leader succession: the lowest live rank wins.

    ``own_ranks`` are the feed ranks THIS process drives (several on a
    host owning multiple data-parallel slots); ``is_leader()`` is simply
    "is the fleet-wide minimum live rank one of mine".  Liveness mirrors
    the :class:`HeartbeatMonitor` contract: a rank is live until it goes
    ``timeout`` without a beat — timed from the first ``observe`` for
    ranks that have never beaten, so compile/startup can't flip
    leadership — or until a collective failure is attributed to it
    (``note_dead``), which is immediate: the survivor that caught the
    failed collective must not wait out a timeout to take over writing.
    """

    def __init__(self, world: int, own_ranks: Iterable[int] = (), *,
                 timeout: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.world = int(world)
        self.own_ranks = {int(r) for r in own_ranks}
        self.timeout = timeout
        self._clock = clock
        self._last_seen: dict[int, float] = {}
        self._dead: set[int] = set()
        self._first_observe: float | None = None

    def bind(self, own_ranks: Iterable[int]) -> None:
        """Set the ranks this process owns (known once the data plane is
        built — e.g. ``DataPlane.process_ranks``)."""
        self.own_ranks = {int(r) for r in own_ranks}

    # -------------------------------------------------------------- liveness
    def observe(self, beats: dict) -> None:
        """Feed one ``step_feed`` poll's events (``{rank: (step, ...)}``).
        Out-of-world ranks (returned workers announcing) are ignored —
        leadership is decided among the CURRENT fleet."""
        now = self._clock()
        if self._first_observe is None:
            self._first_observe = now
        for rank in beats:
            r = int(rank)
            if 0 <= r < self.world:
                self._last_seen[r] = now
                self._dead.discard(r)  # a fresh beat overrides a stale verdict

    def note_dead(self, ranks: Iterable[int]) -> None:
        """External death verdict — post-collective-failure attribution via
        ``transport.snapshot()``.  Takes effect immediately (no timeout)."""
        self._dead.update(int(r) for r in ranks)

    def live(self) -> list[int]:
        now = self._clock()
        out = []
        for r in range(self.world):
            if r in self._dead:
                continue
            if r in self.own_ranks:
                out.append(r)  # we beat for our own ranks by construction
                continue
            seen = self._last_seen.get(r)
            if seen is None:
                seen = now if self._first_observe is None else self._first_observe
            if now - seen <= self.timeout:
                out.append(r)
        return out

    # ------------------------------------------------------------ leadership
    def leader(self) -> int:
        """The current decider: the lowest live rank.  If nothing is live
        (we are the last survivor attributing everyone else), our own
        lowest rank leads — someone must write the post-mortem."""
        live = self.live()
        if live:
            return live[0]
        return min(self.own_ranks) if self.own_ranks else 0

    def is_leader(self) -> bool:
        return self.leader() in self.own_ranks

    def reset(self, world: int, own_ranks: Iterable[int] | None = None) -> None:
        """Re-prime for a new topology after an in-process re-mesh (ranks
        renumber; single-host, so the process owns every rank unless told
        otherwise).  Relaunch-mode fleets build fresh trackers instead."""
        self.world = int(world)
        self.own_ranks = ({int(r) for r in own_ranks}
                          if own_ranks is not None else set(range(self.world)))
        self._last_seen.clear()
        self._dead.clear()
        self._first_observe = None


class LeaderCheckpointer:
    """Checkpoint-writer succession over a plain :class:`Checkpointer`.

    Every process calls :meth:`save` on the same schedule; the proxy makes
    exactly one of them the writer at any moment:

    - the current leader's save is a normal (async, atomic) write;
    - a standby's save snapshots the state to HOST memory and holds it as
      ``pending`` — the warm-standby copy.  Holding host bytes (not device
      buffers) matters twice over: the train step donates its state, and
      after a failed collective the device arrays may be poisoned, but
      the host snapshot taken while they were valid is always writable.

    On succession, :meth:`takeover` synchronously writes the pending
    snapshot — the successor durably owns the exact failure-step state
    before it exits for relaunch.
    """

    def __init__(self, inner: Checkpointer, is_leader: Callable[[], bool]):
        self.inner = inner
        self._is_leader = is_leader
        self._pending: tuple[dict, int, dict | None] | None = None

    def save(self, state, *, step: int, meta: dict | None = None) -> None:
        # Release the previous host copy (the in-flight async write's, or
        # the standby's pending snapshot) BEFORE materialising the new one:
        # holding both doubles peak host memory for the duration of a slow
        # write.  The standby trade-off: if the snapshot itself fails (OOM
        # — exactly when the release matters), the old pending is gone; the
        # durable store still has the previous leader-written step.
        if self._is_leader():
            self._pending = None
            self.inner.wait()
            self.inner.save_snapshot(Checkpointer.snapshot(state),
                                     step=step, meta=meta)
        else:
            self._pending = None
            self._pending = (Checkpointer.snapshot(state), step, meta)

    def takeover(self) -> int | None:
        """Durably write the standby snapshot (succession).  Returns the
        step written, or None when there is nothing pending — e.g. this
        process was already the leader and its saves are on disk."""
        if self._pending is None:
            return None
        flat, step, meta = self._pending
        self._pending = None
        self.inner.save_snapshot(flat, step=step, meta=meta, sync=True)
        return step

    @property
    def pending_step(self) -> int | None:
        return self._pending[1] if self._pending is not None else None

    def wait(self) -> None:
        self.inner.wait()

    def steps(self) -> list[int]:
        return self.inner.steps()


class LeaderHistorySink:
    """History-writer succession over a :class:`JsonlHistorySink`.

    Duck-compatible with the plain-list / JSONL ``history_sink`` contract
    (``append`` / ``rows`` / ``close``).  While this process is not the
    writer, rows are buffered in memory and NOTHING touches the shared
    file — the durable sink is only opened when writer-ship is taken, so
    its torn-tail truncation runs exactly when a successor first takes
    over the file a dead leader may have been mid-write in.

    WHO writes is decided conservatively, because two concurrent writers
    on one file would duplicate rows and tear each other's lines: a
    process that is the leader at its FIRST append owns the file
    outright; a process that started as a standby can ONLY be promoted by
    an explicit :meth:`flush_as_leader` call — the launcher's
    post-collective-failure attribution path (``note_dead`` →
    ``succeed_as_leader``), where the old leader is known dead.  A
    leadership gate that merely flips on a heartbeat TIMEOUT (the old
    leader may be alive and still writing — an NFS stall, a long pause)
    never creates a second writer: the standby just keeps buffering.
    ``flush_as_leader()`` lands the buffered rows; the underlying
    first-wins (epoch, step) dedup drops every row the dead leader
    already wrote.

    ``buffer_standby=False`` turns the standby buffering off for processes
    that can never become the leader (no succession tracker bound, or a
    TCP process beyond the failover list): they would otherwise accumulate
    an unflushable copy of every row for the whole run.
    """

    def __init__(self, path: str, is_leader: Callable[[], bool] | None = None,
                 *, buffer_standby: bool = True):
        self.path = path
        self._is_leader = is_leader or (lambda: True)
        self.buffer_standby = buffer_standby
        self.rows: list[dict] = []       # every row THIS incarnation logged
        self._buffer: list[dict] = []    # standby rows awaiting a takeover
        self._writer: bool | None = None  # None = no append decided it yet
        self._sink: JsonlHistorySink | None = None

    def bind(self, is_leader: Callable[[], bool], *,
             buffer_standby: bool | None = None) -> None:
        self._is_leader = is_leader
        if buffer_standby is not None:
            self.buffer_standby = buffer_standby

    def _durable(self) -> JsonlHistorySink:
        if self._sink is None:
            self._sink = JsonlHistorySink(self.path)
        return self._sink

    def append(self, row: dict) -> bool:
        self.rows.append(row)
        if self._writer is None:
            self._writer = self._is_leader()  # leader at first append: ours
        if not self._writer:
            if self.buffer_standby:
                self._buffer.append(row)
            return False
        return self._durable().append(row)

    def flush_as_leader(self) -> int:
        """Take writer-ship after an ATTRIBUTED succession and make any
        standby-buffered rows durable; returns how many actually landed
        (duplicates of the dead leader's rows don't).  No-op unless the
        bound gate agrees this process now leads."""
        if not self._is_leader():
            return 0
        self._writer = True
        if not self._buffer:
            return 0
        sink = self._durable()
        landed = sum(1 for r in self._buffer if sink.append(r))
        self._buffer.clear()
        return landed

    def load(self) -> list[dict]:
        return self._durable().load()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None
