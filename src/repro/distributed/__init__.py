from repro.distributed.checkpoint import Checkpointer, latest_step, restore
from repro.distributed.elastic import ElasticPlan, HeartbeatMonitor, plan_remesh

__all__ = ["Checkpointer", "restore", "latest_step", "HeartbeatMonitor",
           "plan_remesh", "ElasticPlan"]
