from repro.distributed.checkpoint import (Checkpointer, checkpoint_meta,
                                          latest_step, restore)
from repro.distributed.elastic import (ElasticPlan, HeartbeatMonitor,
                                       plan_remesh, scale_batch_or_steps)
from repro.distributed.leader import (LeaderCheckpointer, LeaderHistorySink,
                                      LeaderTracker)
from repro.distributed.transport import (FileHeartbeatTransport,
                                         TcpHeartbeatCollector,
                                         TcpHeartbeatEmitter, make_transport)

__all__ = ["Checkpointer", "restore", "latest_step", "checkpoint_meta",
           "HeartbeatMonitor", "plan_remesh", "ElasticPlan",
           "scale_batch_or_steps", "FileHeartbeatTransport",
           "TcpHeartbeatCollector", "TcpHeartbeatEmitter", "make_transport",
           "LeaderTracker", "LeaderCheckpointer", "LeaderHistorySink"]
