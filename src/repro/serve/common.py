"""Shared serving utilities: the device→host transfer funnel.

Every blocking device→host pull in the serving stack goes through
``device_get`` — the decode loop's latency budget is dominated by these
syncs (each one stalls the Python thread on the device stream), so they are
funneled through ONE seam that (a) tests can count via ``count_transfers``
to pin the one-pull-per-step contract, and (b) keeps the hot loop honest:
adding a second pull per step shows up as a failing assertion, not a silent
p99 regression.
"""
from __future__ import annotations

import contextlib

import numpy as np

_COUNTER: dict | None = None


def device_get(x) -> np.ndarray:
    """Blocking device→host pull (the only sanctioned one in repro.serve)."""
    global _COUNTER
    if _COUNTER is not None:
        _COUNTER["pulls"] += 1
    return np.asarray(x)


@contextlib.contextmanager
def count_transfers():
    """Count ``device_get`` calls in the block: ``with count_transfers() as c:
    ...; c["pulls"]``.  Nestable; each block counts its own pulls."""
    global _COUNTER
    prev, _COUNTER = _COUNTER, {"pulls": 0}
    try:
        yield _COUNTER
    finally:
        _COUNTER = prev
