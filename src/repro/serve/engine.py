"""ServeEngine: Router + InferencePlane fleet — the serving Engine.

Mirrors the training DataPlane/Engine split: the ``Router`` owns admission
(backpressure, deadlines, prompt-length grouping), each ``InferencePlane``
owns one host's sharded slot pool and jitted programs, and the engine is the
step loop that moves requests between them:

    step():  expire deadlines → batched-prefill queued requests into free
             lanes (least-loaded plane first) → one batched decode step per
             plane with live lanes → retire budget/EOS/full/deadline lanes.

Greedy output is bit-identical to the single-host ``repro.serve.Server``
(itself pinned to hand-rolled decode): decoding is per-lane, so neither the
prefill grouping, the plane assignment, nor the pool's sharding may change
what any request generates — the fleet-equivalence test enforces this.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np
from jax.sharding import Mesh

from repro.models.lm.config import LMConfig
from repro.serve.plane import InferencePlane
from repro.serve.router import Router, ServeRequest
from repro.serve.server import ServeConfig


class ServeEngine:
    """Continuous-batching engine over one or more sharded slot pools."""

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *,
                 planes: int = 1, mesh: Mesh | None = None,
                 queue_limit: int | None = None,
                 prefill_token_budget: int | None = None,
                 seed: int = 0, clock: Callable[[], float] = time.monotonic):
        self.serve = serve
        #: default backpressure bound: 4 waves of the whole fleet
        if queue_limit is None:
            queue_limit = 4 * planes * serve.slots
        self.router = Router(serve, queue_limit=queue_limit, clock=clock)
        self.prefill_token_budget = (prefill_token_budget
                                     or max(serve.max_len, 512))
        # device_put inside each plane dedupes: already-committed shards are
        # reused, so N planes share ONE device copy of the weights
        self.planes = [InferencePlane(params, cfg, serve, mesh=mesh,
                                      seed=seed + i)
                       for i in range(planes)]
        self.active: list[list[ServeRequest | None]] = [
            [None] * serve.slots for _ in self.planes]

    # ------------------------------------------------------------------ queue
    def submit(self, prompt_tokens, *, max_new_tokens: int | None = None,
               deadline_s: float | None = None) -> int:
        """Admit a request (raises ``Backpressure`` / ``ValueError``)."""
        return self.router.submit(prompt_tokens, max_new_tokens=max_new_tokens,
                                  deadline_s=deadline_s)

    # ------------------------------------------------------------ bookkeeping
    def _retire(self, pi: int, slot: int, req: ServeRequest, *,
                status: str = "ok") -> None:
        self.router.finish(req, status=status)
        self.active[pi][slot] = None
        self.planes[pi].release(slot)

    def _should_retire(self, req: ServeRequest, tok: int) -> bool:
        hit_eos = (self.serve.eos_id is not None and tok == self.serve.eos_id)
        return len(req.out) >= req.budget or hit_eos

    def active_lanes(self) -> int:
        return sum(1 for pool in self.active for r in pool if r is not None)

    # ------------------------------------------------------------------- step
    def step(self) -> int:
        """One engine tick.  Returns live lanes + queued requests."""
        self.router.expire()
        # deadline sweep over live lanes: a request past its deadline must
        # release the lane NOW — holding it starves queued requests
        for pi, pool in enumerate(self.active):
            for slot, req in enumerate(pool):
                if req is not None and self.router.past_deadline(req):
                    self._retire(pi, slot, req, status="timeout")

        # admission: batched prefill into free lanes, least-loaded plane first
        while self.router.queue:
            frees = [(len(p.free_slots()), pi) for pi, p in enumerate(self.planes)]
            n_free, pi = max(frees)
            if n_free == 0:
                break
            plane = self.planes[pi]
            group = self.router.pop_group(n_free, self.prefill_token_budget)
            if not group:
                break
            slots = plane.free_slots()[:len(group)]
            prompts = np.stack([r.prompt for r in group])
            toks = plane.prefill_into(slots, prompts)
            for req, slot, tok in zip(group, slots, toks):
                req.out.append(int(tok))
                if self._should_retire(req, int(tok)):
                    # retired AT the prefill token (budget 1 / EOS first):
                    # the lane frees immediately for this same step
                    self._retire(pi, slot, req)
                else:
                    self.active[pi][slot] = req

        # one batched decode step per plane with live lanes
        for pi, (plane, pool) in enumerate(zip(self.planes, self.active)):
            lanes = [s for s, r in enumerate(pool) if r is not None]
            if not lanes:
                continue
            tok_row = plane.decode()
            for slot in lanes:
                req = pool[slot]
                tok = int(tok_row[slot])
                plane.advance(slot, tok)
                req.out.append(tok)
                full = plane.lengths[slot] >= self.serve.max_len - 1
                if self._should_retire(req, tok) or full:
                    self._retire(pi, slot, req)
        return self.active_lanes() + len(self.router.queue)

    def run(self) -> dict[int, list[int]]:
        """Drain queue + lanes to completion.  rid → generated tokens."""
        while self.step():
            pass
        return self.router.results()

    # ------------------------------------------------------------------ stats
    def occupancy(self) -> float:
        """Live-lane fraction of the fleet's slot pool, 0..1."""
        total = len(self.planes) * self.serve.slots
        return self.active_lanes() / total if total else 0.0
