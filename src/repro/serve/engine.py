"""ServeEngine: Router + InferencePlane fleet — the serving Engine.

Mirrors the training DataPlane/Engine split: the ``Router`` owns admission
(backpressure, deadlines, prompt-length grouping), each ``InferencePlane``
owns one host's sharded slot pool and jitted programs, and the engine is the
step loop that moves requests between them:

    step():  expire deadlines → batched-prefill queued requests into free
             lanes (least-loaded plane first) → one batched decode step per
             plane with live lanes → retire budget/EOS/full/deadline lanes.

Output is bit-identical to the single-host ``repro.serve.Server`` (itself
pinned to hand-rolled decode) at ANY temperature: decode and the
request-keyed draws (``repro.serve.sampling``) are per-lane pure functions
of each request, so neither the prefill grouping, the plane assignment, nor
the pool's sharding may change what any request generates — the
fleet-equivalence tests enforce this for greedy and sampled traffic alike.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np
from jax.sharding import Mesh

from repro.models.lm.config import LMConfig
from repro.serve.plane import InferencePlane, PagedInferencePlane
from repro.serve.router import Router, ServeRequest
from repro.serve.server import ServeConfig, validate_request


class ServeEngine:
    """Continuous-batching engine over one or more sharded slot pools.

    ``serve.block_size`` selects the plane flavour: None builds contiguous
    ``InferencePlane`` pools; a block size builds ``PagedInferencePlane``
    pools and admission accounts pool BLOCKS (through ``Router.pop_group``'s
    block budget) on top of free lanes, so a full pool backpressures at the
    router instead of OOM-ing a prefill.
    """

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *,
                 planes: int = 1, mesh: Mesh | None = None,
                 queue_limit: int | None = None,
                 prefill_token_budget: int | None = None,
                 seed: int = 0, clock: Callable[[], float] = time.monotonic):
        self.serve = serve
        self.paged = serve.block_size is not None
        #: default backpressure bound: 4 waves of the whole fleet
        if queue_limit is None:
            queue_limit = 4 * planes * serve.slots
        self.router = Router(serve, queue_limit=queue_limit, clock=clock)
        self.prefill_token_budget = (prefill_token_budget
                                     or max(serve.max_len, 512))
        # device_put inside each plane dedupes: already-committed shards are
        # reused, so N planes share ONE device copy of the weights
        plane_cls = PagedInferencePlane if self.paged else InferencePlane
        self.planes = [plane_cls(params, cfg, serve, mesh=mesh, seed=seed + i)
                       for i in range(planes)]
        self.active: list[list[ServeRequest | None]] = [
            [None] * serve.slots for _ in self.planes]

    # ------------------------------------------------------------------ queue
    def submit(self, prompt_tokens, *, max_new_tokens: int | None = None,
               deadline_s: float | None = None, seed: int | None = None,
               temperature: float | None = None, top_k: int | None = None,
               top_p: float | None = None, rid: int | None = None) -> int:
        """Admit a request (raises ``Backpressure`` / ``ValueError``).

        ``seed``/``temperature``/``top_k``/``top_p`` override the config's
        sampling defaults for this request; ``rid`` pins the request id (the
        fleet worker passes the COORDINATOR's rid so keyed draws survive
        re-placement).  Paged pools add one admission rule: a request whose
        lifetime block cost exceeds the POOL's capacity can never run and is
        rejected with ``ValueError`` here (a full-but-draining pool is
        ``Backpressure`` territory and handled by the router's block
        accounting instead).
        """
        if self.paged:
            prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
            budget = validate_request(self.serve, prompt, max_new_tokens)
            plane = self.planes[0]
            need = plane.block_cost(prompt.size, budget)
            if need > plane.pool.num_blocks:
                raise ValueError(
                    f"request needs {need} blocks; the pool only has "
                    f"{plane.pool.num_blocks} — raise pool_blocks or shorten "
                    f"the request")
        return self.router.submit(prompt_tokens, max_new_tokens=max_new_tokens,
                                  deadline_s=deadline_s, seed=seed,
                                  temperature=temperature, top_k=top_k,
                                  top_p=top_p, rid=rid)

    # ------------------------------------------------------------ bookkeeping
    def _retire(self, pi: int, slot: int, req: ServeRequest, *,
                status: str = "ok") -> None:
        self.router.finish(req, status=status)
        self.active[pi][slot] = None
        self.planes[pi].release(slot)

    def _should_retire(self, req: ServeRequest, tok: int) -> bool:
        hit_eos = (self.serve.eos_id is not None and tok == self.serve.eos_id)
        return len(req.out) >= req.budget or hit_eos

    def active_lanes(self) -> int:
        return sum(1 for pool in self.active for r in pool if r is not None)

    # ------------------------------------------------------------------- step
    def step(self) -> int:
        """One engine tick.  Returns live lanes + queued requests."""
        self.router.expire()
        # deadline sweep over live lanes: a request past its deadline must
        # release the lane NOW — holding it starves queued requests
        for pi, pool in enumerate(self.active):
            for slot, req in enumerate(pool):
                if req is not None and self.router.past_deadline(req):
                    self._retire(pi, slot, req, status="timeout")

        # admission: batched prefill into free lanes, least-loaded plane
        # first; a plane whose BLOCK pool can't take the group's leader is
        # skipped (another plane may have the blocks)
        while self.router.queue:
            order = sorted(((len(p.free_slots()), pi)
                            for pi, p in enumerate(self.planes)), reverse=True)
            popped = False
            for n_free, pi in order:
                if n_free == 0:
                    continue
                plane = self.planes[pi]
                if self.paged:
                    group = self.router.pop_group(
                        n_free, self.prefill_token_budget,
                        block_budget=plane.free_blocks(),
                        block_cost=lambda r, p=plane: p.block_cost(
                            r.prompt.size, r.budget))
                else:
                    group = self.router.pop_group(n_free,
                                                  self.prefill_token_budget)
                if not group:
                    continue
                slots = plane.free_slots()[:len(group)]
                prompts = np.stack([r.prompt for r in group])
                toks = plane.prefill_into(slots, prompts,
                                          budgets=[r.budget for r in group],
                                          rids=[r.rid for r in group],
                                          samples=[r.sample for r in group])
                for req, slot, tok in zip(group, slots, toks):
                    req.out.append(int(tok))
                    if self._should_retire(req, int(tok)):
                        # retired AT the prefill token (budget 1 / EOS first):
                        # the lane frees immediately for this same step
                        self._retire(pi, slot, req)
                    else:
                        self.active[pi][slot] = req
                popped = True
                break
            if not popped:
                break

        # one batched decode step per plane with live lanes
        for pi, (plane, pool) in enumerate(zip(self.planes, self.active)):
            lanes = [s for s, r in enumerate(pool) if r is not None]
            if not lanes:
                continue
            tok_row = plane.decode()
            for slot in lanes:
                req = pool[slot]
                tok = int(tok_row[slot])
                plane.advance(slot, tok)
                req.out.append(tok)
                full = plane.lengths[slot] >= self.serve.max_len - 1
                if self._should_retire(req, tok):
                    self._retire(pi, slot, req)
                elif full:
                    # cache filled before the budget was spent: the caller
                    # must see the difference — "ok" here read as a complete
                    # generation when it was cut off by capacity
                    self._retire(pi, slot, req, status="truncated")
        return self.active_lanes() + len(self.router.queue)

    def run(self) -> dict[int, list[int]]:
        """Drain queue + lanes to completion.  rid → generated tokens."""
        while self.step():
            pass
        return self.router.results()

    # ------------------------------------------------------------------ stats
    def occupancy(self) -> float:
        """Live-lane fraction of the fleet's slot pool, 0..1."""
        total = len(self.planes) * self.serve.slots
        return self.active_lanes() / total if total else 0.0
