"""InferencePlane: one sharded slot pool — the device half of the engine.

The serving analogue of the training DataPlane/Engine split: a plane owns
every device-resident object (weights, the slot-pool KV cache, the jitted
prefill/decode programs) for ONE host's pool.  The engine above it only
moves token ids and bookkeeping.

Sharding contract (the (data x model) mesh):  every jitted program takes
explicit ``in_shardings``/``out_shardings`` over the mesh
``launch.mesh.make_host_mesh`` builds — params via ``shd.lm_param_shardings``
with NO FSDP (decode re-gathers FSDP shards every token, so serving keeps
params TP-sharded only), the cache via ``shd.cache_shardings`` (batch over
the data axes, sequence over model) or ``shd.paged_cache_shardings`` (blocks
over data), activations pinned by ``act_hints``.  The lane row (tokens,
lengths) shards over data only when ``slots`` divides the dp extent, else it
replicates — correct either way, and ``launch/dryrun.py`` proves these specs
compile for the production decode/prefill cells.  Every plane calls
``jax.device_put`` with the same param shardings, so N planes share ONE
device copy of the weights.

Two jitted programs:

- ``_decode``: the batched decode step over all ``slots`` lanes, explicit
  ``in_shardings``/``out_shardings``, cache donated (the pool cache never
  round-trips through host).
- ``_prefill``: BATCHED prefill — ``[k, plen]`` prompts through one forward
  that builds its own k-batch cache *inside* the jit (no host-side
  ``init_cache`` alloc + upload per request), then one fused
  ``scatter_cache`` lands all k lanes in the pool.  This replaces the
  single-host server's per-request init_cache + per-request scatter chain,
  the fill path's main waste.

Sampling happens INSIDE both programs via ``sampling.keyed_sample``:
per-lane (rid, seed, temperature, top_k, top_p) rows ride as jit arguments
next to the length row, and each lane's token is drawn with the request-keyed
``fold_in(fold_in(key(seed), rid), position)`` — a pure function of the
request, never of plane assignment, slot index, or batch composition.  That
is what lets N planes (and the fleet's kill→re-prefill restore) stay
bit-identical to the reference Server at ANY temperature, not just greedy.

One-pull-per-step invariant: decode bookkeeping (lengths, next tokens, block
tables) is host-resident numpy, uploaded as arguments; the only blocking
device->host sync per decode step (and per prefill group) is the single
``common.device_get`` of the sampled token row.  ``common.count_transfers``
counts these, and the serving tests pin the exact per-step totals — adding a
second pull per step fails an assertion instead of silently regressing p99.

``PagedInferencePlane`` swaps the contiguous per-slot cache lines for a
shared block pool (``serve.blocks.BlockPool``) with per-lane block tables:
slot memory then scales with the pool you provision (live tokens), not
``max_len x slots``.  Greedy outputs are bit-identical to the contiguous
plane whenever ``block_size`` divides ``max_len`` (the gathered view is then
exactly the contiguous cache), and the admission seam reports block costs so
the Router can account blocks instead of whole slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.lm import model as lm
from repro.models.lm.config import LMConfig
from repro.serve import common, sampling
from repro.serve.blocks import BlockPool
from repro.serve.server import ServeConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _decode_positions(lengths):
    """Absolute position of the token each decode step SAMPLES: the input
    token sits at index ``lengths``, so the draw lands at ``lengths + 1``."""
    return lengths + jnp.int32(1)


class InferencePlane:
    """Sharded slot pool + jitted prefill/decode for one host."""

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *,
                 mesh: Mesh | None = None, seed: int = 0):
        from repro.launch import sharding as shd

        self.cfg = cfg
        self.serve = serve
        param_sh, lane_sh, rep, hints = self._common_setup(params, cfg, serve,
                                                           mesh, seed)

        b, s = serve.slots, serve.max_len
        cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
        cache_sh = shd.cache_shardings(cache_shape, cfg, self.mesh)
        self.cache = jax.device_put(lm.init_cache(cfg, b, s), cache_sh)

        def decode_fn(p, tok, cache, lengths, rids, seeds, temps, tks, tps):
            logits, cache = lm.decode_step(p, cfg, tok, cache, lengths,
                                           shardings=hints)
            toks = sampling.keyed_sample(logits, rids, seeds,
                                         _decode_positions(lengths), temps,
                                         tks, tps)
            return toks, cache

        # sampling runs INSIDE the jit with per-lane (rid, seed, temp,
        # top_k, top_p) rows as traced inputs: greedy and sampled lanes
        # share ONE compiled program, and the returned row is already the
        # sampled tokens — still exactly one device→host pull per step
        self._decode = jax.jit(
            decode_fn,
            in_shardings=(param_sh, lane_sh, cache_sh, lane_sh, lane_sh,
                          lane_sh, lane_sh, lane_sh, lane_sh),
            out_shardings=(lane_sh, cache_sh),
            donate_argnums=(2,))

        def prefill_fn(p, tokens, rids, seeds, temps, tks, tps):
            # k-batch cache born INSIDE the jit: zero host alloc/upload
            sub = lm.init_cache(cfg, tokens.shape[0], s)
            logits, sub, _ = lm.prefill(p, cfg, tokens, sub, shardings=hints)
            positions = jnp.full((tokens.shape[0],), tokens.shape[1],
                                 jnp.int32)  # prompt occupies 0..plen-1
            toks = sampling.keyed_sample(logits, rids, seeds, positions,
                                         temps, tks, tps)
            return toks, sub

        # retraces per (k, plen) bucket; prompts are tiny — ship replicated
        self._prefill = jax.jit(prefill_fn,
                                in_shardings=(param_sh, rep, rep, rep, rep,
                                              rep, rep))
        self._scatter = jax.jit(lm.scatter_cache,
                                in_shardings=(cache_sh, None, None),
                                out_shardings=cache_sh, donate_argnums=(0,))

    def _common_setup(self, params, cfg, serve, mesh, seed):
        """Mesh + param placement + lane-row shardings shared by both plane
        flavours.  Returns (param_sh, lane_sh, replicated, act hints)."""
        from repro.launch import sharding as shd
        from repro.launch.mesh import dp_axes, dp_size, make_host_mesh
        from repro.launch.specs import act_hints

        self.mesh = mesh = mesh or make_host_mesh()
        params_shape = jax.eval_shape(lambda: params)
        param_sh = shd.lm_param_shardings(params_shape, cfg, mesh, fsdp=())
        # device_put dedupes: already-committed shards are reused, so every
        # plane shares ONE device copy of the weights
        self.params = jax.device_put(params, param_sh)

        b = serve.slots
        # lane row shardings: batch over the data axes when the pool divides
        dp = dp_axes(mesh)
        lane_spec = P(dp if len(dp) > 1 else dp[0]) if _div(b, dp_size(mesh)) else P()
        lane_sh = NamedSharding(mesh, lane_spec)
        rep = NamedSharding(mesh, P())

        # host-resident decode bookkeeping — uploaded as args, never pulled.
        # The sampling rows mirror the length row: per-lane (rid, seed,
        # temperature, top_k, top_p) ride into the jit as arguments, so a
        # lane's draw is a pure function of ITS request — which plane/slot
        # it occupies and who shares the batch cannot change the token.
        self.lengths = np.zeros((b,), np.int32)
        self.tokens = np.zeros((b, 1), np.int32)
        self.rids = np.zeros((b,), np.int32)
        self.seeds = np.zeros((b,), np.uint32)
        self.temps = np.zeros((b,), np.float32)
        self.top_ks = np.full((b,), sampling.TOP_K_OFF, np.int32)
        self.top_ps = np.full((b,), sampling.TOP_P_OFF, np.float32)
        return param_sh, lane_sh, rep, act_hints(cfg, mesh)

    # ---------------------------------------------------------------- sampling
    def _set_sample_rows(self, slots: list[int], rids, samples) -> tuple:
        """Record each slot's (rid, SampleParams) and return the GROUP row
        arrays for the prefill jit.  ``rids``/``samples`` default to rid 0 /
        greedy for direct callers that never leave temperature 0."""
        k = len(slots)
        if rids is None:
            rids = [0] * k
        if samples is None:
            samples = [sampling.SampleParams()] * k
        seeds, temps, tks, tps = sampling.sample_rows(samples, k)
        grids = np.asarray(rids, np.int32)
        for i, slot in enumerate(slots):
            self.rids[slot] = grids[i]
            self.seeds[slot] = seeds[i]
            self.temps[slot] = temps[i]
            self.top_ks[slot] = tks[i]
            self.top_ps[slot] = tps[i]
        return grids, seeds, temps, tks, tps

    # ------------------------------------------------------------------ lanes
    def free_slots(self) -> list[int]:
        """Lanes with no resident sequence (length 0 = masked/never filled)."""
        return [i for i in range(self.serve.slots) if self.lengths[i] == 0]

    def cache_bytes(self) -> int:
        """Resident device bytes of this plane's KV cache (pool or lines)."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def prefill_into(self, slots: list[int], prompts: np.ndarray,
                     budgets: list[int] | None = None,
                     rids: list[int] | None = None,
                     samples=None) -> np.ndarray:
        """Batched prefill of ``[k, plen]`` prompts into ``slots`` (len k).

        ``budgets`` (per-request remaining token budgets) is accepted for
        interface parity with the paged plane, which sizes each lane's block
        allocation from it; the contiguous plane's lanes are pre-sized to
        ``max_len`` so it is unused here.  ``rids``/``samples`` carry each
        request's identity and sampling contract into the keyed sampler
        (defaults: rid 0, greedy).  Returns the k sampled first tokens
        (host).  One device->host pull for the whole group.
        """
        assert prompts.ndim == 2 and prompts.shape[0] == len(slots)
        grids, seeds, temps, tks, tps = self._set_sample_rows(slots, rids,
                                                              samples)
        toks, sub = self._prefill(self.params,
                                  jnp.asarray(prompts, jnp.int32),
                                  grids, seeds, temps, tks, tps)
        toks = common.device_get(toks)
        self.cache = self._scatter(self.cache, sub,
                                   np.asarray(slots, np.int32))
        for i, slot in enumerate(slots):
            self.lengths[slot] = prompts.shape[1]
            self.tokens[slot, 0] = toks[i]
        return toks

    def decode(self) -> np.ndarray:
        """One batched decode step over the pool.  Returns the sampled token
        row (host, [slots]) — the step's single device→host pull."""
        toks, self.cache = self._decode(self.params, self.tokens,
                                        self.cache, self.lengths, self.rids,
                                        self.seeds, self.temps, self.top_ks,
                                        self.top_ps)
        return common.device_get(toks)

    def advance(self, slot: int, tok: int) -> None:
        """Commit a decode step's token on a live lane."""
        self.lengths[slot] += 1
        self.tokens[slot, 0] = tok

    def release(self, slot: int) -> None:
        """Retire a lane: mask its token/length so later decode steps never
        touch its stale state (the cache slice is replaced at next prefill).
        Sampling rows reset to greedy — a dead lane's draw is pure argmax
        and cannot consume or perturb any request's keyed stream."""
        self.lengths[slot] = 0
        self.tokens[slot, 0] = 0
        self.rids[slot] = 0
        self.seeds[slot] = 0
        self.temps[slot] = 0.0
        self.top_ks[slot] = sampling.TOP_K_OFF
        self.top_ps[slot] = sampling.TOP_P_OFF


class PagedInferencePlane(InferencePlane):
    """Slot pool backed by a shared paged KV-cache (block pool + tables).

    The pool holds ``1 + pool_blocks`` physical blocks per layer (block 0 is
    the null block retired lanes write into), padded up to a data-axis
    multiple so the blocks axis shards.  The host keeps the int32 block
    tables ``[slots, max_blocks]`` and uploads them as a decode argument —
    tiny, and it keeps the one-pull-per-step invariant intact.  Block
    allocation is up-front at prefill: ``blocks_for(min(prompt + budget,
    max_len))`` per request, so decode never allocates and admission failure
    is a clean ``Backpressure`` from ``BlockPool.alloc``.
    """

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *,
                 mesh: Mesh | None = None, seed: int = 0):
        from repro.launch import sharding as shd
        from repro.launch.mesh import dp_size

        if serve.block_size is None or serve.block_size < 1:
            raise ValueError(f"paged plane needs block_size >= 1, "
                             f"got {serve.block_size}")
        self.cfg = cfg
        self.serve = serve
        param_sh, lane_sh, rep, hints = self._common_setup(params, cfg, serve,
                                                           mesh, seed)

        b, s = serve.slots, serve.max_len
        bs = serve.block_size
        self.block_size = bs
        #: table width: logical blocks per lane at max_len
        self.max_blocks = -(-s // bs)
        usable = serve.pool_capacity()
        self.pool = BlockPool(usable, bs)
        # device pool: + null block, padded to a dp multiple for sharding
        dp_n = dp_size(self.mesh)
        n_dev = -(-(1 + usable) // dp_n) * dp_n
        self._mask = lm.paged_cache_mask(cfg)
        cache_shape = jax.eval_shape(
            lambda: lm.init_paged_cache(cfg, b, s, num_blocks=n_dev, block_size=bs))
        cache_sh = shd.paged_cache_shardings(cache_shape, cfg, self.mesh, self._mask)
        self.cache = jax.device_put(
            lm.init_paged_cache(cfg, b, s, num_blocks=n_dev, block_size=bs),
            cache_sh)
        #: host block tables; row of a retired lane is all-null
        self.tables = np.zeros((b, self.max_blocks), np.int32)
        self._blocks: list[list[int]] = [[] for _ in range(b)]

        def decode_fn(p, tok, cache, lengths, tables, rids, seeds, temps,
                      tks, tps):
            logits, cache = lm.decode_step(p, cfg, tok, cache, lengths,
                                           shardings=hints, paged=(tables, bs))
            toks = sampling.keyed_sample(logits, rids, seeds,
                                         _decode_positions(lengths), temps,
                                         tks, tps)
            return toks, cache

        self._decode = jax.jit(
            decode_fn,
            in_shardings=(param_sh, lane_sh, cache_sh, lane_sh, rep, lane_sh,
                          lane_sh, lane_sh, lane_sh, lane_sh),
            out_shardings=(lane_sh, cache_sh),
            donate_argnums=(2,))

        def prefill_fn(p, tokens, rids, seeds, temps, tks, tps):
            sub = lm.init_cache(cfg, tokens.shape[0], s)
            logits, sub, _ = lm.prefill(p, cfg, tokens, sub, shardings=hints)
            positions = jnp.full((tokens.shape[0],), tokens.shape[1],
                                 jnp.int32)
            toks = sampling.keyed_sample(logits, rids, seeds, positions,
                                         temps, tks, tps)
            return toks, sub

        self._prefill = jax.jit(prefill_fn,
                                in_shardings=(param_sh, rep, rep, rep, rep,
                                              rep, rep))

        def scatter_fn(cache, sub, slots, phys):
            return lm.scatter_cache_paged(cache, sub, slots, phys,
                                          block_size=bs, mask=self._mask)

        self._scatter = jax.jit(scatter_fn,
                                in_shardings=(cache_sh, None, None, None),
                                out_shardings=cache_sh, donate_argnums=(0,))

    # ------------------------------------------------------------- accounting
    def block_cost(self, prompt_len: int, budget: int) -> int:
        """Blocks a request occupies for its lifetime (allocated up front)."""
        return self.pool.blocks_for(min(prompt_len + budget, self.serve.max_len))

    def free_blocks(self) -> int:
        return self.pool.available

    # ------------------------------------------------------------------ lanes
    def prefill_into(self, slots: list[int], prompts: np.ndarray,
                     budgets: list[int] | None = None,
                     rids: list[int] | None = None,
                     samples=None) -> np.ndarray:
        """Paged batched prefill: allocate each lane's lifetime blocks, land
        the prompt blocks through the tables, record first tokens.

        Raises ``Backpressure`` (after rolling back the group's partial
        allocations) if the pool cannot cover the group — the Router's block
        accounting makes this unreachable in the engine path, but direct
        callers get the clean failure instead of corrupted tables.
        """
        assert prompts.ndim == 2 and prompts.shape[0] == len(slots)
        k, plen = prompts.shape
        if budgets is None:
            budgets = [self.serve.max_new_tokens] * k
        got: list[list[int]] = []
        try:
            for budget in budgets:
                got.append(self.pool.alloc(self.block_cost(plen, budget)))
        except Exception:
            for blocks in got:
                self.pool.free(blocks)
            raise
        nbp = self.pool.blocks_for(plen)  # blocks the prompt itself covers
        for slot, blocks in zip(slots, got):
            self._blocks[slot] = blocks
            self.tables[slot, :] = 0
            self.tables[slot, :len(blocks)] = blocks
        phys = np.stack([self.tables[slot, :nbp] for slot in slots])

        grids, seeds, temps, tks, tps = self._set_sample_rows(slots, rids,
                                                              samples)
        toks, sub = self._prefill(self.params,
                                  jnp.asarray(prompts, jnp.int32),
                                  grids, seeds, temps, tks, tps)
        toks = common.device_get(toks)
        self.cache = self._scatter(self.cache, sub,
                                   np.asarray(slots, np.int32), phys)
        for i, slot in enumerate(slots):
            self.lengths[slot] = plen
            self.tokens[slot, 0] = toks[i]
        return toks

    def decode(self) -> np.ndarray:
        """One batched decode step through the block tables.  Same
        single-pull contract as the contiguous plane."""
        toks, self.cache = self._decode(self.params, self.tokens,
                                        self.cache, self.lengths,
                                        self.tables, self.rids, self.seeds,
                                        self.temps, self.top_ks, self.top_ps)
        return common.device_get(toks)

    def release(self, slot: int) -> None:
        """Retire a lane: free its blocks back to the pool and null its
        table row, so the lane's masked decode writes land in block 0."""
        super().release(slot)
        if self._blocks[slot]:
            self.pool.free(self._blocks[slot])
            self._blocks[slot] = []
        self.tables[slot, :] = 0
