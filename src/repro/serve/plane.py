"""InferencePlane: one sharded slot pool — the device half of the engine.

The serving analogue of the training DataPlane/Engine split: a plane owns
every device-resident object (weights, the slot-pool KV cache, the jitted
prefill/decode programs) for ONE host's pool, laid out over a (data × model)
mesh with the exact shardings ``launch/dryrun.py`` proves compile for the
production decode/prefill cells (``shd.lm_param_shardings`` with no FSDP,
``shd.cache_shardings``, ``act_hints`` activation pins).  The engine above it
only moves token ids and bookkeeping.

Two jitted programs:

- ``_decode``: the batched decode step over all ``slots`` lanes, explicit
  ``in_shardings``/``out_shardings``, cache donated (the pool cache never
  round-trips through host).
- ``_prefill``: BATCHED prefill — ``[k, plen]`` prompts through one forward
  that builds its own k-batch cache *inside* the jit (no host-side
  ``init_cache`` alloc + upload per request), then one fused
  ``scatter_cache`` lands all k lanes in the pool.  This replaces the
  single-host server's per-request init_cache + per-request scatter chain,
  the fill path's main waste.

Decode bookkeeping (lengths, next tokens) is host-resident numpy; the only
blocking sync per decode step is the single ``device_get`` of the sampled
token row (see ``repro.serve.common``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.lm import model as lm
from repro.models.lm.config import LMConfig
from repro.serve import common
from repro.serve.server import ServeConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class InferencePlane:
    """Sharded slot pool + jitted prefill/decode for one host."""

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *,
                 mesh: Mesh | None = None, seed: int = 0):
        from repro.launch import sharding as shd
        from repro.launch.mesh import dp_axes, dp_size, make_host_mesh
        from repro.launch.specs import act_hints

        self.cfg = cfg
        self.serve = serve
        self.mesh = mesh = mesh or make_host_mesh()
        self._key = jax.random.PRNGKey(seed)

        b, s = serve.slots, serve.max_len
        params_shape = jax.eval_shape(lambda: params)
        param_sh = shd.lm_param_shardings(params_shape, cfg, mesh, fsdp=())
        self.params = jax.device_put(params, param_sh)
        cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
        cache_sh = shd.cache_shardings(cache_shape, cfg, mesh)
        self.cache = jax.device_put(lm.init_cache(cfg, b, s), cache_sh)

        # lane row shardings: batch over the data axes when the pool divides
        dp = dp_axes(mesh)
        lane_spec = P(dp if len(dp) > 1 else dp[0]) if _div(b, dp_size(mesh)) else P()
        lane_sh = NamedSharding(mesh, lane_spec)
        rep = NamedSharding(mesh, P())
        hints = act_hints(cfg, mesh)

        # host-resident decode bookkeeping — uploaded as args, never pulled
        self.lengths = np.zeros((b,), np.int32)
        self.tokens = np.zeros((b, 1), np.int32)

        self._decode = jax.jit(
            lambda p, tok, cache, lengths: lm.decode_step(
                p, cfg, tok, cache, lengths, shardings=hints),
            in_shardings=(param_sh, lane_sh, cache_sh, lane_sh),
            out_shardings=(lane_sh, cache_sh),
            donate_argnums=(2,))

        def prefill_fn(p, tokens):
            # k-batch cache born INSIDE the jit: zero host alloc/upload
            sub = lm.init_cache(cfg, tokens.shape[0], s)
            logits, sub, _ = lm.prefill(p, cfg, tokens, sub, shardings=hints)
            return logits, sub

        # retraces per (k, plen) bucket; prompts are tiny — ship replicated
        self._prefill = jax.jit(prefill_fn, in_shardings=(param_sh, rep))
        self._scatter = jax.jit(lm.scatter_cache,
                                in_shardings=(cache_sh, None, None),
                                out_shardings=cache_sh, donate_argnums=(0,))

    # ---------------------------------------------------------------- sampling
    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.serve.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(
            k, logits / self.serve.temperature).astype(jnp.int32)

    # ------------------------------------------------------------------ lanes
    def free_slots(self) -> list[int]:
        """Lanes with no resident sequence (length 0 = masked/never filled)."""
        return [i for i in range(self.serve.slots) if self.lengths[i] == 0]

    def prefill_into(self, slots: list[int], prompts: np.ndarray) -> np.ndarray:
        """Batched prefill of ``[k, plen]`` prompts into ``slots`` (len k).

        Returns the k sampled first tokens (host).  One device→host pull for
        the whole group.
        """
        assert prompts.ndim == 2 and prompts.shape[0] == len(slots)
        logits, sub = self._prefill(self.params, jnp.asarray(prompts, jnp.int32))
        toks = common.device_get(self._sample(logits))
        self.cache = self._scatter(self.cache, sub,
                                   np.asarray(slots, np.int32))
        for i, slot in enumerate(slots):
            self.lengths[slot] = prompts.shape[1]
            self.tokens[slot, 0] = toks[i]
        return toks

    def decode(self) -> np.ndarray:
        """One batched decode step over the pool.  Returns the sampled token
        row (host, [slots]) — the step's single device→host pull."""
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache, self.lengths)
        return common.device_get(self._sample(logits))

    def advance(self, slot: int, tok: int) -> None:
        """Commit a decode step's token on a live lane."""
        self.lengths[slot] += 1
        self.tokens[slot, 0] = tok

    def release(self, slot: int) -> None:
        """Retire a lane: mask its token/length so later decode steps never
        touch its stale state (the cache slice is replaced at next prefill)."""
        self.lengths[slot] = 0
        self.tokens[slot, 0] = 0
