"""Single-host continuous-batching server over a fixed slot pool.

The serving analogue of the paper's workflow: weights and caches are resident
on device; the host only ships token ids.  ``Server`` keeps ``slots`` decode
lanes; finished lanes are refilled from the request queue via single-request
prefill into the shared cache (per-slot dynamic_update on the batch dim).

This is the REFERENCE implementation — one lane prefilled at a time, greedy
path pinned bit-identical to manual decode by tests.  The production engine
(``repro.serve.engine.ServeEngine``) batches prefill and shards the pool over
a (data × model) mesh; its greedy output is pinned bit-identical to this
server, which keeps the whole stack anchored to hand-rolled decode.

Decode bookkeeping (lengths, last tokens, lane occupancy) lives on the HOST:
the only blocking device→host sync per decode step is the single
``device_get`` of the sampled token row — per-lane ``int(arr[slot])`` reads
would serialize O(slots) stream stalls into the latency path.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import model as lm
from repro.models.lm.config import LMConfig
from repro.serve import common, sampling
from repro.serve.sampling import SampleParams


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4  # concurrent decode lanes
    max_len: int = 256  # cache capacity per lane
    max_new_tokens: int = 32
    #: default per-request sampling contract (each submit may override):
    #: draws are request-keyed — ``fold_in(fold_in(key(seed), rid), pos)``
    #: — so they never depend on plane/slot/batch placement.
    temperature: float = 0.0  # 0 = greedy
    sample_seed: int = 0  # default per-request base seed
    top_k: int | None = None  # keep the k largest logits (None = off)
    top_p: float | None = None  # nucleus mass cutoff in (0, 1] (None = off)
    eos_id: int | None = None
    #: paged KV: tokens per cache block (None = contiguous per-slot lines).
    #: The reference Server ignores it — it stays the contiguous anchor.
    block_size: int | None = None
    #: usable blocks in the shared pool; None = slots * ceil(max_len /
    #: block_size), i.e. contiguous capacity at block granularity.  Size it
    #: to the EXPECTED live tokens (prompt+budget per request x slots) to
    #: realise the memory win; admission accounts blocks and backpressures
    #: cleanly when the pool is exhausted.
    pool_blocks: int | None = None

    def __post_init__(self):
        # a negative temperature used to silently decode greedy; reject it
        # (and the other sampling knobs) at CONFIG time, before a request
        # ever rides on the bad default
        sampling.SampleParams(seed=self.sample_seed,
                              temperature=self.temperature,
                              top_k=(sampling.TOP_K_OFF if self.top_k is None
                                     else self.top_k),
                              top_p=(sampling.TOP_P_OFF if self.top_p is None
                                     else self.top_p)).validate()

    def pool_capacity(self) -> int:
        """Usable blocks in the paged pool (0 when not paged)."""
        if self.block_size is None:
            return 0
        if self.pool_blocks is not None:
            return self.pool_blocks
        return self.slots * (-(-self.max_len // self.block_size))


def validate_request(serve: ServeConfig, prompt: np.ndarray,
                     max_new_tokens: int | None) -> int:
    """Resolve + validate a request's token budget.  Returns the budget.

    ``max_new_tokens`` compares against ``None`` (an explicit 0 is NOT "use
    the default" — it is rejected, there is nothing to generate).  Over-long
    prompts are rejected here: ``len(prompt) + budget`` must fit the lane's
    ``max_len`` cache or the decode writes would wrap into the slice a
    neighbouring position owns.
    """
    budget = serve.max_new_tokens if max_new_tokens is None else int(max_new_tokens)
    if budget < 1:
        raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
    if prompt.ndim != 1 or prompt.size == 0:
        raise ValueError(f"prompt must be a non-empty 1-D token array, "
                         f"got shape {prompt.shape}")
    if prompt.size + budget > serve.max_len:
        raise ValueError(
            f"prompt ({prompt.size} tokens) + max_new_tokens ({budget}) "
            f"exceeds max_len ({serve.max_len}); shorten the prompt or "
            f"raise ServeConfig.max_len")
    return budget


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    out: list[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    sample: SampleParams = dataclasses.field(default_factory=SampleParams)


class Server:
    """Continuous-batching server around prefill/decode_step.

    ``seed`` is accepted for API compatibility but no longer feeds
    sampling: draws are request-keyed (``ServeConfig.sample_seed`` /
    per-submit ``seed=``), so output never depends on server identity.
    """

    def __init__(self, params, cfg: LMConfig, serve: ServeConfig, *, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.queue: deque[_Request] = deque()
        self.done: dict[int, list[int]] = {}
        self._next_rid = 0

        b, s = serve.slots, serve.max_len
        self.cache = lm.init_cache(cfg, b, s)
        # host-resident bookkeeping: uploaded as decode args (cheap, async),
        # never pulled back per-lane.  The sampling rows mirror the length
        # row: per-lane (rid, seed, temperature, top_k, top_p) ship as
        # sampler arguments, so a draw is a pure function of the lane's own
        # request — a retired neighbour can never advance anyone's stream.
        self.lengths = np.zeros((b,), np.int32)
        self.tokens = np.zeros((b, 1), np.int32)
        self.active: list[_Request | None] = [None] * b
        self.rids = np.zeros((b,), np.int32)
        self.seeds = np.zeros((b,), np.uint32)
        self.temps = np.zeros((b,), np.float32)
        self.top_ks = np.full((b,), sampling.TOP_K_OFF, np.int32)
        self.top_ps = np.full((b,), sampling.TOP_P_OFF, np.float32)

        self._decode = jax.jit(
            lambda p, tok, cache, lengths: lm.decode_step(p, cfg, tok, cache, lengths))
        self._prefill1 = jax.jit(
            lambda p, tok, cache: lm.prefill(p, cfg, tok, cache))
        self._sampler = jax.jit(sampling.keyed_sample)

    # ------------------------------------------------------------------ queue
    def submit(self, prompt_tokens: np.ndarray, *,
               max_new_tokens: int | None = None, seed: int | None = None,
               temperature: float | None = None, top_k: int | None = None,
               top_p: float | None = None) -> int:
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        budget = validate_request(self.serve, prompt, max_new_tokens)
        sample = SampleParams.resolve(self.serve, seed=seed,
                                      temperature=temperature, top_k=top_k,
                                      top_p=top_p)
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Request(rid, prompt, budget=budget, sample=sample))
        return rid

    def _fill_slot(self, slot: int) -> bool:
        """Prefill queued requests into ``slot`` until one survives.

        A request can retire AT the prefill token: budget already met
        (max_new_tokens=1) or the first sampled token is EOS — it must never
        occupy a decode lane, or it decodes one token past its contract.
        """
        while self.queue:
            req = self.queue.popleft()
            # single-lane prefill into a fresh 1-batch cache, then scatter
            cache1 = lm.init_cache(self.cfg, 1, self.serve.max_len)
            logits, cache1, _ = self._prefill1(
                self.params, jnp.asarray(req.prompt[None]), cache1)
            # the prefill draw sits at absolute position plen (prompt
            # occupies 0..plen-1) — the start of the request's keyed stream
            tok = int(common.device_get(self._sample(
                logits, [req], positions=np.array([req.prompt.size],
                                                  np.int32)))[0])
            req.out.append(tok)
            hit_eos = self.serve.eos_id is not None and tok == self.serve.eos_id
            if len(req.out) >= req.budget or hit_eos:
                self.done[req.rid] = req.out  # retired at prefill; slot stays free
                continue

            def put(big, small):
                # stage-stacked caches: [repeats, ...] with batch at axis 1
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=1)

            self.cache = jax.tree.map(put, self.cache, cache1)
            self.lengths[slot] = req.prompt.size  # prefill length, known on host
            self.tokens[slot, 0] = tok
            self.active[slot] = req
            self.rids[slot] = req.rid
            self.seeds[slot] = req.sample.seed
            self.temps[slot] = req.sample.temperature
            self.top_ks[slot] = req.sample.top_k
            self.top_ps[slot] = req.sample.top_p
            return True
        return False

    def _sample(self, logits: jnp.ndarray, reqs: list[_Request],
                positions: np.ndarray) -> jnp.ndarray:
        """Request-keyed draws for an ad-hoc row of requests (prefill)."""
        seeds, temps, tks, tps = sampling.sample_rows(
            [r.sample for r in reqs], len(reqs))
        rids = np.array([r.rid for r in reqs], np.int32)
        return self._sampler(logits, rids, seeds, positions, temps, tks, tps)

    def _sample_pool(self, logits: jnp.ndarray) -> jnp.ndarray:
        """Request-keyed draws for the whole slot pool (decode).  Position
        of the token being sampled = current length + 1 (the decode input
        token itself sits at index ``lengths``).  Masked lanes carry
        temperature 0 and are ignored by the caller."""
        return self._sampler(logits, self.rids, self.seeds,
                             self.lengths + np.int32(1), self.temps,
                             self.top_ks, self.top_ps)

    # ------------------------------------------------------------------- step
    def step(self) -> int:
        """Refill free slots, run one batched decode step.  Returns #active."""
        for slot in range(self.serve.slots):
            if self.active[slot] is None:
                if not self._fill_slot(slot):
                    break
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.tokens, self.cache,
                                          self.lengths)
        # the step's ONE device→host sync: the whole sampled token row
        next_tok = common.device_get(self._sample_pool(logits))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.lengths[slot] += 1
            tok = int(next_tok[slot])
            self.tokens[slot, 0] = tok  # next step's input for this lane
            req.out.append(tok)
            hit_eos = self.serve.eos_id is not None and tok == self.serve.eos_id
            full = self.lengths[slot] >= self.serve.max_len - 1
            if len(req.out) >= req.budget or hit_eos or full:
                self.done[req.rid] = req.out
                self.active[slot] = None
                # mask the retired lane so later steps never decode its
                # stale token (its length resets; the cache slice is
                # overwritten whole at the next prefill).  The sampling rows
                # reset to greedy: a dead lane's draw is pure argmax and
                # cannot consume or perturb any request's keyed stream.
                self.lengths[slot] = 0
                self.tokens[slot, 0] = 0
                self.rids[slot] = 0
                self.seeds[slot] = 0
                self.temps[slot] = 0.0
                self.top_ks[slot] = sampling.TOP_K_OFF
                self.top_ps[slot] = sampling.TOP_P_OFF
        return sum(1 for r in self.active if r is not None)

    def run(self) -> dict[int, list[int]]:
        """Drain the queue to completion."""
        while self.queue or any(r is not None for r in self.active):
            self.step()
        return self.done
