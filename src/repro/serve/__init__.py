"""Serving engine: sharded continuous batching (the Engine's inference twin).

- ``Server``/``ServeConfig`` — the single-host reference server, greedy path
  pinned bit-identical to manual decode.
- ``InferencePlane`` — one host's sharded slot pool + jitted prefill/decode
  over a (data × model) mesh.
- ``Router`` — bounded admission (``Backpressure``), deadlines, prompt-length
  grouping for batched prefill.
- ``ServeEngine`` — Router + plane fleet; greedy output pinned bit-identical
  to ``Server``.
"""
from repro.serve.common import count_transfers, device_get
from repro.serve.engine import ServeEngine
from repro.serve.plane import InferencePlane
from repro.serve.router import Backpressure, Router, ServeRequest
from repro.serve.server import ServeConfig, Server, validate_request

__all__ = ["Backpressure", "InferencePlane", "Router", "ServeConfig",
           "ServeEngine", "ServeRequest", "Server", "count_transfers",
           "device_get", "validate_request"]
