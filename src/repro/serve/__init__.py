"""Serving engine: sharded continuous batching (the Engine's inference twin).

- ``Server``/``ServeConfig`` — the single-host reference server, greedy path
  pinned bit-identical to manual decode.
- ``InferencePlane`` — one host's sharded slot pool + jitted prefill/decode
  over a (data × model) mesh.
- ``PagedInferencePlane``/``BlockPool`` — paged KV: fixed-size cache blocks
  from a shared pool, so slot memory scales with live tokens instead of
  ``max_len × slots``; pool exhaustion backpressures instead of OOM-ing.
- ``Router`` — bounded admission (``Backpressure``), deadlines, prompt-length
  grouping for batched prefill, block-budget accounting for paged pools.
- ``ServeEngine`` — Router + plane fleet; greedy output pinned bit-identical
  to ``Server``.
- ``ServeWorker``/``FleetEngine`` — elastic fleet: per-host worker processes
  announcing through heartbeat transports; the coordinator re-prefills a dead
  worker's in-flight requests on survivors and re-admits returning hosts.
"""
from repro.serve.blocks import BlockPool, NULL_BLOCK
from repro.serve.common import count_transfers, device_get
from repro.serve.engine import ServeEngine
from repro.serve.fleet import FileMailbox, FleetEngine, LocalMailbox, ServeWorker
from repro.serve.plane import InferencePlane, PagedInferencePlane
from repro.serve.router import Backpressure, Router, ServeRequest
from repro.serve.server import ServeConfig, Server, validate_request

__all__ = ["Backpressure", "BlockPool", "FileMailbox", "FleetEngine",
           "InferencePlane", "LocalMailbox", "NULL_BLOCK",
           "PagedInferencePlane", "Router", "ServeConfig", "ServeEngine",
           "ServeRequest", "ServeWorker", "Server", "count_transfers",
           "device_get", "validate_request"]
