"""Serving engine: sharded continuous batching (the Engine's inference twin).

- ``Server``/``ServeConfig`` — the single-host reference server, greedy path
  pinned bit-identical to manual decode.
- ``InferencePlane`` — one host's sharded slot pool + jitted prefill/decode
  over a (data × model) mesh.
- ``PagedInferencePlane``/``BlockPool`` — paged KV: fixed-size cache blocks
  from a shared pool, so slot memory scales with live tokens instead of
  ``max_len × slots``; pool exhaustion backpressures instead of OOM-ing.
- ``Router`` — bounded admission (``Backpressure``), deadlines, prompt-length
  grouping for batched prefill, block-budget accounting for paged pools.
- ``SampleParams``/``keyed_sample`` — request-keyed sampling: every draw is
  ``fold_in(fold_in(key(seed), rid), position)``, a pure function of the
  request, so temperature > 0 output is independent of plane/slot/batch
  placement and survives re-prefill bit-exactly.
- ``ServeEngine`` — Router + plane fleet; output pinned bit-identical to
  ``Server`` at any temperature.
- ``ServeWorker``/``FleetEngine`` — elastic fleet: per-host worker processes
  announcing through heartbeat transports; the coordinator re-prefills a dead
  worker's in-flight requests on survivors and re-admits returning hosts.
"""
from repro.serve.blocks import BlockPool, NULL_BLOCK
from repro.serve.common import count_transfers, device_get
from repro.serve.engine import ServeEngine
from repro.serve.fleet import FileMailbox, FleetEngine, LocalMailbox, ServeWorker
from repro.serve.plane import InferencePlane, PagedInferencePlane
from repro.serve.router import (Backpressure, Router, ServeRequest,
                                TERMINAL_STATUSES)
from repro.serve.sampling import SampleParams, keyed_sample
from repro.serve.server import ServeConfig, Server, validate_request

__all__ = ["Backpressure", "BlockPool", "FileMailbox", "FleetEngine",
           "InferencePlane", "LocalMailbox", "NULL_BLOCK",
           "PagedInferencePlane", "Router", "SampleParams", "ServeConfig",
           "ServeEngine", "ServeRequest", "ServeWorker", "Server",
           "TERMINAL_STATUSES", "count_transfers", "device_get",
           "keyed_sample", "validate_request"]
