"""Elastic serving fleet: per-host planes behind one coordinator.

The fleet half of "Production serving, part 2": planes stop being objects in
one process and become per-host worker processes that announce liveness
through the PR 3-5 heartbeat transports, while a driver-side coordinator
reuses the PR 5 ``LeaderTracker`` as its liveness oracle:

- ``ServeWorker`` — one host's serving process: a single-plane
  ``ServeEngine`` wrapped in a mailbox pump.  Every tick it drains its inbox
  (assign/cancel/stop), runs one engine step, and reports every NEWLY
  generated token plus completions back to the coordinator, then emits a
  heartbeat.  Streaming tokens per tick is what makes the restore path
  possible: the coordinator always knows each request's generated prefix.

- ``FleetEngine`` — the coordinator: ``Router`` admission (validation,
  backpressure, deadlines), block/slot capacity mirrored per worker (the
  same ``blocks_for`` arithmetic the worker's own pool enforces, so the
  mirror is exact), assignment of queued requests to live workers, and the
  RESTORE path: when the tracker times a worker out, its in-flight requests
  are re-queued at the front and re-prefilled on survivors from
  ``prompt + generated prefix`` with the remaining budget.  This is EXACT
  at ANY temperature — greedy continuation depends only on the token
  prefix, and sampled draws are request-keyed by absolute position
  (``repro.serve.sampling``): the survivor's prefill draw at position
  ``plen + g`` re-derives the very key the dead host would have used for
  its next decode step.  A returning host re-attaches with a fresh mailbox
  incarnation (``attempt``); its resumed beats make the tracker report it
  live again and the coordinator assigns to it like any survivor.

Mailboxes are single-writer single-reader ordered spools.  ``FileMailbox``
uses the same atomic write+rename idiom as ``FileHeartbeatTransport`` (a
message is visible only when complete) and strictly sequential sequence
numbers (the reader stops at the first gap, so reordered directory listings
cannot reorder messages).  ``LocalMailbox`` is the in-process flavour for
tests; it round-trips through JSON so both flavours present identical
payloads (string keys).

Stale-incarnation safety: every assign/report carries the worker's
``attempt``.  After a kill + re-attach, messages from the dead incarnation
(still sitting in its old spool, or racing in) are dropped on both sides, so
a request can never be double-finished by its pre-kill ghost.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.serve.router import Router, ServeRequest, TERMINAL_STATUSES
from repro.serve.server import ServeConfig, validate_request


# ------------------------------------------------------------------ mailboxes
class LocalMailbox:
    """In-process single-writer single-reader message spool (test flavour)."""

    def __init__(self):
        self._q: deque[dict] = deque()

    def send(self, payload: dict) -> None:
        # JSON round-trip so payloads look exactly like the file flavour's
        self._q.append(json.loads(json.dumps(payload)))

    def recv(self) -> list[dict]:
        out = list(self._q)
        self._q.clear()
        return out


class FileMailbox:
    """Cross-process spool: one JSON file per message, atomic rename,
    strictly sequential sequence numbers.

    Single writer, single reader.  The reader consumes files in sequence
    order and stops at the first missing number, so a directory listing that
    surfaces ``m_00000007`` before ``m_00000006`` (or a message still being
    written) just delays it one poll — messages are never reordered or torn.
    A fresh incarnation of a worker gets a FRESH directory (the coordinator
    bumps ``attempt``), so restart sequence-number reuse cannot happen.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        seqs = [int(n[2:10]) for n in os.listdir(directory)
                if n.startswith("m_") and n.endswith(".json")]
        self._seq = max(seqs, default=0)  # writer side
        self._next = 1  # reader side

    def send(self, payload: dict) -> None:
        self._seq += 1
        fd, tmp = tempfile.mkstemp(prefix=".tmp-", dir=self.dir)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, os.path.join(self.dir, f"m_{self._seq:08d}.json"))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def recv(self) -> list[dict]:
        out = []
        while True:
            path = os.path.join(self.dir, f"m_{self._next:08d}.json")
            try:
                with open(path) as f:
                    out.append(json.load(f))
            except (OSError, ValueError):
                break  # missing or mid-write: next poll
            self._next += 1
        return out


# --------------------------------------------------------------------- worker
class ServeWorker:
    """One host's serving process: plane + engine + mailbox pump.

    Wraps a single-plane ``ServeEngine`` (contiguous or paged per the
    ServeConfig), so the worker inherits the whole PR 8 serving stack —
    batched prefill, block accounting, retirement rules, one pull per step.
    The worker's engine queue is unbounded: fleet-level backpressure lives in
    the coordinator's router; the coordinator never assigns beyond this
    worker's slot/block capacity anyway.
    """

    def __init__(self, params, cfg, serve: ServeConfig, *, worker_id: int,
                 inbox, outbox, heartbeat=None, attempt: int = 0,
                 mesh=None, seed: int = 0):
        from repro.serve.engine import ServeEngine

        self.engine = ServeEngine(params, cfg, serve, planes=1, mesh=mesh,
                                  seed=seed, queue_limit=10**9)
        self.worker_id = worker_id
        self.attempt = attempt
        self.inbox, self.outbox, self.hb = inbox, outbox, heartbeat
        self._reqs: dict[int, ServeRequest] = {}  # fleet rid -> local request
        self._reported: dict[int, int] = {}  # fleet rid -> tokens reported
        self._done_sent: set[int] = set()
        self.step_no = 0
        self.stopped = False
        self._tick_beats = True  # run() moves beating to its own thread

    def _pump_inbox(self) -> None:
        for msg in self.inbox.recv():
            kind = msg.get("kind")
            if kind == "stop":
                self.stopped = True
            elif kind == "assign" and msg.get("attempt") == self.attempt:
                for r in msg["reqs"]:
                    # the assignment pins the COORDINATOR's rid and sampling
                    # contract: keyed draws depend on (seed, rid, position)
                    # only, so a restore onto this worker re-derives the dead
                    # incarnation's exact stream
                    self.engine.submit(np.asarray(r["prompt"], np.int32),
                                       max_new_tokens=r["budget"],
                                       rid=int(r["rid"]),
                                       seed=int(r["seed"]),
                                       temperature=float(r["temperature"]),
                                       top_k=int(r["top_k"]),
                                       top_p=float(r["top_p"]))
                    req = self.engine.router.queue[-1]
                    self._reqs[int(r["rid"])] = req
                    self._reported[int(r["rid"])] = 0
            elif kind == "cancel" and msg.get("attempt") == self.attempt:
                req = self._reqs.get(int(msg["rid"]))
                if req is not None and req.status in ("queued", "active"):
                    # an already-passed deadline: the engine's sweep expires
                    # it (queued or holding a lane) on the next step
                    req.deadline = float("-inf")

    def tick(self) -> int:
        """One worker turn: pump inbox, one engine step, report, beat.
        Returns live lanes + queued (0 = idle)."""
        self._pump_inbox()
        live = 0 if self.stopped else self.engine.step()
        toks: dict[str, list[int]] = {}
        done: dict[str, str] = {}
        for rid, req in self._reqs.items():
            n = self._reported[rid]
            if len(req.out) > n:
                toks[str(rid)] = [int(t) for t in req.out[n:]]
                self._reported[rid] = len(req.out)
            if req.status in TERMINAL_STATUSES and rid not in self._done_sent:
                done[str(rid)] = req.status
                self._done_sent.add(rid)
        # tokens and completions ship in ONE message: a crash between "sent
        # the EOS token" and "sent done" is impossible, which keeps the
        # coordinator's restore arithmetic exact
        self.outbox.send({"kind": "report", "attempt": self.attempt,
                          "step": self.step_no, "toks": toks, "done": done,
                          "free_slots": len(self.engine.planes[0].free_slots())})
        if self.hb is not None and self._tick_beats:
            self.hb.emit(self.worker_id, self.step_no)
        self.step_no += 1
        return live

    def run(self, *, poll_s: float = 0.01, step_delay: float = 0.0,
            beat_s: float = 0.25) -> None:
        """Process main loop: tick until a stop message arrives.

        Beats move to a daemon thread: liveness means "the PROCESS is up",
        not "the step loop is fast" — a first-assignment jit compile can
        block a tick for many seconds, and beating from the tick loop would
        make the coordinator declare a perfectly healthy worker dead and
        double-serve its work.  A SIGKILL still silences the thread, so
        death detection is untouched.  The thread is the SOLE emitter
        (``emit``'s per-rank seq counter is not thread-safe)."""
        if self.hb is not None:
            import threading

            self._tick_beats = False

            def beat():
                while not self.stopped:
                    self.hb.emit(self.worker_id, self.step_no)
                    time.sleep(beat_s)

            threading.Thread(target=beat, daemon=True).start()
        while not self.stopped:
            busy = self.tick()
            if step_delay:
                time.sleep(step_delay)
            elif not busy:
                time.sleep(poll_s)


# ---------------------------------------------------------------- coordinator
@dataclasses.dataclass
class _WorkerHandle:
    wid: int
    send: object  # coordinator -> worker mailbox
    recv: object  # worker -> coordinator mailbox
    attempt: int = 0
    #: fleet rid -> (request, mirrored lifetime block cost)
    inflight: dict = dataclasses.field(default_factory=dict)
    live_prev: bool = True
    served: int = 0  # completions credited to this worker (drill evidence)


class FleetEngine:
    """Coordinator for a fleet of ``ServeWorker`` processes.

    Liveness comes from ``LeaderTracker`` over a heartbeat ``step_feed`` —
    the same beat->timeout->succession machinery the training Engine uses;
    here the "plan" a death triggers is re-assignment of the dead worker's
    in-flight requests (see module docstring for the restore path).  The
    tracker's beat-refresh semantics also give re-join for free: a returned
    host's fresh beats flip it live again.
    """

    def __init__(self, serve: ServeConfig, *, world: int, step_feed=None,
                 tracker=None, hb_timeout: float = 2.0,
                 queue_limit: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        from repro.distributed.leader import LeaderTracker

        self.serve = serve
        self.world = world
        self.clock = clock
        if queue_limit is None:
            queue_limit = 4 * world * serve.slots
        self.router = Router(serve, queue_limit=queue_limit, clock=clock)
        self.step_feed = step_feed
        self.tracker = tracker or LeaderTracker(world, own_ranks=(),
                                                timeout=hb_timeout, clock=clock)
        self.workers: dict[int, _WorkerHandle] = {}
        self._requeue: deque[ServeRequest] = deque()  # restore path, FIFO front
        self._block_size = serve.block_size
        self._pool_capacity = serve.pool_capacity()

    # -------------------------------------------------------------- topology
    def attach(self, wid: int, *, send, recv, attempt: int | None = None) -> None:
        """(Re-)attach a worker's mailbox pair.  Re-attaching bumps the
        incarnation ``attempt`` and restores any in-flight requests the old
        incarnation still held (covers an explicit relaunch that races the
        tracker's timeout verdict)."""
        old = self.workers.get(wid)
        if old is not None and old.inflight:
            self._restore(old)
        if attempt is None:
            attempt = 0 if old is None else old.attempt + 1
        self.workers[wid] = _WorkerHandle(wid, send, recv, attempt=attempt)

    def stop_workers(self) -> None:
        for w in self.workers.values():
            w.send.send({"kind": "stop"})

    # ------------------------------------------------------------- admission
    def _block_cost(self, total_tokens: int) -> int:
        return -(-min(total_tokens, self.serve.max_len) // self._block_size)

    def submit(self, prompt_tokens, *, max_new_tokens: int | None = None,
               deadline_s: float | None = None, seed: int | None = None,
               temperature: float | None = None, top_k: int | None = None,
               top_p: float | None = None) -> int:
        """Admit a request (``Backpressure`` / ``ValueError`` as the engine).
        Sampling overrides ride the request through assignment and restore."""
        if self._block_size is not None:
            prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
            budget = validate_request(self.serve, prompt, max_new_tokens)
            need = self._block_cost(prompt.size + budget)
            if need > self._pool_capacity:
                raise ValueError(
                    f"request needs {need} blocks; worker pools only have "
                    f"{self._pool_capacity} — raise pool_blocks or shorten "
                    f"the request")
        return self.router.submit(prompt_tokens, max_new_tokens=max_new_tokens,
                                  deadline_s=deadline_s, seed=seed,
                                  temperature=temperature, top_k=top_k,
                                  top_p=top_p)

    # --------------------------------------------------------------- restore
    def _finalize(self, req: ServeRequest, status: str = "ok") -> None:
        self.router.finish(req, status=status)

    def _restore(self, w: _WorkerHandle) -> None:
        """Re-queue a dead incarnation's in-flight requests (front of line).
        Requests whose reported prefix already satisfies them (budget met or
        EOS — only the worker's final 'done' was lost) finalize directly."""
        for rid, (req, _cost) in list(w.inflight.items()):
            hit_eos = (self.serve.eos_id is not None and req.out
                       and req.out[-1] == self.serve.eos_id)
            if len(req.out) >= req.budget or hit_eos:
                self._finalize(req)
            else:
                req.status = "queued"
                self._requeue.append(req)
        w.inflight.clear()

    # ------------------------------------------------------------------ tick
    def _pump_reports(self) -> None:
        for w in self.workers.values():
            for msg in w.recv.recv():
                if (msg.get("kind") != "report"
                        or msg.get("attempt") != w.attempt):
                    continue  # stale incarnation or foreign message
                for rid_s, toks in msg.get("toks", {}).items():
                    ent = w.inflight.get(int(rid_s))
                    if ent is not None:
                        ent[0].out.extend(int(t) for t in toks)
                for rid_s, status in msg.get("done", {}).items():
                    ent = w.inflight.pop(int(rid_s), None)
                    if ent is not None:
                        self._finalize(ent[0], status=status)
                        w.served += 1

    def _capacity(self, w: _WorkerHandle) -> tuple[int, int | None]:
        free_slots = self.serve.slots - len(w.inflight)
        if self._block_size is None:
            return free_slots, None
        used = sum(cost for _req, cost in w.inflight.values())
        return free_slots, self._pool_capacity - used

    def _dispatch(self, live: set[int]) -> None:
        targets = [w for wid, w in self.workers.items() if wid in live]
        if not targets:
            return
        assigns: dict[int, list[dict]] = {}
        while True:
            src = self._requeue if self._requeue else self.router.queue
            if not src:
                break
            req = src[0]
            # continuation semantics: prompt + generated prefix, remaining
            # budget — identical arithmetic for a fresh request (empty out)
            total = req.prompt.size + req.budget  # lifetime tokens
            cost = (self._block_cost(total)
                    if self._block_size is not None else 0)
            best = None
            for w in targets:
                free_slots, free_blocks = self._capacity(w)
                if free_slots - len(assigns.get(w.wid, ())) < 1:
                    continue
                pend = sum(a["_cost"] for a in assigns.get(w.wid, ()))
                if free_blocks is not None and free_blocks - pend < cost:
                    continue
                load = len(w.inflight) + len(assigns.get(w.wid, ()))
                if best is None or load < best[0]:
                    best = (load, w)
            if best is None:
                break
            w = best[1]
            src.popleft()
            req.status = "active"
            full_prompt = req.prompt.tolist() + [int(t) for t in req.out]
            assigns.setdefault(w.wid, []).append({
                "rid": req.rid, "prompt": full_prompt,
                "budget": req.budget - len(req.out), "_cost": cost,
                "seed": req.sample.seed,
                "temperature": req.sample.temperature,
                "top_k": req.sample.top_k, "top_p": req.sample.top_p,
                "_req": req})
        for wid, entries in assigns.items():
            w = self.workers[wid]
            for e in entries:
                w.inflight[e["rid"]] = (e.pop("_req"), e.pop("_cost"))
            w.send.send({"kind": "assign", "attempt": w.attempt,
                         "reqs": entries})

    def tick(self) -> int:
        """One coordinator turn: observe beats, restore dead workers' work,
        pump reports, expire deadlines, dispatch.  Returns pending work."""
        if self.step_feed is not None:
            self.tracker.observe(self.step_feed())
        live = set(self.tracker.live())
        for w in self.workers.values():
            alive = w.wid in live
            if w.live_prev and not alive and w.inflight:
                self._restore(w)
            w.live_prev = alive
        self._pump_reports()
        self.router.expire()
        for w in self.workers.values():
            for rid, (req, _cost) in list(w.inflight.items()):
                if self.router.past_deadline(req):
                    w.inflight.pop(rid)
                    self._finalize(req, status="timeout")
                    w.send.send({"kind": "cancel", "attempt": w.attempt,
                                 "rid": rid})
        self._dispatch(live)
        return self.pending()

    def pending(self) -> int:
        return (len(self.router.queue) + len(self._requeue)
                + sum(len(w.inflight) for w in self.workers.values()))

    def results(self) -> dict[int, list[int]]:
        return self.router.results()
