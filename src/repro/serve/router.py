"""Request router: bounded admission queue, deadlines, prompt-length groups.

The router owns everything about a request EXCEPT device state: admission
(validation + backpressure when the queue outruns the fleet's slots),
per-request deadlines (expired requests fail fast instead of holding a decode
lane), and the prefill grouping policy — ``pop_group`` hands the engine a
same-length batch of prompts up to a token budget, which is what makes
batched prefill a single ``[k, plen]`` forward instead of k single-lane
passes.

Grouping never changes outputs: greedy decode is per-lane, so admission
order only affects WHEN a request runs, not what it generates — the fleet
bit-identity test pins this.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.serve.sampling import SampleParams
from repro.serve.server import ServeConfig, validate_request

#: statuses a finished request can carry (``truncated`` = the lane was
#: retired because the cache filled before the budget was spent)
TERMINAL_STATUSES = ("ok", "timeout", "truncated")


class Backpressure(RuntimeError):
    """Raised by ``submit`` when the admission queue is full — the caller
    (load balancer, client) must retry or shed load; queueing unboundedly
    would only convert overload into timeout storms."""


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray
    budget: int
    deadline: float | None  # absolute, on the router's clock; None = never
    submitted_at: float = 0.0
    finished_at: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    status: str = "queued"  # queued | active | ok | timeout | truncated
    #: request-keyed sampling contract — rides WITH the request through
    #: planes, fleet mailboxes and re-prefill, so draws never depend on
    #: where the request runs
    sample: SampleParams = dataclasses.field(default_factory=SampleParams)

    @property
    def latency_s(self) -> float | None:
        """Admission→finish latency.  ``None`` until the request reaches a
        terminal status — ``finished_at`` is unset before that, and the old
        ``finished - submitted`` arithmetic went NEGATIVE on in-flight
        requests (0.0 minus a real clock reading)."""
        if self.status not in TERMINAL_STATUSES:
            return None
        return self.finished_at - self.submitted_at


class Router:
    """Admission + scheduling front of the serving engine."""

    def __init__(self, serve: ServeConfig, *, queue_limit: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.serve = serve
        #: max queued (not-yet-prefilled) requests; None = unbounded
        self.queue_limit = queue_limit
        self.clock = clock
        self.queue: deque[ServeRequest] = deque()
        self.done: dict[int, ServeRequest] = {}
        self._next_rid = 0

    def __len__(self) -> int:
        return len(self.queue)

    # -------------------------------------------------------------- admission
    def submit(self, prompt_tokens, *, max_new_tokens: int | None = None,
               deadline_s: float | None = None, seed: int | None = None,
               temperature: float | None = None, top_k: int | None = None,
               top_p: float | None = None, rid: int | None = None) -> int:
        """Admit a request.  Raises ``Backpressure`` when the queue is full,
        ``ValueError`` on an invalid budget/prompt (see ``validate_request``)
        or invalid sampling overrides (negative temperature, bad top_k/p).

        ``seed``/``temperature``/``top_k``/``top_p`` override the
        ``ServeConfig`` defaults for THIS request.  ``rid`` pins an explicit
        request id — the fleet seam: a worker must key its draws with the
        COORDINATOR'S rid, or re-prefill on a different host would re-derive
        a different stream."""
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        budget = validate_request(self.serve, prompt, max_new_tokens)
        sample = SampleParams.resolve(self.serve, seed=seed,
                                      temperature=temperature, top_k=top_k,
                                      top_p=top_p)
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            raise Backpressure(
                f"queue full ({len(self.queue)}/{self.queue_limit} requests); "
                f"retry or shed load")
        now = self.clock()
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = ServeRequest(rid, prompt, budget,
                           deadline=None if deadline_s is None else now + deadline_s,
                           submitted_at=now, sample=sample)
        self.queue.append(req)
        return req.rid

    # -------------------------------------------------------------- deadlines
    def expire(self) -> list[ServeRequest]:
        """Fail queued requests whose deadline passed (they never reach a
        slot).  Active lanes are expired by the engine, which owns them."""
        now = self.clock()
        expired = [r for r in self.queue
                   if r.deadline is not None and now >= r.deadline]
        for r in expired:
            self.queue.remove(r)
            self.finish(r, status="timeout")
        return expired

    def past_deadline(self, req: ServeRequest) -> bool:
        return req.deadline is not None and self.clock() >= req.deadline

    # ------------------------------------------------------------- scheduling
    def pop_group(self, max_requests: int, token_budget: int, *,
                  block_budget: int | None = None,
                  block_cost=None) -> list[ServeRequest]:
        """Pop a batch of SAME-prompt-length requests for one batched prefill.

        Takes the oldest queued request's prompt length as the group key and
        collects up to ``max_requests`` queued requests of that length whose
        summed prompt tokens stay within ``token_budget``.  Other lengths
        stay queued for the next group (the scan skips past them, so one
        odd-length head never starves a same-length run behind it).

        The token budget is a THROUGHPUT knob, so the group's leader always
        ships even alone — a budget smaller than one prompt must not
        deadlock.  Block accounting is different: when ``block_budget`` /
        ``block_cost`` are given (paged planes; ``block_cost(req)`` = the
        target plane's lifetime block count for ``req``), blocks are a HARD
        resource and the group's summed cost must fit the budget.  A leader
        that does not fit returns an EMPTY group — it stays queued (FIFO:
        head-of-line waits rather than being overtaken) until retirements
        free blocks; never-fitting requests are rejected at submit, so this
        cannot deadlock.

        Popped requests flip to status "active".  Grouping never changes
        outputs: decode and the request-keyed draws are per-lane, so the
        batch composition only affects WHEN a request runs (the fleet
        bit-identity tests pin this at temperature 0 AND above).
        """
        if (block_budget is None) != (block_cost is None):
            # passing one without the other used to surface as a bare
            # TypeError deep in the accounting loop, after requests had
            # already been inspected — validate the pairing up front
            raise ValueError(
                "pop_group needs block_budget and block_cost together: "
                f"got block_budget={block_budget!r}, "
                f"block_cost={'None' if block_cost is None else 'set'} "
                "(paged planes supply both; contiguous planes neither)")
        if not self.queue or max_requests <= 0:
            return []
        plen = self.queue[0].prompt.size
        group: list[ServeRequest] = []
        tokens = 0
        blocks = 0
        for r in list(self.queue):
            if r.prompt.size != plen:
                continue
            if group and tokens + plen > token_budget:
                break
            if block_budget is not None:
                cost = block_cost(r)
                if blocks + cost > block_budget:
                    if not group:
                        return []  # head-of-line waits for block frees
                    break
                blocks += cost
            group.append(r)
            tokens += plen
            if len(group) >= max_requests:
                break
        for r in group:
            self.queue.remove(r)
            r.status = "active"
        return group

    # --------------------------------------------------------------- results
    def finish(self, req: ServeRequest, *, status: str = "ok") -> None:
        req.status = status
        req.finished_at = self.clock()
        self.done[req.rid] = req

    def results(self) -> dict[int, list[int]]:
        """rid → generated tokens, for every finished request."""
        return {rid: r.out for rid, r in self.done.items()}
