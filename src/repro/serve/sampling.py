"""Stateless request-keyed sampling: the index-batching principle for PRNGs.

The serving stack used to draw sampled tokens from a mutable per-plane key
stream (``key, k = split(key)`` per step), which made a request's output at
``temperature > 0`` depend on which plane it landed on, what else was in
flight, and even on retired lanes (a whole-row ``categorical`` advances the
stream for dead slots too).  That violates the repo's one structural rule —
construct state at runtime from indices instead of storing it — exactly
where it hurts most: the fleet's kill→re-prefill restore was provably exact
only for greedy decode.

This module replaces the streams with a **pure function of indices**: the
token at sequence position ``pos`` of request ``rid`` is drawn with

    key = fold_in(fold_in(PRNGKey(seed), rid), pos)

so a draw depends only on ``(seed, rid, pos, logits)`` — not on plane
assignment, slot index, batch composition, or any other request.  Positions
are absolute (the prompt occupies ``0..plen-1``; the first sampled token
sits at ``pos = plen``), which is what makes re-prefill exact at any
temperature: a restored request re-prefills from ``prompt + generated
prefix`` of length ``plen + g``, and its prefill draw at ``pos = plen + g``
re-derives the very key (and, greedy-identity having pinned the logits, the
very token) the dead host would have produced next.

``keyed_sample`` is designed to run INSIDE the jitted decode/prefill
programs: per-lane (rid, seed, temperature, top_k, top_p) rows ride along as
jit inputs next to the existing length rows, and temperature is a *traced*
value — greedy and sampled traffic share one compiled program, and a
``temperature == 0`` lane reproduces the historical ``argmax`` of the raw
logits bit-exactly (the greedy bit-identity suite keeps holding).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

#: disabled-filter sentinels (the "off" encodings are real no-op parameter
#: values, so one compiled program serves filtered and unfiltered lanes)
TOP_K_OFF = 0
TOP_P_OFF = 1.0


@dataclasses.dataclass(frozen=True)
class SampleParams:
    """Per-request sampling contract, resolved + validated at submit time.

    ``seed`` is the request's base PRNG seed (folded with rid/position at
    draw time); ``top_k``/``top_p`` filter logits before the draw
    (``TOP_K_OFF``/``TOP_P_OFF`` disable).  ``temperature == 0`` is greedy
    regardless of the other fields.
    """

    seed: int = 0
    temperature: float = 0.0
    top_k: int = TOP_K_OFF
    top_p: float = TOP_P_OFF

    def validate(self) -> "SampleParams":
        if not math.isfinite(self.temperature) or self.temperature < 0.0:
            raise ValueError(
                f"temperature must be finite and >= 0 (0 = greedy), got "
                f"{self.temperature}")
        if not 0 <= int(self.seed) < 2 ** 32:
            raise ValueError(f"seed must fit uint32, got {self.seed}")
        if self.top_k < 0:
            raise ValueError(
                f"top_k must be >= 1 ({TOP_K_OFF} = disabled), got "
                f"{self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1] ({TOP_P_OFF} = disabled), got "
                f"{self.top_p}")
        return self

    @classmethod
    def resolve(cls, serve, *, seed=None, temperature=None, top_k=None,
                top_p=None) -> "SampleParams":
        """Fill per-request overrides from the ``ServeConfig`` defaults and
        validate the result (the submit seam's half of the contract)."""
        return cls(
            seed=int(serve.sample_seed if seed is None else seed),
            temperature=float(serve.temperature if temperature is None
                              else temperature),
            top_k=int((TOP_K_OFF if serve.top_k is None else serve.top_k)
                      if top_k is None else top_k),
            top_p=float((TOP_P_OFF if serve.top_p is None else serve.top_p)
                        if top_p is None else top_p),
        ).validate()


def sample_rows(samples, dtype_len: int) -> tuple:
    """Host-side row arrays (seeds, temps, top_ks, top_ps) for ``dtype_len``
    lanes from a list of ``SampleParams`` (padded with greedy defaults)."""
    seeds = np.zeros((dtype_len,), np.uint32)
    temps = np.zeros((dtype_len,), np.float32)
    tks = np.full((dtype_len,), TOP_K_OFF, np.int32)
    tps = np.full((dtype_len,), TOP_P_OFF, np.float32)
    for i, s in enumerate(samples):
        seeds[i], temps[i], tks[i], tps[i] = s.seed, s.temperature, s.top_k, s.top_p
    return seeds, temps, tks, tps


def request_key(seed, rid, position):
    """The draw key for token ``position`` of request ``rid``: a pure
    function of indices — no stream, nothing to restore."""
    base = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    return jax.random.fold_in(jax.random.fold_in(base, rid), position)


def _filter_top_k(lg, k):
    """Mask logits below the k-th largest to -inf.  ``k <= 0`` disables
    (effective k = vocab).  Ties at the k-th value are kept — the standard
    caveat, and deterministic either way."""
    eff = jnp.where(k <= 0, lg.shape[-1], k)
    kth = jnp.sort(lg)[::-1][eff - 1]
    return jnp.where(lg >= kth, lg, -jnp.inf)


def _filter_top_p(lg, p):
    """Nucleus filter: keep the smallest descending-probability prefix whose
    cumulative mass reaches ``p`` (always >= 1 token).  ``p >= 1`` keeps
    everything — the same code path, no branch."""
    desc = jnp.sort(lg)[::-1]
    cum = jnp.cumsum(jax.nn.softmax(desc))
    keep = jnp.sum(cum < p) + 1  # first index reaching p is inclusive
    thresh = desc[keep - 1]
    return jnp.where(lg >= thresh, lg, -jnp.inf)


def keyed_sample(logits, rids, seeds, positions, temps, top_ks, top_ps):
    """Sample one token per lane from ``logits [B, V]`` with request-keyed
    draws.  All row args are ``[B]``; every output depends only on its own
    lane's ``(seed, rid, position, logits)``.

    A ``temperature == 0`` lane returns ``argmax`` of the RAW logits —
    bit-identical to the historical greedy path (filters never touch it).
    Retired lanes (temperature 0) therefore cost nothing and, unlike the
    old whole-row categorical, can never advance anyone else's draws.
    """

    def one(lg, rid, seed, pos, temp, k, p):
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = request_key(seed, rid, pos)
        filt = _filter_top_p(_filter_top_k(lg, k), p)
        safe_t = jnp.where(temp > 0.0, temp, jnp.float32(1.0))
        drawn = jax.random.categorical(key, filt / safe_t).astype(jnp.int32)
        return jnp.where(temp > 0.0, drawn, greedy)

    return jax.vmap(one)(logits, rids, seeds, positions, temps, top_ks,
                         top_ps)
