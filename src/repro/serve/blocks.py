"""BlockPool: the paged KV-cache allocator (host-side bookkeeping).

The serving analogue of the paper's index-batching trick: instead of
materialising ``max_len`` of contiguous cache per slot up front, cache lines
are paged from a shared pool of fixed-size sequence blocks and a per-request
*block table* maps logical positions to physical blocks.  Slot memory then
scales with live tokens, not ``max_len x slots``, and admission becomes a
block-accounting decision: a request that does not fit raises
``Backpressure`` cleanly instead of OOM-ing the device.

Physical block 0 is the NULL block and is never allocated: retired lanes keep
all-zero block tables, so their (masked, ignored) decode writes land in block
0 and can never corrupt a live request's blocks.  The allocator hands out
blocks ``1..num_blocks``.

Allocation is up-front at admission: a request needs
``blocks_for(min(prompt + budget, max_len))`` blocks for its whole lifetime,
so decode never allocates mid-flight and a prefilled request can always run
to its budget.  Freed blocks return to the free list in retirement order and
are reused immediately (their stale contents are masked by per-lane lengths
until overwritten).
"""
from __future__ import annotations

from collections import deque

from repro.serve.router import Backpressure

#: physical block id reserved as the write sink for retired/masked lanes
NULL_BLOCK = 0


class BlockPool:
    """Free-list allocator over ``num_blocks`` usable KV-cache blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"need at least 1 usable block, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        #: usable blocks (excludes the null block 0)
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self._free: deque[int] = deque(range(1, self.num_blocks + 1))
        self._owned: set[int] = set()

    @property
    def available(self) -> int:
        """Blocks free for allocation right now."""
        return len(self._free)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` cache positions (ceil)."""
        return -(-int(tokens) // self.block_size)

    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` blocks.  Raises ``Backpressure`` on exhaustion —
        the clean admission failure; the caller retries after retirements
        free blocks instead of the device OOM-ing mid-decode."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise Backpressure(
                f"block pool exhausted ({len(self._free)}/{self.num_blocks} "
                f"free, need {n}); retry after retirements")
        blocks = [self._free.popleft() for _ in range(n)]
        self._owned.update(blocks)
        return blocks

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the pool.  Double-free, foreign ids and
        duplicates WITHIN one call raise ``ValueError`` with the pool
        unchanged (atomic failure) — a block on the free list twice would be
        handed to two requests, and a half-applied free used to leave the
        pool in a state no caller could reason about (duplicates passed the
        membership pre-check, then ``KeyError``-ed mid-loop)."""
        if len(set(blocks)) != len(blocks):
            dupes = sorted({b for b in blocks if blocks.count(b) > 1})
            raise ValueError(
                f"duplicate block ids in one free call: {dupes} — the pool "
                f"is unchanged")
        for b in blocks:
            if b not in self._owned:
                raise ValueError(f"free of unallocated block {b}")
        for b in blocks:
            self._owned.remove(b)
            self._free.append(b)
