"""grok-1-314b — MoE decoder: 8 experts, top-2, GQA kv=8.
[hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig, MoEConfig

ARCH = ArchSpec(
    id="grok-1-314b",
    family="moe",
    lm=LMConfig(
        name="grok-1-314b",
        layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32_768, vocab=131_072, head_dim=128,
        attn="full", pos="rope", mlp="geglu",
        moe=MoEConfig(n_experts=8, top_k=2),
    ),
    skips=full_attn_skips(),
    source="hf:xai-org/grok-1",
    # capacity_factor = E/k makes the smoke config worst-case dropless so
    # prefill/decode parity tests are exact (production keeps 1.25).
    smoke_overrides={"moe": MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0)},
)
