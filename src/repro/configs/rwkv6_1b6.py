"""rwkv6-1.6b "Finch" — attention-free RNN with data-dependent decay.
Runs long_500k: per-layer state is [H, 64, 64] regardless of context.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchSpec
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="rwkv6-1.6b",
    family="ssm",
    lm=LMConfig(
        name="rwkv6-1.6b",
        layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/64
        d_ff=7168, vocab=65_536,
        rwkv=True, rwkv_head_size=64, attn="none", pos="none",
        mlp="relu_sq",
    ),
    source="arXiv:2404.05892",
    smoke_overrides={"rwkv_head_size": 16},
)
