"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.
Runs long_500k: SWA window bounds the KV cache and prefill FLOPs.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchSpec
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="h2o-danube-3-4b",
    family="dense",
    lm=LMConfig(
        name="h2o-danube-3-4b",
        layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10_240, vocab=32_000, head_dim=120,
        attn="swa", window=4096, pos="rope", mlp="swiglu",
    ),
    source="arXiv:2401.16818",
    smoke_overrides={"window": 16},
)
