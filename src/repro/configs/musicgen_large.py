"""musicgen-large — decoder-only over EnCodec tokens (frontend STUB: the
EnCodec tokenizer is upstream; ``input_specs`` provides token streams).
MHA (kv == heads), learned absolute positions.
[arXiv:2306.05284; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="musicgen-large",
    family="audio",
    lm=LMConfig(
        name="musicgen-large",
        layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64,
        attn="full", pos="learned", mlp="gelu",
        frontend="frames", max_seq_len=32_768,
    ),
    skips=full_attn_skips(),
    source="arXiv:2306.05284",
)
