"""qwen1.5-4b — dense decoder, MHA (kv == heads), QKV bias.
[hf:Qwen/Qwen1.5-0.5B family scaled per assignment; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="qwen1.5-4b",
    family="dense",
    lm=LMConfig(
        name="qwen1.5-4b",
        layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab=151_936, head_dim=128,
        qkv_bias=True, attn="full", pos="rope", mlp="swiglu",
    ),
    skips=full_attn_skips(),
    source="hf:Qwen/Qwen1.5-0.5B",
)
