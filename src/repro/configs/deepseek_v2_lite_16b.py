"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 64 routed top-6 + 2 shared.
First layer is dense (d_ff 10944); routed experts are 1408-wide.
[arXiv:2405.04434; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig, MLAConfig, MoEConfig

ARCH = ArchSpec(
    id="deepseek-v2-lite-16b",
    family="moe",
    lm=LMConfig(
        name="deepseek-v2-lite-16b",
        layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102_400, head_dim=128,
        attn="mla", pos="rope", mlp="swiglu",
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                      first_k_dense=1, dense_d_ff=10_944),
    ),
    skips=full_attn_skips(),
    source="arXiv:2405.04434",
    smoke_overrides={
        "moe": MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32,
                         first_k_dense=1, dense_d_ff=64, capacity_factor=4.0),
        "mla": MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16),
    },
)
