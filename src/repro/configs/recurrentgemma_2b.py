"""recurrentgemma-2b — Griffin hybrid: RG-LRU recurrent blocks + local
attention (window 2048), pattern rec,rec,attn (1 attn : 2 recurrent), MQA.
Runs long_500k: recurrent state + window cache are constant-size.
[arXiv:2402.19427; hf-verified]"""
from repro.configs.base import ArchSpec
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="recurrentgemma-2b",
    family="hybrid",
    lm=LMConfig(
        name="recurrentgemma-2b",
        layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab=256_000, head_dim=256,
        block_pattern=("rec", "rec", "swa"), window=2048,
        lru_width=2560, conv1d_width=4,
        pos="rope", mlp="geglu",
    ),
    source="arXiv:2402.19427",
    smoke_overrides={"layers": 4, "lru_width": 64, "window": 16,
                     "n_kv_heads": 1, "head_dim": 16},
)
