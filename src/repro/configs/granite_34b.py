"""granite-34b — 88-layer llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="granite-34b",
    family="dense",
    lm=LMConfig(
        name="granite-34b",
        layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24_576, vocab=49_152, head_dim=128,
        attn="full", pos="rope", mlp="gelu",  # granite-code uses GELU MLP
    ),
    skips=full_attn_skips(),
    source="arXiv:2405.04324",
    smoke_overrides={"n_kv_heads": 1},
)
