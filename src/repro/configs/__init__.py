"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from repro.configs.base import LM_SHAPES, ArchSpec, ShapeCell
from repro.configs.deepseek_v2_lite_16b import ARCH as DEEPSEEK_V2_LITE
from repro.configs.granite_34b import ARCH as GRANITE_34B
from repro.configs.grok1_314b import ARCH as GROK1_314B
from repro.configs.h2o_danube3_4b import ARCH as H2O_DANUBE3_4B
from repro.configs.internvl2_26b import ARCH as INTERNVL2_26B
from repro.configs.minitron_8b import ARCH as MINITRON_8B
from repro.configs.musicgen_large import ARCH as MUSICGEN_LARGE
from repro.configs.qwen15_4b import ARCH as QWEN15_4B
from repro.configs.recurrentgemma_2b import ARCH as RECURRENTGEMMA_2B
from repro.configs.rwkv6_1b6 import ARCH as RWKV6_1B6
from repro.configs.stgnn import DCRNN_PEMS, PGT_DCRNN_PEMS_ALL_LA

LM_ARCHS: dict[str, ArchSpec] = {
    a.id: a
    for a in (
        QWEN15_4B, MINITRON_8B, GRANITE_34B, H2O_DANUBE3_4B, INTERNVL2_26B,
        GROK1_314B, DEEPSEEK_V2_LITE, MUSICGEN_LARGE, RECURRENTGEMMA_2B,
        RWKV6_1B6,
    )
}

STGNN_ARCHS = {a.id: a for a in (DCRNN_PEMS, PGT_DCRNN_PEMS_ALL_LA)}

ARCHS: dict[str, ArchSpec] = {**LM_ARCHS, **STGNN_ARCHS}


def get_arch(arch_id: str) -> ArchSpec:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}") from None


__all__ = ["ARCHS", "LM_ARCHS", "STGNN_ARCHS", "get_arch", "ArchSpec",
           "ShapeCell", "LM_SHAPES"]
