"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.
Per assignment the modality frontend is a stub: ``input_specs`` provides
precomputed patch embeddings [B, n_prefix, d_model] prepended to the text.
[arXiv:2404.16821; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="internvl2-26b",
    family="vlm",
    lm=LMConfig(
        name="internvl2-26b",
        layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16_384, vocab=92_553, head_dim=128,
        attn="full", pos="rope", mlp="swiglu",
        frontend="patches", n_prefix=1024,  # 448px / 14 patch + thumbnails ~ 1024 tokens
        pad_vocab_to_multiple=16,  # 92553 -> 92560 so vocab shards over TP=16
    ),
    skips=full_attn_skips(),
    source="arXiv:2404.16821",
    smoke_overrides={"n_prefix": 8},
)
