"""Config system: architecture specs + input-shape cells.

Every assigned architecture is one ``ArchSpec`` selectable by ``--arch <id>``
in the launchers.  ``shapes`` enumerates the assigned (arch × shape) cells;
``skips`` documents cells the spec directs us to skip (long_500k for pure
full-attention archs), with the reason surfaced in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.models.lm.config import LMConfig

ShapeKind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: ShapeKind
    seq_len: int
    global_batch: int


LM_SHAPES = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

_FULL_ATTN_SKIP = ("long_500k is long-context decode over a 524,288-token KV "
                   "cache; this arch is pure full attention (no sub-quadratic "
                   "path), skipped per assignment spec")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | stgnn
    lm: LMConfig | None
    shapes: tuple[ShapeCell, ...] = LM_SHAPES
    skips: dict[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""
    notes: str = ""
    # reduced same-family config for CPU smoke tests
    smoke_overrides: dict = dataclasses.field(default_factory=dict)

    def cells(self, include_skipped: bool = False):
        for s in self.shapes:
            if s.name in self.skips and not include_skipped:
                continue
            yield s

    def smoke_config(self) -> LMConfig:
        if self.lm is None:
            raise ValueError(f"{self.id} is not an LM arch")
        base = dict(
            layers=2, d_model=64, n_heads=4, n_kv_heads=min(4, self.lm.n_kv_heads),
            d_ff=128, vocab=128, head_dim=16, max_seq_len=128, dtype="float32",
        )
        base.update(self.smoke_overrides)
        return dataclasses.replace(self.lm, **base)


def full_attn_skips() -> dict[str, str]:
    return {"long_500k": _FULL_ATTN_SKIP}
