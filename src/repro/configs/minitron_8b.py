"""minitron-8b — width/depth-pruned nemotron dense decoder, GQA kv=8.
[arXiv:2407.14679; hf-verified]"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.lm.config import LMConfig

ARCH = ArchSpec(
    id="minitron-8b",
    family="dense",
    lm=LMConfig(
        name="minitron-8b",
        layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16_384, vocab=256_000, head_dim=128,
        attn="full", pos="rope", mlp="relu_sq",  # nemotron uses squared ReLU
    ),
    skips=full_attn_skips(),
    source="arXiv:2407.14679",
)
