"""ST-GNN architecture specs — the paper's own models as first-class configs.

These flow through the same launcher/dry-run machinery as the LM archs; their
"shape" cells are the paper's datasets (nodes × window) at the paper's batch
sizes, plus a production-scale training cell on the full PeMS graph.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchSpec, ShapeCell
from repro.models.dcrnn import DCRNNConfig
from repro.models.pgt_dcrnn import PGTDCRNNConfig


@dataclasses.dataclass(frozen=True)
class STGNNSpec(ArchSpec):
    model: object | None = None  # DCRNNConfig / PGTDCRNNConfig
    dataset: str = "pems"


DCRNN_PEMS = STGNNSpec(
    id="dcrnn-pems",
    family="stgnn",
    lm=None,
    model=DCRNNConfig(num_nodes=11_160, in_features=2, out_features=1,
                      hidden=64, layers=2, max_diffusion_step=2,
                      input_len=12, horizon=12),
    dataset="pems",
    shapes=(ShapeCell("train_pems", "train", 12, 1024),),
    source="Li et al. ICLR'18 + paper §3",
    notes="full PeMS graph, no partitioning — the paper's headline workload",
)

PGT_DCRNN_PEMS_ALL_LA = STGNNSpec(
    id="pgt-dcrnn-pems-all-la",
    family="stgnn",
    lm=None,
    model=PGTDCRNNConfig(num_nodes=2_716, in_features=2, out_features=1,
                         hidden=64, max_diffusion_step=2,
                         input_len=12, horizon=12),
    dataset="pems-all-la",
    shapes=(ShapeCell("train_all_la", "train", 12, 1024),),
    source="paper §3 case study",
)
