from repro.optim.adam import AdamConfig, apply_updates, clip_by_global_norm, global_norm, init_opt_state
from repro.optim.schedule import constant, linear_scaled_lr, warmup_cosine

__all__ = [
    "AdamConfig", "init_opt_state", "apply_updates", "global_norm",
    "clip_by_global_norm", "warmup_cosine", "constant", "linear_scaled_lr",
]
