"""LR schedules, including the linear-scaling rule the paper's §5.3.3
follow-up uses to offset large-global-batch MAE degradation (Goyal et al.;
You et al. [67])."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, base_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), base_lr)


def linear_scaled_lr(base_lr: float, global_batch: int, base_batch: int,
                     cap: float = 16.0) -> float:
    """Linear LR scaling for large global batches (capped): the mitigation the
    paper cites for the MAE growth in Fig. 8."""
    return base_lr * min(global_batch / base_batch, cap)
