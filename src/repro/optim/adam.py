"""Adam/AdamW in pure JAX with dtype policies and global-norm clipping.

Optimizer state dtype is configurable (``state_dtype="bfloat16"`` halves the
m/v HBM footprint — required to fit grok-1 Adam state in 256 × 16 GB; see
EXPERIMENTS.md §Dry-run).  State is a pytree congruent with params, so FSDP
sharding rules apply to it verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0
    state_dtype: str = "float32"


def init_opt_state(params: Any, cfg: AdamConfig) -> dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamConfig,
                  lr: jnp.ndarray | float) -> tuple[Any, dict]:
    """One AdamW step.  ``lr`` may be a traced schedule value."""
    grad_norm = None
    if cfg.grad_clip is not None:
        grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1.0 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, grad_norm
