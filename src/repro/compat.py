"""JAX version shims (pinned 0.4.x ↔ 0.5+/0.6+).

The repo pins jax 0.4.37 in the container, but the mesh API it targets grew in
two steps upstream:

- ``jax.sharding.AxisType`` (Auto/Explicit/Manual) only exists on 0.5+;
- ``jax.make_mesh``'s ``axis_types=`` keyword likewise.

Everything that builds a mesh goes through :func:`make_mesh` below, which
forwards ``axis_types`` when the installed JAX understands it and silently
drops it otherwise (0.4.x meshes are implicitly all-Auto, so dropping the
argument preserves semantics).  ``AxisType`` is re-exported from JAX when
available and stubbed with an equivalent enum when not, so call sites can
spell ``AxisType.Auto`` unconditionally.
"""
from __future__ import annotations

import enum
import inspect
from typing import Sequence

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: meshes are implicitly all-Auto

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def _make_mesh_accepts_axis_types() -> bool:
    fn = getattr(jax, "make_mesh", None)
    if fn is None:
        return False
    try:
        return "axis_types" in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return False


_AXIS_TYPES_KW = _make_mesh_accepts_axis_types()


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Sequence[AxisType] | None = None,
    devices=None,
) -> Mesh:
    """``jax.make_mesh`` that works on every supported JAX.

    ``axis_types`` is forwarded when the runtime supports it and dropped
    otherwise; ``devices=None`` defers to JAX's own device selection.
    """
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _AXIS_TYPES_KW:
        kw["axis_types"] = tuple(axis_types)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)
    # very old jax: build the device grid by hand
    import numpy as np

    devs = devices if devices is not None else jax.devices()
    grid = np.asarray(devs).reshape(tuple(axis_shapes))
    return Mesh(grid, tuple(axis_names))
