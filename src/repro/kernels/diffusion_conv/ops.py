"""Public diffusion-conv op: jnp oracle by default, Pallas kernel on request.

On this CPU container the Pallas path runs in interpret mode (Python-level
execution of the kernel body) purely for correctness; on TPU ``interpret``
stays False and the same call sites get the real kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import kernel_defaults
from repro.kernels.diffusion_conv.kernel import hop_project
from repro.kernels.diffusion_conv.ref import diffusion_conv_ref

def _pad_nodes(a: jnp.ndarray, n_pad: int, axes: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, 0)] * a.ndim
    for ax in axes:
        pads[ax] = (0, n_pad - a.shape[ax])
    return jnp.pad(a, pads) if any(p != (0, 0) for p in pads) else a


def diffusion_conv(
    x,
    supports,
    w,
    b,
    *,
    k_hops: int,
    use_pallas: bool = False,
    block_n: int | None = None,
    backend: str | None = None,
    impl: str | None = None,
):
    """x: [B, N, C] -> [B, N, H].  See ref.py for the weight layout.

    Tiling/interpret defaults resolve per call from ``backend`` (None =
    ambient, read now).  ``impl`` overrides ``use_pallas``:
    ``"ref"``/``"pallas"`` force a lowering, ``"auto"`` routes through the
    measured dispatcher (:mod:`repro.kernels.autotune`).
    """
    if impl == "auto":
        from repro.kernels.autotune import dispatch
        return dispatch("diffusion_conv", x, tuple(supports), w, b,
                        k_hops=k_hops, n_supports=len(supports))
    if impl is not None:
        if impl not in ("ref", "pallas"):
            raise ValueError(f"impl {impl!r}; expected ref|pallas|auto")
        use_pallas = impl == "pallas"
    if not use_pallas:
        return diffusion_conv_ref(x, supports, w, b, k_hops=k_hops)

    kd = kernel_defaults(backend)
    if block_n is None:
        block_n = kd.block_n
    bsz, n, c = x.shape
    h = w.shape[1]
    n_pad = int(np.ceil(n / block_n) * block_n)

    z0 = _pad_nodes(jnp.transpose(x, (1, 0, 2)), n_pad, (0,))  # [Np, B, C]
    # Identity-hop projection (plain matmul — XLA handles it optimally).
    y = jnp.einsum("nbc,ch->nbh", z0, w[:c].astype(x.dtype))
    wk = w[c:].reshape(len(supports), k_hops, c, h)

    for si, s in enumerate(supports):
        s_p = _pad_nodes(s, n_pad, (0, 1))
        z = z0
        for k in range(k_hops):
            z, y = hop_project(
                s_p, z, wk[si, k].astype(x.dtype), y,
                block_n=block_n, interpret=kd.interpret,
            )
    return jnp.transpose(y[:n], (1, 0, 2)) + b
