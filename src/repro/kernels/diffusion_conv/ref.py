"""Pure-jnp oracle for diffusion convolution (DCRNN dual random-walk form).

Weight layout (rows of ``w``): [identity | support0 hop1..K | support1 hop1..K]
each block of size C, so ``w: [(1 + n_supports*K) * C, H]``.
"""
from __future__ import annotations

import jax.numpy as jnp


def diffusion_conv_ref(x, supports, w, b, *, k_hops: int):
    """x: [B, N, C], supports: tuple of [N, N], w: [(1+S*K)*C, H], b: [H]."""
    feats = [x]
    for s in supports:
        z = x
        for _ in range(k_hops):
            z = jnp.einsum("mn,bnc->bmc", s, z)
            feats.append(z)
    h = jnp.concatenate(feats, axis=-1)
    return h @ w + b
