"""Pallas TPU kernel: one diffusion hop fused with projection accumulation.

Computes, for one support matrix S and hop weight W_k:

    Z_k = S @ Z_{k-1}            (the [N,N] x [N, B*C] hot matmul)
    Y  += Z_k @ W_k              (per-hop projection, fused)

TPU adaptation of the paper's GPU code path (dense torch.matmul chain):
the node dimension is tiled into MXU-aligned blocks that stream through VMEM;
the j grid axis reduces over node blocks of Z_{k-1} with output-revisiting
accumulation (TPU grids execute sequentially, so the (i, ·) output tile stays
resident in VMEM across the j sweep).  S tile traffic dominates the roofline:
arithmetic intensity is (B*C)/2 FLOP/byte in f32 — see EXPERIMENTS.md §Roofline.

Grid: (N/bn_i, N/bn_j).
  s:     (bn_i, bn_j)   <- S[i, j]
  z_in:  (bn_j, B, C)   <- Z_{k-1}[j]
  w:     (C, H)         (resident)
  y_in:  (bn_i, B, H)   <- Y[i]
  z_out: (bn_i, B, C)   -> Z_k[i]        (accumulator across j)
  y_out: (bn_i, B, H)   -> Y[i] + Z_k[i] @ W_k   (written at last j)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hop_project_kernel(s_ref, z_ref, w_ref, y_ref, z_out_ref, y_out_ref):
    j = pl.program_id(1)
    bn_i = s_ref.shape[0]
    bn_j, b, c = z_ref.shape

    @pl.when(j == 0)
    def _init():
        z_out_ref[...] = jnp.zeros_like(z_out_ref)

    z = z_ref[...].reshape(bn_j, b * c)
    part = jax.lax.dot(
        s_ref[...], z.astype(s_ref.dtype), preferred_element_type=jnp.float32
    )
    acc = z_out_ref[...].reshape(bn_i, b * c) + part
    z_out_ref[...] = acc.reshape(bn_i, b, c).astype(z_out_ref.dtype)

    @pl.when(j == pl.num_programs(1) - 1)
    def _project():
        zz = z_out_ref[...].reshape(bn_i * b, c)
        proj = jax.lax.dot(
            zz.astype(w_ref.dtype), w_ref[...], preferred_element_type=jnp.float32
        )
        y_out_ref[...] = y_ref[...] + proj.reshape(bn_i, b, -1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def hop_project(s, z, w, y, *, block_n: int = 128, interpret: bool = False):
    """One fused hop.  s: [N, N], z: [N, B, C], w: [C, H], y: [N, B, H].

    N must be a multiple of ``block_n`` (ops.py pads).  Returns (z_next, y_next).
    """
    n, b, c = z.shape
    h = w.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n, n // block_n)
    return pl.pallas_call(
        _hop_project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_n), lambda i, j: (i, j)),  # S
            pl.BlockSpec((block_n, b, c), lambda i, j: (j, 0, 0)),  # Z_{k-1}
            pl.BlockSpec((c, h), lambda i, j: (0, 0)),  # W_k
            pl.BlockSpec((block_n, b, h), lambda i, j: (i, 0, 0)),  # Y in
        ],
        out_specs=[
            pl.BlockSpec((block_n, b, c), lambda i, j: (i, 0, 0)),  # Z_k
            pl.BlockSpec((block_n, b, h), lambda i, j: (i, 0, 0)),  # Y out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, b, c), z.dtype),
            jax.ShapeDtypeStruct((n, b, h), y.dtype),
        ],
        interpret=interpret,
    )(s, z, w, y)
