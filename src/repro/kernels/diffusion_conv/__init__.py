from repro.kernels.diffusion_conv.ops import diffusion_conv
from repro.kernels.diffusion_conv.ref import diffusion_conv_ref

__all__ = ["diffusion_conv", "diffusion_conv_ref"]
