"""Pallas TPU kernels for the workload's compute hot-spots.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (the
jit'd public wrapper with padding/dispatch) and ref.py (pure-jnp oracle the
tests sweep against).  On this CPU container kernels run in interpret mode;
on TPU the same call sites get the compiled kernel.
"""
from repro.kernels.autotune import (autotune_policy, autotuning, dispatch,
                                    reset_autotune, set_autotune, verdict_for)
from repro.kernels.diffusion_conv.ops import diffusion_conv
from repro.kernels.diffusion_conv.ref import diffusion_conv_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.linear_scan.ops import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref
from repro.kernels.window_gather.ops import gather_xy, window_gather
from repro.kernels.window_gather.ref import window_gather_ref

__all__ = [
    "diffusion_conv", "diffusion_conv_ref",
    "flash_attention", "flash_attention_ref",
    "linear_scan", "linear_scan_ref",
    "window_gather", "window_gather_ref", "gather_xy",
    "autotune_policy", "autotuning", "dispatch", "reset_autotune",
    "set_autotune", "verdict_for",
]
