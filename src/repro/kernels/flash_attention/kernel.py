"""Pallas TPU kernel: causal flash attention (forward).

The §Perf "next lever" for every memory-bound LM train/prefill cell: the
pure-JAX chunked attention writes each [bq, bk] score block to HBM at fusion
boundaries (measured: ~70 % of qwen train_4k's optimized memory term); this
kernel keeps scores, the online-softmax stats and the output accumulator in
VMEM scratch — HBM traffic collapses to q/k/v reads + one output write.

Grid: (B, H, Sq/bq, Skv/bk), kv innermost.  TPU grids run sequentially, so
the (m, l, acc) scratch persists across the kv sweep of one (b, h, qi) tile.
GQA folds into the BlockSpec index_map: query head h reads kv head h // g —
no [G×] materialisation of k/v.  Scores are f32 on the MXU
(preferred_element_type) regardless of the input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, bq, bk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # [bq, d]
    k = k_ref[0, 0]  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    if causal:  # fully-masked rows: exp(NEG_INF - NEG_INF) -> keep at 0
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0, 0], preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, H, Sq, D].

    Sq % block_q == 0 and Skv % block_k == 0 (ops.py pads).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert h % hkv == 0 and sq % block_q == 0 and skv % block_k == 0
    g = h // hkv
    grid = (b, h, sq // block_q, skv // block_k)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=block_q, bk=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
