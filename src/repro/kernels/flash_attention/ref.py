"""Pure-jnp oracle for causal flash attention (layout [B, H, S, D])."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Skv, D] (H % Hkv == 0) -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(k.shape[2])[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)
