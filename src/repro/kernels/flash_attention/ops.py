"""Public flash-attention op: [B, S, H, D] layout adapter + padding + oracle."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import kernel_defaults
from repro.kernels.flash_attention.kernel import flash_attention as _flash_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref

def flash_attention(q, k, v, *, causal: bool = True, use_pallas: bool = False,
                    block_q: int | None = None, block_k: int | None = None,
                    backend: str | None = None, impl: str | None = None):
    """q: [B, S, H, D]; k/v: [B, S, Hkv, D] -> [B, S, H, D] (model layout).

    Tiling/interpret defaults resolve per call from ``backend`` (None =
    ambient, read now).  ``impl`` overrides ``use_pallas``:
    ``"ref"``/``"pallas"`` force a lowering, ``"auto"`` routes through the
    measured dispatcher (:mod:`repro.kernels.autotune`).
    """
    if impl == "auto":
        from repro.kernels.autotune import dispatch
        return dispatch("flash_attention", q, k, v, causal=causal)
    if impl is not None:
        if impl not in ("ref", "pallas"):
            raise ValueError(f"impl {impl!r}; expected ref|pallas|auto")
        use_pallas = impl == "pallas"
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if not use_pallas:
        out = flash_attention_ref(qt, kt, vt, causal=causal)
        return jnp.swapaxes(out, 1, 2)
    kd = kernel_defaults(backend)
    s = qt.shape[2]
    bq = min(block_q if block_q is not None else kd.block_q, s)
    bk = min(block_k if block_k is not None else kd.block_k, s)
    pad_q = (-s) % bq
    pad_k = (-s) % bk
    if pad_q or pad_k:
        # pad kv with zeros (masked by causality for the real rows) and q with
        # zeros (padded outputs sliced off)
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = _flash_kernel(qt, kt, vt, causal=causal, block_q=bq, block_k=bk,
                        interpret=kd.interpret)
    return jnp.swapaxes(out[:, :, :s], 1, 2)
