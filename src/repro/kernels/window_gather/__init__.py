from repro.kernels.window_gather.ops import gather_xy, window_gather
from repro.kernels.window_gather.ref import window_gather_ref

__all__ = ["window_gather", "gather_xy", "window_gather_ref"]
