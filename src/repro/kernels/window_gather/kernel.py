"""Pallas TPU kernel: scalar-prefetch-driven window gather (index-batching).

The TPU-native equivalent of the paper's NumPy views: the int32 start-index
array is *scalar-prefetched* into SMEM before the grid runs, and each grid
step's BlockSpec index_map reads ``starts[b]`` to aim the HBM→VMEM DMA at the
right time-rows of the resident series.  No materialised snapshot array ever
exists in HBM — the paper's eq.-2 memory model holds on device.

Grid: (B, span, C/bc)
  series block (1, bc)  <- series[starts[b] + t, c-block]   (DMA, no compute)
  out    block (1,1,bc) -> out[b, t, c-block]

The kernel body is a pure VMEM copy; the win is that the index indirection is
resolved by the scalar-prefetch unit concurrently with the previous block's
DMA, so gathers pipeline at full HBM bandwidth instead of issuing B separate
host-driven slices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(starts_ref, series_ref, out_ref):
    # starts_ref lives in SMEM (scalar prefetch); blocks are pre-aimed by the
    # index_map below, so the body is a straight VMEM copy.
    del starts_ref
    out_ref[0] = series_ref[...]


@functools.partial(jax.jit, static_argnames=("span", "block_c", "interpret"))
def window_gather(
    series: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    span: int,
    block_c: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """series: [T, C], starts: [B] int32 -> [B, span, C].

    C must be a multiple of ``block_c`` (ops.py pads).  ``span`` is
    input_len + horizon — x/y are sliced from the result by the caller.
    """
    t, c = series.shape
    b = starts.shape[0]
    bc = block_c or c
    assert c % bc == 0, (c, bc)

    grid = (b, span, c // bc)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # time index comes from the prefetched starts array
                pl.BlockSpec((1, bc), lambda i, j, k, starts: (starts[i] + j, k)),
            ],
            out_specs=pl.BlockSpec((1, 1, bc), lambda i, j, k, starts: (i, j, k)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, span, c), series.dtype),
        interpret=interpret,
    )(starts, series)
