"""Public window-gather op: jnp oracle by default, Pallas kernel on request.

Handles arbitrary trailing shape by flattening to [T, C], padding C to the
block size, and restoring the shape afterwards.  The batching layer
(`repro.core.batching`) routes through here when ``use_pallas=True``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import kernel_defaults
from repro.kernels.window_gather.kernel import window_gather as _window_gather_kernel
from repro.kernels.window_gather.ref import window_gather_ref


def window_gather(
    series: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    span: int,
    use_pallas: bool = False,
    block_c: int | None = None,
    backend: str | None = None,
    impl: str | None = None,
) -> jnp.ndarray:
    """series: [T, ...], starts: [B] -> [B, span, ...].

    Tiling/interpret defaults resolve per call from ``backend`` (None = the
    ambient ``jax.default_backend()``, read now — never cached).  ``impl``
    overrides ``use_pallas``: ``"ref"`` / ``"pallas"`` force a lowering,
    ``"auto"`` routes through the measured shape-bucketed dispatcher
    (:mod:`repro.kernels.autotune`), which picks the fastest VERIFIED
    variant for this (backend, shape-bucket).
    """
    if impl == "auto":
        from repro.kernels.autotune import dispatch
        return dispatch("window_gather", series, starts, span=span)
    if impl is not None:
        if impl not in ("ref", "pallas"):
            raise ValueError(f"impl {impl!r}; expected ref|pallas|auto")
        use_pallas = impl == "pallas"
    if not use_pallas:
        return window_gather_ref(series, starts, span=span)

    kd = kernel_defaults(backend)
    t = series.shape[0]
    trailing = series.shape[1:]
    c = int(np.prod(trailing)) if trailing else 1
    flat = series.reshape(t, c)
    if block_c is None:
        block_c = (c if c % kd.lane == 0 and c <= kd.block_c_max
                   else min(c, kd.block_c_cap))
    pad = (-c) % block_c
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = _window_gather_kernel(flat, starts.astype(jnp.int32), span=span,
                                block_c=block_c, interpret=kd.interpret)
    out = out[..., :c]
    return out.reshape((starts.shape[0], span) + trailing)


def gather_xy(
    series: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    input_len: int,
    horizon: int,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused gather of the full span, split into (x, y) views."""
    w = window_gather(series, starts, span=input_len + horizon, use_pallas=use_pallas)
    return w[:, :input_len], w[:, input_len:]
