"""Pure-jnp oracle for the index-batching window gather.

Given the resident series ``[T, C]`` (C = flattened nodes×features, or 1 for a
token stream) and per-sample window starts ``[B]``, produce the stacked
windows ``[B, span, C]`` — exactly what the paper's NumPy-view batching hands
to the model, but on-device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def window_gather_ref(series: jnp.ndarray, starts: jnp.ndarray, *, span: int) -> jnp.ndarray:
    """series: [T, C], starts: [B] int32 -> [B, span, C]."""

    def one(s):
        return jax.lax.dynamic_slice(series, (s,) + (0,) * (series.ndim - 1),
                                     (span,) + series.shape[1:])

    return jax.vmap(one)(starts)
