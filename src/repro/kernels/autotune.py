"""Measured kernel autotuning: a variant registry + shape-bucketed dispatcher.

The static :class:`~repro.kernels.common.KernelDefaults` table guesses one
tiling per backend and never measures anything — and the smoke bench showed
where that leads: ``gather="pallas"`` (interpret mode on CPU) was ~2x SLOWER
than the dense lowering it was supposed to beat.  This module replaces the
guess with a measurement:

- every op declares its candidate lowerings (**variants**): the pure-jnp
  reference, the fused XLA alternatives (``gather_batch_take`` /
  ``gather_batch_fused``), and the Pallas kernel — compiled where the backend
  has a Mosaic/Triton lowering, interpret mode otherwise — each with a small
  block-size search space derived from ``KernelDefaults``
  (:func:`~repro.kernels.common.block_candidates`);
- the **tuner** times every candidate under jit (``block_until_ready``,
  warmup + median-of-N — the same contract as ``benchmarks/common.timed``,
  which is reused when importable) on synthetic inputs at the call's
  **shape bucket** (powers-of-two envelopes of every dimension), and only
  admits candidates whose VALUES match the reference (bit-exact for pure
  data-movement ops, allclose for float kernels);
- verdicts are keyed ``(op, backend, shape-bucket, dtype)`` and persisted to
  ``results/TUNING_<backend>.json`` — written atomically (tempfile +
  ``os.replace``) so concurrent tuners can interleave but a reader can never
  observe a torn file, and loaded defensively: a missing, corrupt, or
  foreign-backend cache yields ``{}`` (retune or static default), never an
  exception.

Dispatch discipline (same rules ``kernels/common.py`` documents): the jax
backend is resolved PER CALL — never at import, never cached at first use —
because the prefetcher's host threads race device init.  What IS memoized is
keyed BY backend (tuning verdicts, built callables), so nothing a racing
thread primes can pin the wrong backend for everyone.

Modes (``set_autotune(mode=...)`` / ``--autotune`` on the launcher):

- ``"off"``  — static heuristic defaults only (reference lowering on
  interpret-mode backends, Pallas at ``KernelDefaults`` tiles elsewhere);
  no file IO.
- ``"load"`` — use a persisted verdict when one covers the bucket, else the
  static default; never measures.  The default mode: committed caches make
  ``backend="auto"`` dispatch measured without paying tuning time.
- ``"tune"`` — like ``load`` but a cache miss triggers measurement and the
  verdict is persisted.  Delete the cache file to force a full retune.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.kernels.common import (KernelDefaults, block_candidates,
                                  kernel_defaults, resolve_backend)

# --------------------------------------------------------------------- policy


@dataclasses.dataclass(frozen=True)
class AutotunePolicy:
    """Process-wide dispatch policy (see module docstring for the modes)."""

    mode: str = "load"          # off | load | tune
    cache_dir: str = "results"  # TUNING_<backend>.json lives here
    warmup: int = 1             # per-candidate warmup calls (absorbs jit)
    iters: int = 5              # timed calls per candidate; median wins


MODES = ("off", "load", "tune")

_LOCK = threading.RLock()
_policy = AutotunePolicy()
#: (bucket key, mode, cache_dir) -> Verdict — resolved dispatch decisions.
_MEMO: dict[tuple, "Verdict"] = {}
#: cache path -> entries dict loaded from disk (refreshed on policy change).
_FILE_MEMO: dict[str, dict] = {}
#: (op, variant, static, params) -> built callable.  Built callables wrap
#: ``jax.jit`` closures; memoizing them keeps the function identity stable so
#: jit's own cache works across dispatches.
_FN_MEMO: dict[tuple, Callable] = {}


def autotune_policy() -> AutotunePolicy:
    return _policy


def set_autotune(mode: str | None = None, cache_dir: str | None = None,
                 warmup: int | None = None,
                 iters: int | None = None) -> AutotunePolicy:
    """Update the process-wide policy; clears resolved-verdict memos."""
    global _policy
    if mode is not None and mode not in MODES:
        raise ValueError(f"autotune mode {mode!r}; expected one of {MODES}")
    kw = {k: v for k, v in dict(mode=mode, cache_dir=cache_dir, warmup=warmup,
                                iters=iters).items() if v is not None}
    with _LOCK:
        _policy = dataclasses.replace(_policy, **kw)
        _MEMO.clear()
        _FILE_MEMO.clear()
    return _policy


def reset_autotune() -> None:
    """Restore the default policy and drop every memo (tests)."""
    global _policy
    with _LOCK:
        _policy = AutotunePolicy()
        _MEMO.clear()
        _FILE_MEMO.clear()
        _FN_MEMO.clear()


@contextlib.contextmanager
def autotuning(**kw):
    """Scoped policy override: ``with autotuning(mode="tune", cache_dir=d):``"""
    global _policy
    with _LOCK:
        prev = _policy
    try:
        yield set_autotune(**kw)
    finally:
        with _LOCK:
            _policy = prev
            _MEMO.clear()
            _FILE_MEMO.clear()


# ------------------------------------------------------------ shape bucketing


def pow2_bucket(n: int) -> int:
    """The power-of-two envelope of ``n`` (1 for n <= 1)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def bucket_key(op: str, backend: str, dims: dict, dtype) -> str:
    """Cache key: every dim rounded up to its power-of-two envelope, so one
    measured verdict covers the whole envelope instead of one exact shape."""
    parts = ",".join(f"{k}={pow2_bucket(v)}" for k, v in dims.items())
    return f"{op}|{backend}|{parts}|{np.dtype(dtype).name}"


# ------------------------------------------------------------- tuning cache


def cache_path(backend: str, cache_dir: str | None = None) -> str:
    d = cache_dir if cache_dir is not None else _policy.cache_dir
    return os.path.join(d, f"TUNING_{backend}.json")


def load_cache(path: str, backend: str) -> dict:
    """The persisted entries, or ``{}`` — NEVER an exception.

    Missing file, torn/corrupt JSON (a crashed writer, a truncated copy), a
    non-object payload, or a cache tuned for a DIFFERENT backend all fall
    back to empty: the dispatcher then retunes (mode=tune) or uses the
    static defaults, which is always safe.
    """
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("backend") != backend:
        return {}
    entries = data.get("entries")
    return dict(entries) if isinstance(entries, dict) else {}


def save_cache(path: str, backend: str, entries: dict) -> None:
    """Merge ``entries`` into the persisted cache, atomically.

    Read-merge-replace: concurrent tuners (two processes tuning different
    buckets at once) interleave per-key last-writer-wins, but ``os.replace``
    of a same-directory tempfile guarantees no reader — nor a crash mid-write
    — can ever observe a torn file.
    """
    merged = load_cache(path, backend)
    merged.update(entries)
    payload = {"schema": 1, "backend": backend, "jax": jax.__version__,
               "entries": merged}
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".tuning-", dir=d)
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


# ----------------------------------------------------------------- registry


@dataclasses.dataclass(frozen=True)
class Variant:
    """One candidate lowering of an op.

    ``build(static, params) -> fn(*arrays)`` returns the jit-wrapped callable
    (memoized by the dispatcher, so jit caches hold across calls).
    ``grid(bucket_dims, kd) -> (params, ...)`` is the block-size search space,
    derived from :class:`KernelDefaults` and filtered to the bucket (a scan
    chunk longer than the sequence is the same candidate twice).
    ``exact`` selects the admission check the tuner runs against the
    reference variant: bit-equality for pure data movement, allclose for
    float kernels whose accumulation order differs.
    """

    name: str
    build: Callable[[dict, dict], Callable]
    grid: Callable[[dict, KernelDefaults], tuple] = lambda dims, kd: ({},)
    exact: bool = True
    atol: float = 1e-3
    rtol: float = 1e-3


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One tunable op: how to key it, synthesize it, and lower it.

    ``describe(args, static) -> (dims, dtype)`` extracts the bucketable
    dimensions (shapes only — safe on tracers).
    ``variants()`` returns the candidates, reference FIRST (it is the
    correctness oracle and the unconditional fallback); lowerings are
    imported lazily inside it so registering ops never imports jax kernels
    at module-import time.
    ``synth(bucket_dims, static, dtype)`` builds concrete inputs at the
    bucket envelope for timing (dispatch may fire at trace time, where the
    live args are tracers and cannot be timed).
    ``default(backend, dims) -> (variant, params)`` is the unmeasured
    heuristic: the reference on interpret-mode backends, Pallas at the
    ``KernelDefaults`` tiles elsewhere.
    """

    name: str
    describe: Callable[[tuple, dict], tuple[dict, Any]]
    variants: Callable[[], tuple[Variant, ...]]
    synth: Callable[[dict, dict, Any], tuple]
    default: Callable[[str, dict], tuple[str, dict]]


@dataclasses.dataclass(frozen=True)
class Verdict:
    """A resolved dispatch decision and where it came from."""

    variant: str
    params: dict
    us: float | None = None
    source: str = "default"  # default | cache | tuned


_OPS: dict[str, OpSpec] = {}


def register_op(spec: OpSpec) -> OpSpec:
    _OPS[spec.name] = spec
    return spec


def registered_ops() -> tuple[str, ...]:
    return tuple(_OPS)


# ------------------------------------------------------------------- tuning


def _timed(fn: Callable[[], Any], *, warmup: int, iters: int) -> float:
    """Median wall seconds (same contract as ``benchmarks/common.timed``,
    reused when the benchmarks package is importable)."""
    try:
        from benchmarks.common import timed
    except ImportError:
        for _ in range(max(warmup, 0)):
            jax.block_until_ready(fn())
        times = []
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]
    return timed(fn, warmup=max(warmup, 0), iters=max(iters, 1))


def _values_match(ref, out, variant: Variant) -> bool:
    rl, ol = jax.tree.leaves(ref), jax.tree.leaves(out)
    if len(rl) != len(ol):
        return False
    for r, o in zip(rl, ol):
        r, o = np.asarray(r), np.asarray(o)
        if r.shape != o.shape or r.dtype != o.dtype:
            return False
        if variant.exact:
            if not np.array_equal(r, o):
                return False
        elif not np.allclose(r, o, atol=variant.atol, rtol=variant.rtol):
            return False
    return True


def _label(name: str, params: dict) -> str:
    if not params:
        return name
    inner = ",".join(f"{k}={v}" for k, v in sorted(params.items()))
    return f"{name}[{inner}]"


def _tune(spec: OpSpec, backend: str, dims: dict, static: dict, dtype,
          policy: AutotunePolicy) -> dict:
    """Measure every candidate at the bucket envelope; returns a cache entry.

    Inputs are SYNTHESIZED at the bucket (not the live args): the verdict
    represents the whole envelope, and dispatch may fire under a jit trace
    where the live args are tracers.  JAX trace state is thread-local, so
    the measurement body runs in a fresh worker thread: candidates execute
    EAGERLY on concrete arrays with real wall times, never staged into the
    surrounding trace.  (``ensure_compile_time_eval`` is not enough — it
    inlines inner jits, and ``lax.scan`` has no eager eval rule.)
    """
    box: list = []

    def _run():
        try:
            box.append((None, _tune_body(spec, backend, dims, static, dtype,
                                         policy)))
        except BaseException as e:  # noqa: BLE001 — re-raised in the caller
            box.append((e, None))

    t = threading.Thread(target=_run, name=f"autotune-{spec.name}",
                         daemon=True)
    t.start()
    t.join()
    err, entry = box[0]
    if err is not None:
        raise err
    return entry


def _tune_body(spec: OpSpec, backend: str, dims: dict, static: dict, dtype,
               policy: AutotunePolicy) -> dict:
    """The measurement loop proper; must run outside any ambient trace.

    Candidates that fail to lower or whose values diverge from the reference
    are recorded as rejected, never selected — a tuner can pick a slow
    candidate, never a wrong one.
    """
    kd = kernel_defaults(backend)
    bdims = {k: pow2_bucket(v) for k, v in dims.items()}
    candidates: dict[str, dict] = {}
    best: tuple[str, dict, float] | None = None
    sargs = spec.synth(bdims, static, dtype)
    variants = spec.variants()
    ref_out = _built(spec, variants[0], static, {})(*sargs)

    for v in variants:
        for params in v.grid(bdims, kd):
            label = _label(v.name, params)
            try:
                fn = _built(spec, v, static, params)
                out = fn(*sargs)
                if not _values_match(ref_out, out, v):
                    candidates[label] = {
                        "us": None, "rejected": "values diverge from ref"}
                    continue
                t = _timed(lambda: fn(*sargs), warmup=policy.warmup,
                           iters=policy.iters)
            except Exception as e:  # noqa: BLE001 — a candidate that
                # cannot lower on this backend is disqualified, not fatal
                candidates[label] = {
                    "us": None,
                    "rejected": f"{type(e).__name__}: {e}"[:200]}
                continue
            us = 1e6 * t
            candidates[label] = {"us": round(us, 2)}
            if best is None or us < best[2]:
                best = (v.name, dict(params), us)
    if best is None:  # cannot happen: the reference always lowers
        raise RuntimeError(f"no candidate survived tuning for {spec.name}")
    return {"variant": best[0], "params": best[1], "us": round(best[2], 2),
            "dims": dict(dims), "bucket": bdims,
            "candidates": candidates,
            "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}


# ----------------------------------------------------------------- dispatch


def _freeze(d: dict) -> tuple:
    return tuple(sorted(d.items()))


def _built(spec: OpSpec, variant: Variant, static: dict,
           params: dict) -> Callable:
    key = (spec.name, variant.name, _freeze(static), _freeze(params))
    with _LOCK:
        fn = _FN_MEMO.get(key)
    if fn is None:
        # jit every candidate: timing then measures the compiled lowering,
        # and under ``ensure_compile_time_eval`` a jitted call compiles and
        # runs where a bare one would need eager eval rules (lax.scan's
        # ``empty`` primitive has none).  Memoized so the jit cache is
        # stable across dispatches.
        fn = jax.jit(variant.build(static, params))
        with _LOCK:
            _FN_MEMO[key] = fn
    return fn


def _resolve(spec: OpSpec, backend: str, key: str, dims: dict, static: dict,
             dtype) -> Verdict:
    policy = _policy
    if policy.mode == "off":
        name, params = spec.default(backend, dims)
        return Verdict(name, params, source="default")
    memo_key = (key, policy.mode, policy.cache_dir)
    with _LOCK:
        hit = _MEMO.get(memo_key)
    if hit is not None:
        return hit
    path = cache_path(backend, policy.cache_dir)
    with _LOCK:
        entries = _FILE_MEMO.get(path)
        if entries is None:
            entries = load_cache(path, backend)
            _FILE_MEMO[path] = entries
    entry = entries.get(key)
    if isinstance(entry, dict) and isinstance(entry.get("variant"), str):
        v = Verdict(entry["variant"], dict(entry.get("params") or {}),
                    entry.get("us"), "cache")
    elif policy.mode == "tune":
        entry = _tune(spec, backend, dims, static, dtype, policy)
        with _LOCK:
            entries[key] = entry
            save_cache(path, backend, {key: entry})
        v = Verdict(entry["variant"], dict(entry["params"]), entry["us"],
                    "tuned")
    else:
        name, params = spec.default(backend, dims)
        v = Verdict(name, params, source="default")
    with _LOCK:
        _MEMO[memo_key] = v
    return v


def verdict_for(op: str, *args, **static) -> Verdict:
    """The dispatch decision for this call, without executing it (benches)."""
    spec = _OPS[op]
    backend = resolve_backend(None)  # per call, never cached
    dims, dtype = spec.describe(args, static)
    return _resolve(spec, backend, bucket_key(op, backend, dims, dtype),
                    dims, static, dtype)


def dispatch(op: str, *args, **static):
    """Run ``op`` through its measured (or default) fastest lowering.

    Resolution happens per call: backend read NOW, bucket computed from the
    call shapes, verdict looked up (memoized per bucket — keyed by backend,
    so nothing a racing thread primes can pin a foreign backend's verdict).
    A stale cache entry naming a variant that no longer exists, or whose
    params no longer lower, falls back to the static default instead of
    crashing the train step.
    """
    spec = _OPS[op]
    backend = resolve_backend(None)
    dims, dtype = spec.describe(args, static)
    key = bucket_key(op, backend, dims, dtype)
    verdict = _resolve(spec, backend, key, dims, static, dtype)
    by_name = {v.name: v for v in spec.variants()}
    var = by_name.get(verdict.variant)
    if var is None:  # cache from an older registry revision
        name, params = spec.default(backend, dims)
        var, verdict = by_name[name], Verdict(name, params, source="default")
    try:
        return _built(spec, var, static, verdict.params)(*args)
    except Exception:
        name, params = spec.default(backend, dims)
        if name == verdict.variant and params == verdict.params:
            raise  # the default itself failed: a real error, surface it
        return _built(spec, by_name[name], static, params)(*args)


# ------------------------------------------------------------- op specs
# Lowerings are imported lazily inside variants()/build closures: this module
# must stay importable before jax.distributed.initialize() runs, and the ops
# modules import US for impl="auto" — laziness breaks the cycle.


def _rng() -> np.random.Generator:
    return np.random.default_rng(0)


def _synth_series(t: int, c: int, dtype) -> np.ndarray:
    if np.issubdtype(np.dtype(dtype), np.integer):
        return _rng().integers(0, 100, size=(t, c)).astype(dtype)
    return _rng().standard_normal((t, c)).astype(dtype)


def _ref_default(backend: str, dims: dict) -> tuple[str, dict]:
    del dims
    return ("ref", {})


def _bc_grid(dims: dict, kd: KernelDefaults) -> tuple:
    """block_c candidates for the Pallas gather: the ops-level heuristic
    (None) plus lane-multiples that do not dwarf the bucket's row width."""
    out: list[dict] = [{"block_c": None}]
    for b in block_candidates(kd.lane, lo=kd.lane):
        if b <= 2 * dims.get("c", b):
            out.append({"block_c": b})
    return tuple(out)


# window_gather: series [T, ...], starts [B] -> [B, span, ...]


def _wg_describe(args, static):
    series, starts = args
    trailing = series.shape[1:]
    c = int(np.prod(trailing)) if trailing else 1
    return ({"t": series.shape[0], "c": c, "b": starts.shape[0],
             "span": static["span"]}, series.dtype)


def _wg_synth(bdims, static, dtype):
    import jax.numpy as jnp
    span = static["span"]
    t = max(bdims["t"], span)
    series = _synth_series(t, bdims["c"], dtype)
    starts = _rng().integers(0, max(t - span + 1, 1),
                             bdims["b"]).astype(np.int32)
    return jnp.asarray(series), jnp.asarray(starts)


def _wg_variants() -> tuple[Variant, ...]:
    def ref(static, params):
        from repro.kernels.window_gather.ref import window_gather_ref
        span = static["span"]
        return jax.jit(lambda s, st: window_gather_ref(s, st, span=span))

    def take(static, params):
        import jax.numpy as jnp
        span = static["span"]

        def fn(series, starts):
            offs = jnp.arange(span, dtype=starts.dtype)
            return jnp.take(series, starts[:, None] + offs[None, :], axis=0)

        return jax.jit(fn)

    def pallas(static, params):
        from repro.kernels.window_gather.ops import window_gather
        span, bc = static["span"], params.get("block_c")
        return jax.jit(lambda s, st: window_gather(s, st, span=span,
                                                   use_pallas=True,
                                                   block_c=bc))

    return (Variant("ref", ref),
            Variant("take", take),
            Variant("pallas", pallas, grid=_bc_grid))


def _pallas_or_ref(params_for_pallas: Callable[[KernelDefaults], dict]):
    """Static default: reference on interpret-mode backends (running the
    kernel body in Python is never the fast path), Pallas at the
    KernelDefaults tiles on backends with a real lowering."""

    def default(backend: str, dims: dict) -> tuple[str, dict]:
        kd = kernel_defaults(backend)
        if kd.interpret:
            return ("ref", {})
        return ("pallas", params_for_pallas(kd))

    return default


register_op(OpSpec(
    name="window_gather",
    describe=_wg_describe,
    variants=_wg_variants,
    synth=_wg_synth,
    default=_pallas_or_ref(lambda kd: {"block_c": None}),
))


# gather: the pipeline-level (x, y) window gather —
# gather(series, starts, input_len=, horizon=) -> (x, y)


def _xy_describe(args, static):
    series, starts = args
    trailing = series.shape[1:]
    c = int(np.prod(trailing)) if trailing else 1
    return ({"t": series.shape[0], "c": c, "b": starts.shape[0],
             "span": static["input_len"] + static["horizon"]}, series.dtype)


def _xy_synth(bdims, static, dtype):
    import jax.numpy as jnp
    span = static["input_len"] + static["horizon"]
    t = max(bdims["t"], span)
    series = _synth_series(t, bdims["c"], dtype)
    starts = _rng().integers(0, max(t - span + 1, 1),
                             bdims["b"]).astype(np.int32)
    return jnp.asarray(series), jnp.asarray(starts)


def _xy_variants() -> tuple[Variant, ...]:
    def _wrap(gather_fn, static):
        il, hz = static["input_len"], static["horizon"]
        return jax.jit(lambda s, st: gather_fn(s, st, input_len=il,
                                               horizon=hz))

    def slice_(static, params):
        from repro.core.batching import gather_batch
        return _wrap(gather_batch, static)

    def take(static, params):
        from repro.core.batching import gather_batch_take
        return _wrap(gather_batch_take, static)

    def fused(static, params):
        from repro.core.batching import gather_batch_fused
        return _wrap(gather_batch_fused, static)

    def pallas(static, params):
        from repro.kernels.window_gather.ops import window_gather
        il, hz, bc = static["input_len"], static["horizon"], \
            params.get("block_c")

        def fn(series, starts):
            w = window_gather(series, starts, span=il + hz, use_pallas=True,
                              block_c=bc)
            return w[:, :il], w[:, il:]

        return jax.jit(fn)

    return (Variant("slice", slice_),
            Variant("take", take),
            Variant("fused", fused),
            Variant("pallas", pallas, grid=_bc_grid))


def _xy_default(backend: str, dims: dict) -> tuple[str, dict]:
    kd = kernel_defaults(backend)
    if kd.interpret:
        return ("slice", {})  # the dense lowering the CPU bench crowns
    return ("pallas", {"block_c": None})


register_op(OpSpec(
    name="gather",
    describe=_xy_describe,
    variants=_xy_variants,
    synth=_xy_synth,
    default=_xy_default,
))


# linear_scan: h_t = a_t * h_{t-1} + b_t over [B, S, D]


def _ls_describe(args, static):
    a, b, h0 = args
    del b, h0, static
    return ({"b": a.shape[0], "s": a.shape[1], "d": a.shape[2]}, a.dtype)


def _ls_synth(bdims, static, dtype):
    import jax.numpy as jnp
    del static
    b, s, d = bdims["b"], bdims["s"], bdims["d"]
    rng = _rng()
    a = rng.uniform(0.7, 1.0, (b, s, d)).astype(dtype)
    bb = rng.standard_normal((b, s, d)).astype(dtype)
    h0 = np.zeros((b, d), dtype)
    return jnp.asarray(a), jnp.asarray(bb), jnp.asarray(h0)


def _ls_grid(dims: dict, kd: KernelDefaults) -> tuple:
    # chunks longer than the sequence all clamp to the same kernel — dedupe
    chunks = dict.fromkeys(min(c, dims["s"])
                           for c in block_candidates(kd.scan_chunk))
    return tuple({"chunk": c} for c in chunks)


def _ls_variants() -> tuple[Variant, ...]:
    def ref(static, params):
        from repro.kernels.linear_scan.ref import linear_scan_ref
        return jax.jit(linear_scan_ref)

    def pallas(static, params):
        from repro.kernels.linear_scan.ops import linear_scan
        chunk = params.get("chunk")
        return jax.jit(lambda a, b, h0: linear_scan(a, b, h0,
                                                    use_pallas=True,
                                                    chunk=chunk))

    return (Variant("ref", ref),
            Variant("pallas", pallas, grid=_ls_grid, exact=False))


register_op(OpSpec(
    name="linear_scan",
    describe=_ls_describe,
    variants=_ls_variants,
    synth=_ls_synth,
    default=_pallas_or_ref(lambda kd: {"chunk": kd.scan_chunk}),
))


# flash_attention: q [B, S, H, D], k/v [B, S, Hkv, D] (model layout)


def _fa_describe(args, static):
    q, k, v = args
    del v, static
    return ({"b": q.shape[0], "s": q.shape[1], "h": q.shape[2],
             "hkv": k.shape[2], "d": q.shape[3]}, q.dtype)


def _fa_synth(bdims, static, dtype):
    import jax.numpy as jnp
    del static
    rng = _rng()
    b, s, h, hkv, d = (bdims["b"], bdims["s"], bdims["h"], bdims["hkv"],
                       bdims["d"])
    h = max(h, hkv) // hkv * hkv  # grouped-query: H must divide by Hkv
    q = rng.standard_normal((b, s, h, d)).astype(dtype)
    k = rng.standard_normal((b, s, hkv, d)).astype(dtype)
    v = rng.standard_normal((b, s, hkv, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def _fa_grid(dims: dict, kd: KernelDefaults) -> tuple:
    qs = dict.fromkeys(min(b, dims["s"]) for b in block_candidates(kd.block_q))
    return tuple({"block_q": b, "block_k": b} for b in qs)


def _fa_variants() -> tuple[Variant, ...]:
    def ref(static, params):
        from repro.kernels.flash_attention.ops import flash_attention
        causal = static["causal"]
        return jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=causal,
                                                       use_pallas=False))

    def pallas(static, params):
        from repro.kernels.flash_attention.ops import flash_attention
        causal = static["causal"]
        bq, bk = params.get("block_q"), params.get("block_k")
        return jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, use_pallas=True, block_q=bq, block_k=bk))

    return (Variant("ref", ref),
            Variant("pallas", pallas, grid=_fa_grid, exact=False,
                    atol=2e-3, rtol=2e-3))


register_op(OpSpec(
    name="flash_attention",
    describe=_fa_describe,
    variants=_fa_variants,
    synth=_fa_synth,
    default=_pallas_or_ref(lambda kd: {"block_q": kd.block_q,
                                       "block_k": kd.block_k}),
))


# diffusion_conv: x [B, N, C], supports (tuple of [N, N]), w, bias


def _dc_describe(args, static):
    x, supports, w, bias = args
    del supports, bias
    return ({"b": x.shape[0], "n": x.shape[1], "c": x.shape[2],
             "h": w.shape[1]}, x.dtype)


def _dc_synth(bdims, static, dtype):
    import jax.numpy as jnp
    rng = _rng()
    b, n, c, h = bdims["b"], bdims["n"], bdims["c"], bdims["h"]
    k, ns = static["k_hops"], static["n_supports"]
    supports = []
    for _ in range(ns):
        adj = rng.uniform(0, 1, (n, n)).astype(np.float32)
        adj[adj < 0.5] = 0
        np.fill_diagonal(adj, 1.0)
        supports.append(jnp.asarray(adj / adj.sum(1, keepdims=True)))
    x = rng.standard_normal((b, n, c)).astype(dtype)
    w = (rng.standard_normal(((1 + ns * k) * c, h)) * 0.1).astype(dtype)
    bias = np.zeros((h,), dtype)
    return (jnp.asarray(x), tuple(supports), jnp.asarray(w),
            jnp.asarray(bias))


def _dc_grid(dims: dict, kd: KernelDefaults) -> tuple:
    blocks = dict.fromkeys(min(b, pow2_bucket(dims["n"]))
                           for b in block_candidates(kd.block_n))
    return tuple({"block_n": b} for b in blocks)


def _dc_variants() -> tuple[Variant, ...]:
    def ref(static, params):
        from repro.kernels.diffusion_conv.ref import diffusion_conv_ref
        k = static["k_hops"]
        return jax.jit(lambda x, sup, w, b: diffusion_conv_ref(x, sup, w, b,
                                                               k_hops=k))

    def pallas(static, params):
        from repro.kernels.diffusion_conv.ops import diffusion_conv
        k, bn = static["k_hops"], params.get("block_n")
        return jax.jit(lambda x, sup, w, b: diffusion_conv(
            x, sup, w, b, k_hops=k, use_pallas=True, block_n=bn))

    return (Variant("ref", ref),
            Variant("pallas", pallas, grid=_dc_grid, exact=False))


register_op(OpSpec(
    name="diffusion_conv",
    describe=_dc_describe,
    variants=_dc_variants,
    synth=_dc_synth,
    default=_pallas_or_ref(lambda kd: {"block_n": kd.block_n}),
))
