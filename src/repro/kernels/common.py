"""Shared kernel-op plumbing."""
from __future__ import annotations

import jax


def interpret_on_cpu() -> bool:
    """Whether Pallas kernels should run in interpret mode (CPU container).

    Resolved LAZILY at call time, never at import: reading the backend at
    import would initialize jax before a multi-host launcher can call
    ``jax.distributed.initialize()`` (models/kernels are imported long
    before main runs).
    """
    return jax.default_backend() == "cpu"
