"""Shared kernel-op plumbing: per-call backend resolution + block defaults.

Every verdict derived from the jax backend is resolved LAZILY, PER CALL —
never at import, never cached at first use.  Two reasons:

- reading the backend at import would initialize jax before a multi-host
  launcher can call ``jax.distributed.initialize()`` (models/kernels are
  imported long before main runs);
- caching at first use would let whichever thread happens to call first pin
  the verdict for everyone.  The async feed prefetcher
  (:mod:`repro.pipeline.prefetch`) runs host threads that may race device
  init: its stage-1 thread is numpy-only by contract, but a stage-2
  transfer thread CAN touch jax early, and a first-use cache primed there
  would freeze whatever backend was visible at that instant.  With per-call
  resolution there is nothing to pin — every kernel call re-reads
  ``jax.default_backend()`` (cheap: jax caches the client itself), and an
  explicit ``backend=`` override always wins over the ambient default.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class KernelDefaults:
    """Per-backend default tiling for the Pallas kernel ops.

    ``lane``        last-dim tile quantum (TPU lane width); last-dim blocks
                    should be multiples of this.
    ``block_c_max`` widest last-dim the window gather keeps as ONE block
                    when it is lane-aligned.
    ``block_c_cap`` last-dim block cap when the width is ragged.
    ``block_q/k``   flash-attention query/key tile lengths.
    ``block_n``     diffusion-conv node tile.
    ``block_b``     linear-scan batch tile (used when the batch divides it).
    ``scan_chunk``  linear-scan sequence chunk.
    ``interpret``   run Pallas in interpret mode (CPU has no Mosaic/Triton
                    lowering; interpret executes the kernel body in Python
                    for correctness).
    """

    lane: int = 128
    block_c_max: int = 4096
    block_c_cap: int = 2048
    block_q: int = 256
    block_k: int = 256
    block_n: int = 128
    block_b: int = 8
    scan_chunk: int = 256
    interpret: bool = False


#: Static per-backend table — selection from it happens per call in
#: :func:`kernel_defaults`; nothing here reads jax state.
_DEFAULTS = {
    "tpu": KernelDefaults(),
    "gpu": KernelDefaults(),
    "cpu": KernelDefaults(interpret=True),
}


def resolve_backend(backend: str | None = None) -> str:
    """The backend a kernel call should tile for: the explicit override when
    given, else ``jax.default_backend()`` read NOW (per call)."""
    return backend if backend is not None else jax.default_backend()


def kernel_defaults(backend: str | None = None) -> KernelDefaults:
    """Per-backend :class:`KernelDefaults`, resolved at call time.

    Unknown backends get the TPU-shaped defaults with interpret off — a new
    accelerator is better served by real lowering + lane-aligned tiles than
    by Python interpret mode.
    """
    return _DEFAULTS.get(resolve_backend(backend), KernelDefaults())


def interpret_on_cpu(backend: str | None = None) -> bool:
    """Whether Pallas kernels should run in interpret mode (CPU container).

    Kept as the historical entry point; equivalent to
    ``kernel_defaults(backend).interpret``.
    """
    return kernel_defaults(backend).interpret


def block_candidates(base: int, *, lo: int = 32,
                     hi: int = 4096) -> tuple[int, ...]:
    """The autotuner's block-size search space around a ``KernelDefaults``
    base tile: ``{base/2, base, base*2}`` clamped to ``[lo, hi]``, sorted and
    deduped (e.g. ``block_q=256 -> (128, 256, 512)``).  Small by design — the
    measured dispatcher (:mod:`repro.kernels.autotune`) times every candidate
    under jit, so the space must stay cheap to sweep."""
    return tuple(sorted({min(max(b, lo), hi)
                         for b in (base // 2, base, base * 2)}))
