"""Pallas TPU kernel: chunked diagonal linear recurrence (RG-LRU / SSM scan).

h_t = a_t ⊙ h_{t-1} + b_t over [B, S, D], computed in sequence chunks.

TPU adaptation: the recurrence is elementwise over (B, D) — all the
parallelism lives in those axes (VPU lanes), while S is inherently sequential.
The grid is (B/bb, D/bd, S/chunk) with the *sequence axis innermost*: TPU
grids execute sequentially in row-major order, so the running state for one
(B, D) tile stays resident in a VMEM scratch across all of its S-chunks — one
HBM round-trip for a/b, none for the carried state.  This mirrors the
production RG-LRU kernels in Gemma/Griffin, vs. the GPU approach of a
block-parallel associative scan (warp shuffles have no TPU analogue; the
sequential-grid carry is the idiomatic replacement).

Within a chunk the time loop is a ``fori_loop`` over VMEM rows — VPU work,
fully vectorised over the (bb, bd) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h0_ref, y_ref, hlast_ref, carry):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)

    chunk = a_ref.shape[1]
    h = carry[...]

    def step(t, h):
        at = a_ref[:, t].astype(jnp.float32)
        bt = b_ref[:, t].astype(jnp.float32)
        h = at * h + bt
        y_ref[:, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h)
    carry[...] = h

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _last():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_b", "block_d", "interpret"))
def linear_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    chunk: int = 256,
    block_b: int = 8,
    block_d: int = 128,
    interpret: bool = False,
):
    """a, b: [B, S, D]; h0: [B, D] -> (h_seq [B, S, D], h_last [B, D]).

    B % block_b == 0, D % block_d == 0, S % chunk == 0 (ops.py pads).
    """
    bsz, s, d = a.shape
    assert bsz % block_b == 0 and d % block_d == 0 and s % chunk == 0, (a.shape, block_b, block_d, chunk)
    grid = (bsz // block_b, d // block_d, s // chunk)

    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((block_b, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
            jax.ShapeDtypeStruct((bsz, d), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
