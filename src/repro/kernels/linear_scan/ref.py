"""Pure-jnp oracle for the diagonal linear recurrence h_t = a_t*h_{t-1} + b_t.

This is the RG-LRU inner loop (and any diagonal SSM).  The oracle is a plain
``lax.scan`` over time — bit-faithful sequential semantics the chunked Pallas
kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """a, b: [B, S, D]; h0: [B, D].  Returns (h_seq [B, S, D], h_last [B, D])."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    hl, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hl
