"""Public linear-scan op with shape padding; oracle by default."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_on_cpu
from repro.kernels.linear_scan.kernel import linear_scan as _linear_scan_kernel
from repro.kernels.linear_scan.ref import linear_scan_ref

def linear_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray | None = None,
    *,
    use_pallas: bool = False,
    chunk: int = 256,
):
    """h_t = a_t*h_{t-1} + b_t.  a/b: [B, S, D], h0: [B, D] (zeros if None).

    Returns (h_seq [B, S, D], h_last [B, D]).
    """
    bsz, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, d), a.dtype)
    if not use_pallas:
        return linear_scan_ref(a, b, h0)

    chunk = min(chunk, s)
    block_b = 8 if bsz % 8 == 0 else 1
    block_d = 128 if d % 128 == 0 else d
    pad_s = (-s) % chunk
    if pad_s:
        # padded steps use a=1, b=0 (identity) so h_last is unaffected
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)), constant_values=1)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    h_seq, h_last = _linear_scan_kernel(a, b, h0, chunk=chunk, block_b=block_b,
                                        block_d=block_d, interpret=interpret_on_cpu())
    return h_seq[:, :s], h_last
