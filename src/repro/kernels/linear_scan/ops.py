"""Public linear-scan op with shape padding; oracle by default."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import kernel_defaults
from repro.kernels.linear_scan.kernel import linear_scan as _linear_scan_kernel
from repro.kernels.linear_scan.ref import linear_scan_ref

def linear_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray | None = None,
    *,
    use_pallas: bool = False,
    chunk: int | None = None,
    backend: str | None = None,
    impl: str | None = None,
):
    """h_t = a_t*h_{t-1} + b_t.  a/b: [B, S, D], h0: [B, D] (zeros if None).

    Returns (h_seq [B, S, D], h_last [B, D]).  Tiling/interpret defaults
    resolve per call from ``backend`` (None = ambient, read now).  ``impl``
    overrides ``use_pallas``: ``"ref"``/``"pallas"`` force a lowering,
    ``"auto"`` routes through the measured dispatcher
    (:mod:`repro.kernels.autotune`).
    """
    bsz, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, d), a.dtype)
    if impl == "auto":
        from repro.kernels.autotune import dispatch
        return dispatch("linear_scan", a, b, h0)
    if impl is not None:
        if impl not in ("ref", "pallas"):
            raise ValueError(f"impl {impl!r}; expected ref|pallas|auto")
        use_pallas = impl == "pallas"
    if not use_pallas:
        return linear_scan_ref(a, b, h0)

    kd = kernel_defaults(backend)
    chunk = min(chunk if chunk is not None else kd.scan_chunk, s)
    block_b = kd.block_b if bsz % kd.block_b == 0 else 1
    block_d = kd.lane if d % kd.lane == 0 else d
    pad_s = (-s) % chunk
    if pad_s:
        # padded steps use a=1, b=0 (identity) so h_last is unaffected
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)), constant_values=1)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    h_seq, h_last = _linear_scan_kernel(a, b, h0, chunk=chunk, block_b=block_b,
                                        block_d=block_d, interpret=kd.interpret)
    return h_seq[:, :s], h_last
