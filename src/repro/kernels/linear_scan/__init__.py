from repro.kernels.linear_scan.ops import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref

__all__ = ["linear_scan", "linear_scan_ref"]
