"""A3T-GCN (Zhu et al. 2020) — the paper's §5.5 broader-applicability model.

TGCN cell (GRU whose gates are 2-hop GCNs over the symmetric-normalised
adjacency) unrolled over the input window, followed by global temporal
attention over the hidden-state sequence and a final projection to the
horizon.  Matches the PGT `a3tgcn2` example the paper integrates with.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class A3TGCNConfig:
    num_nodes: int
    in_features: int = 2
    hidden: int = 32
    input_len: int = 12
    horizon: int = 12


def _glorot(rng, shape):
    fan = sum(shape[-2:])
    return jax.random.normal(rng, shape, jnp.float32) * jnp.sqrt(2.0 / fan)


def init(rng, cfg: A3TGCNConfig) -> dict[str, Any]:
    ks = jax.random.split(rng, 6)
    in_dim, h = cfg.in_features, cfg.hidden
    return {
        # two-layer GCN inside each gate: (in+h) -> h
        "gcn_ru": {"w1": _glorot(ks[0], (in_dim + h, 2 * h)), "b1": jnp.zeros((2 * h,)),
                   "w2": _glorot(ks[1], (2 * h, 2 * h)), "b2": jnp.ones((2 * h,))},
        "gcn_c": {"w1": _glorot(ks[2], (in_dim + h, h)), "b1": jnp.zeros((h,)),
                  "w2": _glorot(ks[3], (h, h)), "b2": jnp.zeros((h,))},
        "att": {"w": _glorot(ks[4], (h, 1)), "b": jnp.zeros((1,))},
        "proj": {"w": _glorot(ks[5], (h, cfg.horizon)), "b": jnp.zeros((cfg.horizon,))},
    }


def _gcn(p, a_hat, x):
    """Two-hop GCN: A(A X W1 + b1) W2 + b2, x: [B, N, C]."""
    h = jnp.einsum("mn,bnc->bmc", a_hat, x) @ p["w1"] + p["b1"]
    return jnp.einsum("mn,bnc->bmc", a_hat, h) @ p["w2"] + p["b2"]


def _tgcn_cell(params, a_hat, x, h):
    xh = jnp.concatenate([x, h], axis=-1)
    ru = jax.nn.sigmoid(_gcn(params["gcn_ru"], a_hat, xh))
    r, u = jnp.split(ru, 2, axis=-1)
    xc = jnp.concatenate([x, r * h], axis=-1)
    c = jnp.tanh(_gcn(params["gcn_c"], a_hat, xc))
    return u * h + (1.0 - u) * c


def apply(params, cfg: A3TGCNConfig, a_hat: jnp.ndarray, x_seq: jnp.ndarray) -> jnp.ndarray:
    """x_seq: [B, T, N, F] -> [B, horizon, N, 1]."""
    bsz, _, n, _ = x_seq.shape
    h0 = jnp.zeros((bsz, n, cfg.hidden), x_seq.dtype)

    def step(h, xt):
        h2 = _tgcn_cell(params, a_hat, xt, h)
        return h2, h2

    _, hs = jax.lax.scan(step, h0, jnp.swapaxes(x_seq, 0, 1))  # [T, B, N, H]
    scores = hs @ params["att"]["w"] + params["att"]["b"]  # [T, B, N, 1]
    alpha = jax.nn.softmax(scores, axis=0)
    ctx = jnp.sum(alpha * hs, axis=0)  # [B, N, H]
    out = ctx @ params["proj"]["w"] + params["proj"]["b"]  # [B, N, horizon]
    return jnp.transpose(out, (0, 2, 1))[..., None]


@partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params, cfg: A3TGCNConfig, a_hat, x, y):
    pred = apply(params, cfg, a_hat, x)
    return jnp.mean((pred - y[..., :1]) ** 2)  # A3T-GCN trains with MSE (Table 6)
