"""PGT-DCRNN — the paper's lightweight variant (§3).

A single spatiotemporal diffusion-conv recurrent layer processed *stepwise*:
the hidden state is carried across the input sequence and an output is emitted
at every step, forming a prediction sequence of equal length to the input
(the paper's modification for batched seq2seq prediction).  No encoder-decoder
structure — deliberately simpler and faster than full DCRNN, matching the
15.3x runtime gap reported in Table 2.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.diffusion_conv import diffusion_conv


@dataclasses.dataclass(frozen=True)
class PGTDCRNNConfig:
    num_nodes: int
    in_features: int = 2
    out_features: int = 1
    hidden: int = 64
    max_diffusion_step: int = 2
    input_len: int = 12
    horizon: int = 12
    use_pallas: bool = False
    remat: bool = False  # checkpoint each time step (needed at PeMS scale)

    @property
    def n_matrices(self) -> int:
        return 1 + 2 * self.max_diffusion_step


def init(rng, cfg: PGTDCRNNConfig) -> dict[str, Any]:
    kru, kc, kp = jax.random.split(rng, 3)
    in_dim = (cfg.in_features + cfg.hidden) * cfg.n_matrices

    def dconv(k, out):
        return {
            "w": jax.random.normal(k, (in_dim, out), jnp.float32) / jnp.sqrt(in_dim),
            "b": jnp.zeros((out,), jnp.float32),
        }

    return {
        "ru": dconv(kru, 2 * cfg.hidden),
        "c": dconv(kc, cfg.hidden),
        "proj": {
            "w": jax.random.normal(kp, (cfg.hidden, cfg.out_features), jnp.float32)
            / jnp.sqrt(cfg.hidden),
            "b": jnp.zeros((cfg.out_features,), jnp.float32),
        },
    }


def _cell(params, cfg: PGTDCRNNConfig, supports, x, h):
    xh = jnp.concatenate([x, h], axis=-1)
    ru = jax.nn.sigmoid(
        diffusion_conv(xh, supports, params["ru"]["w"], params["ru"]["b"],
                       k_hops=cfg.max_diffusion_step, use_pallas=cfg.use_pallas))
    r, u = jnp.split(ru, 2, axis=-1)
    xc = jnp.concatenate([x, r * h], axis=-1)
    c = jnp.tanh(
        diffusion_conv(xc, supports, params["c"]["w"], params["c"]["b"],
                       k_hops=cfg.max_diffusion_step, use_pallas=cfg.use_pallas))
    return u * h + (1.0 - u) * c


def apply(params, cfg: PGTDCRNNConfig, supports, x_seq: jnp.ndarray) -> jnp.ndarray:
    """x_seq: [B, T, N, F] -> [B, T, N, out_features] (stepwise predictions)."""
    bsz, _, n, _ = x_seq.shape
    h0 = jnp.zeros((bsz, n, cfg.hidden), x_seq.dtype)

    def step(h, xt):
        h2 = _cell(params, cfg, supports, xt, h)
        out = h2 @ params["proj"]["w"] + params["proj"]["b"]
        return h2, out

    if cfg.remat:
        step = jax.checkpoint(step)
    _, outs = jax.lax.scan(step, h0, jnp.swapaxes(x_seq, 0, 1))
    return jnp.swapaxes(outs, 0, 1)


@partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params, cfg: PGTDCRNNConfig, supports, x, y):
    pred = apply(params, cfg, supports, x)
    return jnp.mean(jnp.abs(pred - y[..., : cfg.out_features]))
