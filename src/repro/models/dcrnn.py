"""DCRNN (Li et al., ICLR'18) — full encoder-decoder with DCGRU cells.

This is the paper's baseline model ("the original DCRNN"): an encoder stack of
DCGRU layers consumes the input sequence; a decoder stack (with output
projection) rolls out ``horizon`` predictions, teacher-forced during training
via scheduled sampling.

Diffusion convolution (the compute hot spot) follows the paper's dual
random-walk form:

    DConv(X; theta) = sum_{k=0..K} ( (D_O^{-1} A)^k X W_k^{fwd}
                                   + (D_I^{-1} A^T)^k X W_k^{rev} )

realised as a hop recurrence ``Z_k = S @ Z_{k-1}`` feeding one fused
projection.  The recurrence is exposed through ``repro.kernels.diffusion_conv``
so the Pallas TPU kernel and the jnp oracle are interchangeable here.

All functions are functional (params pytree in, arrays out) and jit/pjit-safe;
time loops use ``jax.lax.scan``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.diffusion_conv import diffusion_conv


@dataclasses.dataclass(frozen=True)
class DCRNNConfig:
    num_nodes: int
    in_features: int = 2
    out_features: int = 1
    hidden: int = 64
    layers: int = 2
    max_diffusion_step: int = 2  # K
    input_len: int = 12
    horizon: int = 12
    use_pallas: bool = False  # route DConv through the Pallas kernel
    remat: bool = False  # checkpoint each time step (needed at PeMS scale)

    @property
    def n_supports(self) -> int:
        return 2  # forward + reverse random walks

    @property
    def n_matrices(self) -> int:
        # identity hop + K hops per support
        return 1 + self.n_supports * self.max_diffusion_step


# --------------------------------------------------------------------- params
def _dconv_params(rng, cfg: DCRNNConfig, in_dim: int, out_dim: int):
    k1, _ = jax.random.split(rng)
    fan_in = in_dim * cfg.n_matrices
    w = jax.random.normal(k1, (fan_in, out_dim), jnp.float32) * (1.0 / jnp.sqrt(fan_in))
    b = jnp.zeros((out_dim,), jnp.float32)
    return {"w": w, "b": b}


def _cell_params(rng, cfg: DCRNNConfig, in_dim: int):
    kr, ku, kc = jax.random.split(rng, 3)
    h = cfg.hidden
    return {
        "ru": _dconv_params(kr, cfg, in_dim + h, 2 * h),  # fused reset+update gates
        "c": _dconv_params(kc, cfg, in_dim + h, h),
    }


def init(rng, cfg: DCRNNConfig) -> dict[str, Any]:
    keys = jax.random.split(rng, 2 * cfg.layers + 1)
    enc = [_cell_params(keys[i], cfg, cfg.in_features if i == 0 else cfg.hidden)
           for i in range(cfg.layers)]
    dec = [_cell_params(keys[cfg.layers + i], cfg, cfg.out_features if i == 0 else cfg.hidden)
           for i in range(cfg.layers)]
    kp = keys[-1]
    proj = {
        "w": jax.random.normal(kp, (cfg.hidden, cfg.out_features), jnp.float32)
        * (1.0 / jnp.sqrt(cfg.hidden)),
        "b": jnp.zeros((cfg.out_features,), jnp.float32),
    }
    return {"encoder": enc, "decoder": dec, "proj": proj}


# ---------------------------------------------------------------------- cells
def _dconv(p, cfg: DCRNNConfig, supports, x):
    """x: [B, N, C_in] -> [B, N, C_out] via the shared diffusion-conv op."""
    return diffusion_conv(x, supports, p["w"], p["b"],
                          k_hops=cfg.max_diffusion_step, use_pallas=cfg.use_pallas)


def dcgru_cell(p, cfg: DCRNNConfig, supports, x, h):
    """One DCGRU step.  x: [B, N, C], h: [B, N, H] -> new h."""
    xh = jnp.concatenate([x, h], axis=-1)
    ru = jax.nn.sigmoid(_dconv(p["ru"], cfg, supports, xh))
    r, u = jnp.split(ru, 2, axis=-1)
    xc = jnp.concatenate([x, r * h], axis=-1)
    c = jnp.tanh(_dconv(p["c"], cfg, supports, xc))
    return u * h + (1.0 - u) * c


def _stack_step(cells, cfg, supports, x, hs):
    """Run the layer stack for one time step.  hs: [L, B, N, H] list."""
    new_hs = []
    inp = x
    for p, h in zip(cells, hs):
        h2 = dcgru_cell(p, cfg, supports, inp, h)
        new_hs.append(h2)
        inp = h2
    return inp, new_hs


# -------------------------------------------------------------------- forward
def apply(
    params,
    cfg: DCRNNConfig,
    supports: tuple[jnp.ndarray, jnp.ndarray],
    x_seq: jnp.ndarray,
    *,
    y_teacher: jnp.ndarray | None = None,
    teacher_prob: float = 0.0,
    rng=None,
) -> jnp.ndarray:
    """x_seq: [B, T_in, N, F] -> predictions [B, horizon, N, out_features].

    ``y_teacher`` + ``teacher_prob`` implement scheduled sampling: with prob p
    the decoder input at step t is the ground truth instead of its own output.
    """
    B, _, N, _ = x_seq.shape
    h0 = [jnp.zeros((B, N, cfg.hidden), x_seq.dtype) for _ in range(cfg.layers)]

    # ---- encoder: scan over input time steps
    def enc_step(hs, xt):
        _, hs2 = _stack_step(params["encoder"], cfg, supports, xt, hs)
        return hs2, None

    if cfg.remat:
        # store only per-step carries; recompute DConv intermediates in bwd
        # (without this the scan saves every hop's [B, N, C] — measured
        # 209 GiB/device on the PeMS cell)
        enc_step = jax.checkpoint(enc_step)
    hs, _ = jax.lax.scan(enc_step, h0, jnp.swapaxes(x_seq, 0, 1))

    # ---- decoder: roll out horizon steps
    go = jnp.zeros((B, N, cfg.out_features), x_seq.dtype)
    use_teacher = y_teacher is not None and teacher_prob > 0.0
    if use_teacher:
        coin = jax.random.bernoulli(rng, teacher_prob, (cfg.horizon,))
        teach = jnp.swapaxes(y_teacher, 0, 1)  # [T, B, N, F_out]
    else:
        coin = jnp.zeros((cfg.horizon,), bool)
        teach = jnp.zeros((cfg.horizon, B, N, cfg.out_features), x_seq.dtype)

    def dec_step(carry, inputs):
        hs, prev = carry
        use_t, y_t = inputs
        inp = jnp.where(use_t, y_t, prev)
        top, hs2 = _stack_step(params["decoder"], cfg, supports, inp, hs)
        out = top @ params["proj"]["w"] + params["proj"]["b"]
        return (hs2, out), out

    if cfg.remat:
        dec_step = jax.checkpoint(dec_step)
    (_, _), outs = jax.lax.scan(dec_step, (hs, go), (coin, teach))
    return jnp.swapaxes(outs, 0, 1)  # [B, horizon, N, F_out]


# ----------------------------------------------------------------------- loss
def mae_loss(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.abs(pred - target))


@partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params, cfg: DCRNNConfig, supports, x, y):
    pred = apply(params, cfg, supports, x)
    return mae_loss(pred, y[..., : cfg.out_features])
