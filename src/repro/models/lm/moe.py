"""Mixture-of-Experts FFN (grok-1: 8e top-2; deepseek-v2-lite: 64e top-6 + 2 shared).

Dispatch is sort-based with static capacity (dropless up to
``capacity_factor``): tokens are ordered by expert id (stable sort keeps
earlier tokens at higher priority), positions within each expert's queue are
computed from segment starts, and tokens beyond capacity are dropped (they
keep their residual + shared-expert path).  Expert compute is one batched
einsum ``[E, C, d] x [E, d, de]`` that maps cleanly onto the MXU and shards
over the model axis (TP on ``de``) or the expert axis (EP) — see
``launch/sharding.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.config import MoEConfig
from repro.models.lm.layers import init_linear, init_mlp, mlp


def init_moe(rng, d_model: int, moe: MoEConfig, d_ff: int, mlp_kind: str,
             dtype=jnp.float32):
    de = moe.d_expert or d_ff
    kr, ke, ks = jax.random.split(rng, 3)
    scale = 1.0 / jnp.sqrt(d_model)
    e = moe.n_experts

    def stack(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": init_linear(kr, d_model, e, dtype=jnp.float32),  # router in f32
        "wi": stack(k1, (e, d_model, de)),
        "wg": stack(k2, (e, d_model, de)),
        "wo": stack(k3, (e, de, d_model)),
    }
    if moe.n_shared:
        p["shared"] = init_mlp(ks, d_model, moe.n_shared * de, mlp_kind, dtype=dtype)
    return p


def _dispatch_indices(top_ix: jnp.ndarray, n_experts: int, capacity: int):
    """top_ix: [T, k] expert ids -> (slot_token [E, C], slot_valid [E, C],
    token_slot_weighting helpers).  Pure integer ops, static shapes."""
    t, k = top_ix.shape
    e_flat = top_ix.reshape(-1)  # token-major: token i slot j -> i*k + j
    order = jnp.argsort(e_flat, stable=True)  # grouped by expert, FIFO inside
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[sorted_e]  # position within expert queue
    keep = pos < capacity
    # scatter (expert, pos) -> flat (token*k + slot) index; dropped -> sentinel
    slot_src = jnp.full((n_experts, capacity), t * k, jnp.int32)  # sentinel
    slot_src = slot_src.at[sorted_e, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, order, t * k).astype(jnp.int32), mode="drop")
    return slot_src  # [E, C] indices into the flattened (token, slot) space


def _constrain(x, shardings, key):
    if shardings is None or shardings.get(key) is None:
        return x
    return jax.lax.with_sharding_constraint(x, shardings[key])


def moe_ffn(p, x: jnp.ndarray, moe: MoEConfig, mlp_kind: str, *,
            deterministic: bool = True, shardings=None, groups: int = 1):
    """x: [B, S, d] -> (y, aux_loss).

    ``groups > 1``: GROUPED LOCAL DISPATCH — tokens are split into ``groups``
    independent dispatch domains (one per data shard), each with its own
    capacity.  The argsort/bincount/gather/scatter then never cross shards
    (hint "moe_group" pins the group dim to the data axes), removing the
    global-dispatch collectives at a small load-imbalance cost — the classic
    per-core dispatch of Switch/GShard, adapted to the (data, model) mesh.
    """
    b, s, d = x.shape
    if groups > 1:
        return _moe_ffn_grouped(p, x, moe, mlp_kind, groups=groups,
                                shardings=shardings)
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"]["w"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ix = jax.lax.top_k(probs, moe.top_k)  # [T, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    capacity = int(t * moe.top_k / moe.n_experts * moe.capacity_factor)
    capacity = max(128, -(-capacity // 128) * 128)  # round up (128: shardable)
    slot_src = _dispatch_indices(top_ix, moe.n_experts, capacity)  # [E, C]

    token_of = slot_src // moe.top_k  # sentinel t*k -> t (out of range)
    valid = slot_src < t * moe.top_k
    gather_ix = jnp.where(valid, token_of, 0)
    xe = _constrain(xf[gather_ix], shardings, "moe_cap")  # [E, C, d]
    w_slot = jnp.where(valid, top_w.reshape(-1)[jnp.where(valid, slot_src, 0)], 0.0)

    # Batched expert FFN (single einsum per projection — MXU/TP friendly).
    if mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
        hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(x.dtype))
        hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(x.dtype))
        he = act(hg) * hi
    else:
        he = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(x.dtype)))
    ye = _constrain(jnp.einsum("ecf,efd->ecd", he, p["wo"].astype(x.dtype)),
                    shardings, "moe_cap")

    # Combine: scatter-add weighted expert outputs back to tokens.
    yf = jnp.zeros((t + 1, d), x.dtype)  # +1 dump row for dropped slots
    scatter_ix = jnp.where(valid, token_of, t)
    yf = yf.at[scatter_ix.reshape(-1)].add(
        (ye * w_slot[..., None].astype(x.dtype)).reshape(-1, d))
    y = yf[:t].reshape(b, s, d)

    if moe.n_shared:
        y = y + mlp(p["shared"], x, mlp_kind)

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ix, moe.n_experts, dtype=jnp.float32), axis=1),
        axis=0)
    aux = moe.aux_loss_coef * moe.n_experts * jnp.sum(fe * me)
    return y, aux


def _moe_ffn_grouped(p, x, moe: MoEConfig, mlp_kind: str, *, groups: int,
                     shardings=None):
    """Grouped local dispatch with EXPLICIT group batch dims.

    All dispatch math (sort, position, gather, scatter) carries the leading
    group dim and the "moe_group*" hints pin it to the data axes, so every
    dispatch op stays shard-local (a vmap'd formulation loses the sharding at
    the gather — measured: the partitioner all-gathers the 60 GB xe buffer
    per layer).  Expert einsums are 2D-sharded: groups × data, d_expert ×
    model.  Per-group capacity trades ~load balance for zero dispatch
    collectives (GShard/Switch per-core dispatch).
    """
    b, s, d = x.shape
    t = b * s
    assert t % groups == 0, (t, groups)
    tg = t // groups
    e, k = moe.n_experts, moe.top_k
    xg = _constrain(x.reshape(groups, tg, d), shardings, "moe_group")

    logits = xg.astype(jnp.float32) @ p["router"]["w"]  # [g, tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ix = jax.lax.top_k(probs, k)  # [g, tg, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    capacity = int(tg * k / e * moe.capacity_factor)
    capacity = max(128, -(-capacity // 128) * 128)

    # --- batched dispatch indices (leading g dim everywhere)
    e_flat = top_ix.reshape(groups, tg * k)
    order = jnp.argsort(e_flat, axis=1, stable=True)  # [g, tg*k]
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # [g, tg*k, E]
    counts = jnp.sum(onehot, axis=1)  # [g, E]
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(tg * k)[None] - jnp.take_along_axis(starts, sorted_e, axis=1)
    keep = pos < capacity
    slot_src = jnp.full((groups, e, capacity), tg * k, jnp.int32)
    slot_src = slot_src.at[
        jnp.arange(groups)[:, None], sorted_e, jnp.where(keep, pos, 0)
    ].set(jnp.where(keep, order, tg * k).astype(jnp.int32), mode="drop")

    token_of = slot_src // k  # [g, E, C]
    valid = slot_src < tg * k
    gather_ix = jnp.where(valid, token_of, 0).reshape(groups, e * capacity)
    xe = jnp.take_along_axis(xg, gather_ix[..., None], axis=1)
    xe = _constrain(xe.reshape(groups, e, capacity, d), shardings, "moe_disp")
    w_flat = top_w.reshape(groups, tg * k)
    w_slot = jnp.where(
        valid, jnp.take_along_axis(
            w_flat, jnp.where(valid, slot_src, 0).reshape(groups, e * capacity),
            axis=1).reshape(groups, e, capacity), 0.0)

    if mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
        hi = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype))
        hg = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(x.dtype))
        he = act(hg) * hi
    else:
        he = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype)))
    ye = jnp.einsum("gecf,efd->gecd", he, p["wo"].astype(x.dtype))
    ye = _constrain(ye, shardings, "moe_disp")

    yf = jnp.zeros((groups, tg + 1, d), x.dtype)  # +1 dump row per group
    scatter_ix = jnp.where(valid, token_of, tg).reshape(groups, e * capacity)
    contrib = (ye * w_slot[..., None].astype(x.dtype)).reshape(groups, e * capacity, d)
    yf = yf.at[jnp.arange(groups)[:, None], scatter_ix].add(contrib)
    y = _constrain(yf[:, :tg], shardings, "moe_group").reshape(b, s, d)

    if moe.n_shared:
        y = y + mlp(p["shared"], x, mlp_kind)

    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(jnp.sum(jax.nn.one_hot(top_ix, e, dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = moe.aux_loss_coef * e * jnp.sum(fe * me)
    return y, aux
