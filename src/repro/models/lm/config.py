"""Model-level configuration for the LM backbone (10 assigned architectures).

One ``LMConfig`` describes any of: dense GQA/MQA decoders (qwen/minitron/
granite/danube/internvl backbone), MoE decoders (grok, deepseek-v2-lite w/
MLA), audio-token decoders (musicgen), hybrid recurrent (recurrentgemma
RG-LRU 1:2) and attention-free SSM (rwkv6).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int | None = None  # expert FFN width (defaults to d_ff)
    first_k_dense: int = 0  # leading dense layers (deepseek)
    dense_d_ff: int | None = None  # width of those dense layers
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False  # qwen1.5
    attn: Literal["full", "swa", "mla", "none"] = "full"
    window: int | None = None  # swa / recurrentgemma local-attn window
    pos: Literal["rope", "learned", "none"] = "rope"
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192  # learned-pos table size / cache default
    mlp: Literal["swiglu", "geglu", "gelu", "relu_sq"] = "swiglu"
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    # hybrid/ssm families
    block_pattern: tuple[str, ...] | None = None  # e.g. ("rec","rec","attn")
    lru_width: int | None = None  # RG-LRU state width
    conv1d_width: int = 4  # Griffin temporal conv
    rwkv: bool = False
    rwkv_head_size: int = 64
    # frontend stubs
    frontend: Literal["tokens", "patches", "frames"] = "tokens"
    n_prefix: int = 0  # precomputed patch/frame embeddings prepended
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    # kernels
    use_pallas_scan: bool = False  # route RG-LRU through kernels/linear_scan
    # sharding: pad the embedding/logit tables so vocab divides the TP axis
    # (standard practice; padded ids are masked to -inf in logits_fn)
    pad_vocab_to_multiple: int = 0
    # blockwise-attention tile shape (perf knob; see EXPERIMENTS.md §Perf)
    q_chunk: int = 512
    kv_chunk: int = 512

    def __post_init__(self):
        if self.attn != "none" and self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: n_heads {self.n_heads} not divisible by kv {self.n_kv_heads}")

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        return self.vocab if not m else -(-self.vocab // m) * m

    def block_types(self) -> tuple[str, ...]:
        """Per-layer block kind: attn | swa | mla | rec | rwkv."""
        if self.rwkv:
            return ("rwkv",) * self.layers
        if self.block_pattern is not None:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.layers))
        return (self.attn,) * self.layers

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline and memory budgets)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.block_types():
            total += 2 * d  # two RMSNorm gains
            if kind in ("attn", "full", "swa"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif kind == "mla":
                m = self.mla
                qd = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                total += d * qd  # W_q
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # W_dkv
                total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += self.n_heads * m.v_head_dim * d  # W_o
            elif kind == "rec":
                w = self.lru_width or d
                total += 2 * d * w + w * d  # in-proj x2 + out-proj
                total += self.conv1d_width * w + w  # conv1d
                total += 2 * w + 2 * w * max(w // 16, 8)  # RG-LRU gates (lora-ish)
            elif kind == "rwkv":
                total += 6 * d * d // 1  # r,k,v,g,o,w projections (approx)
                total += 2 * d * self.d_ff  # channel mix
                continue  # rwkv has its own ffn accounted above
            # FFN
            if self.moe is not None and kind not in ("rec",):
                continue  # counted below per-layer via moe block
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            total += mult * d * self.d_ff
        if self.moe is not None:
            de = self.moe.d_expert or self.d_ff
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            n_moe = self.layers - self.moe.first_k_dense
            total += n_moe * (self.moe.n_experts + self.moe.n_shared) * mult * d * de
            total += n_moe * d * self.moe.n_experts  # router
            dff = self.moe.dense_d_ff or self.d_ff
            total += self.moe.first_k_dense * mult * d * dff
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        de = self.moe.d_expert or self.d_ff
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        n_moe = self.layers - self.moe.first_k_dense
        inactive = n_moe * (self.moe.n_experts - self.moe.top_k) * mult * self.d_model * de
        return self.param_count() - inactive
